#!/usr/bin/env python3
"""Fail-soft comparison of two BENCH_router.json artifacts.

Usage: bench_compare.py BASELINE.json CURRENT.json

Prints a GitHub-flavored markdown table of per-phase ns deltas (negative
= faster).  Tolerates a missing or schema-drifted baseline: any phase it
cannot pair is reported as "new", and an unreadable baseline degrades to
a note instead of a failure — CI must never go red because history is
thin.
"""

import json
import sys


def dig(d, *path):
    for k in path:
        if not isinstance(d, dict):
            return None
        d = d.get(k)
    return d


def rows(doc):
    """Yield (label, ns) pairs for every phase we know how to read."""
    for c in doc.get("clusters", []):
        n = c.get("n")
        yield (f"n={n} steady put", dig(c, "steady", "put", "ns_op"))
        yield (f"n={n} steady get", dig(c, "steady", "get", "ns_op"))
        yield (f"n={n} churn get", dig(c, "churn", "get", "ns_op"))
        yield (f"n={n} failover get", dig(c, "failover", "get", "ns_op"))
        for b in dig(c, "batch", "sizes") or []:
            bs = b.get("batch")
            yield (f"n={n} mget@{bs}", dig(b, "mget", "ns_key"))
            yield (f"n={n} mput@{bs}", dig(b, "mput", "ns_key"))
        ratio = dig(c, "batch", "mget64_vs_get")
        if ratio is not None:
            yield (f"n={n} mget64-vs-get ratio", -ratio)  # sentinel: ratio row
    pb = doc.get("placement_batch")
    if isinstance(pb, dict):  # absent in pre-bucket_batch artifacts
        tag = f"placement n={pb.get('n')}"
        for b in pb.get("sizes") or []:
            bs = b.get("batch")
            yield (f"{tag} scalar@{bs}", b.get("scalar_ns_key"))
            yield (f"{tag} batched@{bs}", b.get("batched_ns_key"))
            speedup = b.get("speedup")
            if speedup is not None:
                yield (f"{tag} batch@{bs} speedup ratio", -speedup)
    rep = doc.get("replication")
    if isinstance(rep, dict):  # absent in pre-replication artifacts
        n = rep.get("n")
        f = rep.get("factor")
        tag = f"replication n={n} R={f}"
        yield (f"{tag} put", dig(rep, "put", "ns_op"))
        yield (f"{tag} get", dig(rep, "get", "ns_op"))
        yield (f"{tag} degraded get", dig(rep, "degraded_get", "ns_op"))
        yield (f"{tag} degraded get p99", rep.get("degraded_p99"))
        yield (f"{tag} restore round-trips", rep.get("restore_round_trips"))
    zipf = doc.get("zipf")
    if isinstance(zipf, dict):  # absent in pre-hot-cache artifacts
        n = zipf.get("n")
        tag = f"zipf n={n} t={zipf.get('theta')}"
        yield (f"{tag} get cache-off", dig(zipf, "get_cache_off", "ns_op"))
        yield (f"{tag} get cache-on", dig(zipf, "get_cache_on", "ns_op"))
        speedup = zipf.get("cache_speedup")
        if speedup is not None:
            yield (f"{tag} cache-speedup ratio", -speedup)
        w = zipf.get("weighted")
        if isinstance(w, dict):
            wtag = f"weighted {w.get('weights')}"
            yield (f"{wtag} get", dig(w, "get", "ns_op"))
            lf = w.get("weighted_load_factor")
            if lf is not None:
                yield (f"{wtag} load-factor ratio", -lf)
    fan = doc.get("fanin")
    if isinstance(fan, dict):  # null on platforms without the event server
        conns = fan.get("connections")
        yield (f"fanin@{conns} connect", dig(fan, "connect", "ns_op"))
        yield (f"fanin@{conns} hot get", dig(fan, "get", "ns_op"))
        yield (f"fanin@{conns} hot get p99", fan.get("p99"))


def main():
    if len(sys.argv) != 3:
        print("usage: bench_compare.py BASELINE.json CURRENT.json")
        return
    try:
        with open(sys.argv[2]) as f:
            new = dict(rows(json.load(f)))
    except Exception as e:  # the fresh file should exist; still fail soft
        print(f"bench-compare: current bench unreadable ({e}); skipping")
        return
    try:
        with open(sys.argv[1]) as f:
            old = dict(rows(json.load(f)))
    except Exception as e:
        print(f"bench-compare: no usable baseline ({e}); current run seeds it")
        old = {}

    print("| phase | baseline | current | delta |")
    print("|---|---:|---:|---:|")
    for label, cur in new.items():
        if cur is None:
            continue
        base = old.get(label)
        if label.endswith("ratio"):
            # Stored negated so the generic pairing still works.
            cur_s = f"{-cur:.2f}x"
            base_s = f"{-base:.2f}x" if base is not None else "—"
            print(f"| {label} | {base_s} | {cur_s} | |")
            continue
        unit = "" if label.endswith("round-trips") else " ns"
        if base is None or base == 0:
            print(f"| {label} | — | {cur:.0f}{unit} | new |")
            continue
        delta = (cur - base) / base * 100.0
        print(f"| {label} | {base:.0f}{unit} | {cur:.0f}{unit} | {delta:+.1f}% |")


if __name__ == "__main__":
    main()
