#!/usr/bin/env python3
"""Unit tests for bench_compare.py (stdlib only; run by the CI lint job).

Covers the three behaviors CI leans on: a missing/unreadable baseline
degrades to a note (never a failure), a phase present only in the
current artifact is reported as "new", and paired phases get a signed
percentage delta.
"""

import contextlib
import io
import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import bench_compare  # noqa: E402


def doc(get_ns=100.0, zipf=None, placement_batch=None):
    """A minimal BENCH_router.json document with one cluster."""
    d = {
        "bench": "router_hotpath",
        "clusters": [
            {
                "n": 4,
                "steady": {
                    "put": {"ns_op": 200.0},
                    "get": {"ns_op": get_ns},
                },
                "churn": {"get": {"ns_op": 300.0}},
                "failover": {"get": {"ns_op": 400.0}},
                "batch": {
                    "sizes": [
                        {
                            "batch": 8,
                            "mget": {"ns_key": 50.0},
                            "mput": {"ns_key": 60.0},
                        }
                    ],
                    "mget64_vs_get": 2.5,
                },
            }
        ],
    }
    if zipf is not None:
        d["zipf"] = zipf
    if placement_batch is not None:
        d["placement_batch"] = placement_batch
    return d


PLACEMENT_BATCH = {
    "engine": "binomial",
    "n": 16,
    "sizes": [
        {"batch": 64, "scalar_ns_key": 8.0, "batched_ns_key": 5.0, "speedup": 1.6},
        {"batch": 1024, "scalar_ns_key": 8.0, "batched_ns_key": 4.0, "speedup": 2.0},
    ],
}


ZIPF = {
    "n": 16,
    "theta": 0.99,
    "get_cache_off": {"ns_op": 500.0},
    "get_cache_on": {"ns_op": 120.0},
    "cache_speedup": 4.17,
    "weighted": {
        "weights": "4x2+4x1",
        "get": {"ns_op": 550.0},
        "weighted_load_factor": 1.012,
    },
}


def run_compare(baseline_path, current_path):
    """Run bench_compare.main() against two paths, capturing stdout."""
    argv, sys.argv = sys.argv, ["bench_compare.py", baseline_path, current_path]
    out = io.StringIO()
    try:
        with contextlib.redirect_stdout(out):
            bench_compare.main()
    finally:
        sys.argv = argv
    return out.getvalue()


def write_json(tmpdir, name, document):
    path = os.path.join(tmpdir, name)
    with open(path, "w") as f:
        json.dump(document, f)
    return path


class RowsTest(unittest.TestCase):
    def test_zipf_phase_yields_labeled_rows(self):
        labels = dict(bench_compare.rows(doc(zipf=ZIPF)))
        self.assertEqual(labels["zipf n=16 t=0.99 get cache-off"], 500.0)
        self.assertEqual(labels["zipf n=16 t=0.99 get cache-on"], 120.0)
        # Ratio rows are stored negated so the generic pairing works.
        self.assertEqual(labels["zipf n=16 t=0.99 cache-speedup ratio"], -4.17)
        self.assertEqual(labels["weighted 4x2+4x1 get"], 550.0)
        self.assertEqual(labels["weighted 4x2+4x1 load-factor ratio"], -1.012)

    def test_documents_without_zipf_yield_no_zipf_rows(self):
        labels = dict(bench_compare.rows(doc()))
        self.assertFalse(any(label.startswith("zipf") for label in labels))

    def test_placement_batch_phase_yields_labeled_rows(self):
        labels = dict(bench_compare.rows(doc(placement_batch=PLACEMENT_BATCH)))
        self.assertEqual(labels["placement n=16 scalar@64"], 8.0)
        self.assertEqual(labels["placement n=16 batched@64"], 5.0)
        self.assertEqual(labels["placement n=16 scalar@1024"], 8.0)
        self.assertEqual(labels["placement n=16 batched@1024"], 4.0)
        # Speedup ratios ride the negated-sentinel convention.
        self.assertEqual(labels["placement n=16 batch@64 speedup ratio"], -1.6)
        self.assertEqual(labels["placement n=16 batch@1024 speedup ratio"], -2.0)

    def test_documents_without_placement_batch_yield_no_placement_rows(self):
        labels = dict(bench_compare.rows(doc()))
        self.assertFalse(any(label.startswith("placement") for label in labels))


class CompareTest(unittest.TestCase):
    def test_missing_baseline_degrades_to_a_note(self):
        with tempfile.TemporaryDirectory() as tmp:
            cur = write_json(tmp, "current.json", doc())
            out = run_compare(os.path.join(tmp, "absent.json"), cur)
        self.assertIn("no usable baseline", out)
        # Every phase still prints, flagged as new.
        self.assertIn("| n=4 steady get | — | 100 ns | new |", out)

    def test_unreadable_baseline_degrades_to_a_note(self):
        with tempfile.TemporaryDirectory() as tmp:
            cur = write_json(tmp, "current.json", doc())
            bad = os.path.join(tmp, "bad.json")
            with open(bad, "w") as f:
                f.write("not json {")
            out = run_compare(bad, cur)
        self.assertIn("no usable baseline", out)
        self.assertIn("new", out)

    def test_phase_added_since_baseline_is_reported_as_new(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_json(tmp, "base.json", doc())
            cur = write_json(tmp, "current.json", doc(zipf=ZIPF))
            out = run_compare(base, cur)
        # The paired phase gets a delta, the new phase gets "new".
        self.assertIn("| n=4 steady get | 100 ns | 100 ns | +0.0% |", out)
        self.assertIn("| zipf n=16 t=0.99 get cache-on | — | 120 ns | new |", out)

    def test_regression_delta_formatting(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_json(tmp, "base.json", doc(get_ns=100.0))
            cur = write_json(tmp, "current.json", doc(get_ns=150.0))
            out = run_compare(base, cur)
        self.assertIn("| n=4 steady get | 100 ns | 150 ns | +50.0% |", out)

    def test_improvement_delta_is_negative(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_json(tmp, "base.json", doc(get_ns=100.0))
            cur = write_json(tmp, "current.json", doc(get_ns=80.0))
            out = run_compare(base, cur)
        self.assertIn("| n=4 steady get | 100 ns | 80 ns | -20.0% |", out)

    def test_placement_batch_rows_pair_and_render(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_json(tmp, "base.json", doc(placement_batch=PLACEMENT_BATCH))
            cur = write_json(tmp, "current.json", doc(placement_batch=PLACEMENT_BATCH))
            out = run_compare(base, cur)
        self.assertIn("| placement n=16 batched@1024 | 4 ns | 4 ns | +0.0% |", out)
        self.assertIn("| placement n=16 batch@1024 speedup ratio | 2.00x | 2.00x | |", out)

    def test_ratio_rows_render_as_multipliers_without_delta(self):
        with tempfile.TemporaryDirectory() as tmp:
            base = write_json(tmp, "base.json", doc(zipf=ZIPF))
            cur = write_json(tmp, "current.json", doc(zipf=ZIPF))
            out = run_compare(base, cur)
        self.assertIn("| n=4 mget64-vs-get ratio | 2.50x | 2.50x | |", out)
        self.assertIn(
            "| zipf n=16 t=0.99 cache-speedup ratio | 4.17x | 4.17x | |", out
        )
        self.assertIn(
            "| weighted 4x2+4x1 load-factor ratio | 1.01x | 1.01x | |", out
        )


if __name__ == "__main__":
    unittest.main()
