#!/usr/bin/env python3
"""Textual lint gates for the concurrency shim (rust/src/sync/).

Run from the repo root (CI runs it in the lint step):

    python3 tools/lint_sync.py

Three rules, all scoped to `rust/src/**/*.rs`:

1. **Shim boundary** — outside `rust/src/sync/`, no direct textual use
   of `std::sync::atomic`, `std::sync::Mutex` / `RwLock` / `Condvar`,
   or `std::sync::Arc` / `Weak`.  All synchronization imports go
   through `crate::sync`, so that `--features model` substitutes the
   instrumented primitives everywhere at once.  This must be a textual
   check: clippy's `disallowed-types` resolves *through* re-exports,
   so it would flag the shim's own zero-cost `pub use` surface.
   Waive a deliberate exception with a `lint_sync: allow` comment on
   the same line or the two lines above it (used inside the shim's
   normal-build implementation and nowhere else today).

2. **Ordering justification** — every `Ordering::` use must carry an
   `ord:` comment on the same line or within the six lines above it,
   stating the chosen ordering and why it suffices (`// ord: Relaxed —
   independent telemetry counter`, `// ord: test-only`, ...).  The
   memory-ordering table in `rust/src/router/mod.rs` is the index of
   the load-bearing sites.

3. **SAFETY comments** — every `unsafe` keyword must have a `SAFETY:`
   comment on the same line or within the eight lines above it.  This
   duplicates `#![deny(clippy::undocumented_unsafe_blocks)]` for the
   cases that lint does not cover (`unsafe impl`, code behind
   non-default cfg gates that a default clippy run never type-checks).

Lines that are themselves comments never *trigger* a rule (prose may
mention `std::sync::Arc` or `unsafe` freely) but do *satisfy* the
annotation lookbacks.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

SRC = Path("rust/src")
SYNC = SRC / "sync"

BOUNDARY = re.compile(r"std::sync::(atomic|Mutex\b|RwLock\b|Condvar\b|Arc\b|Weak\b)")
ORDERING = re.compile(r"Ordering::")
UNSAFE = re.compile(r"\bunsafe\b")

WAIVER = "lint_sync: allow"
ORD_MARK = "ord:"
SAFETY_MARK = "SAFETY:"

BOUNDARY_LOOKBACK = 2
ORD_LOOKBACK = 6
SAFETY_LOOKBACK = 8


def is_comment(line: str) -> bool:
    return line.lstrip().startswith("//")


def nearby(lines: list[str], idx: int, lookback: int, needle: str) -> bool:
    """Is `needle` on line idx or within `lookback` lines above it?"""
    return any(needle in lines[j] for j in range(max(0, idx - lookback), idx + 1))


def check_file(path: Path) -> list[str]:
    problems: list[str] = []
    lines = path.read_text(encoding="utf-8").split("\n")
    inside_shim = SYNC in path.parents or path.parent == SYNC
    for idx, line in enumerate(lines):
        if is_comment(line):
            continue
        loc = f"{path}:{idx + 1}"
        if not inside_shim and BOUNDARY.search(line):
            if not nearby(lines, idx, BOUNDARY_LOOKBACK, WAIVER):
                problems.append(
                    f"{loc}: direct std::sync use outside the shim — import it "
                    f"from crate::sync instead (or add a `{WAIVER}` comment "
                    f"explaining why the model scheduler must not see this "
                    f"site)\n    {line.strip()}"
                )
        if ORDERING.search(line):
            if not nearby(lines, idx, ORD_LOOKBACK, ORD_MARK):
                problems.append(
                    f"{loc}: Ordering:: use without an `ord:` justification "
                    f"comment (same line or up to {ORD_LOOKBACK} lines above)"
                    f"\n    {line.strip()}"
                )
        if UNSAFE.search(line):
            if not nearby(lines, idx, SAFETY_LOOKBACK, SAFETY_MARK):
                problems.append(
                    f"{loc}: `unsafe` without a `SAFETY:` comment (same line "
                    f"or up to {SAFETY_LOOKBACK} lines above)\n    {line.strip()}"
                )
    return problems


def main() -> int:
    if not SRC.is_dir():
        print(f"lint_sync: {SRC} not found — run from the repo root", file=sys.stderr)
        return 2
    files = sorted(SRC.rglob("*.rs"))
    if not files:
        print(f"lint_sync: no Rust sources under {SRC}", file=sys.stderr)
        return 2
    problems: list[str] = []
    for path in files:
        problems.extend(check_file(path))
    if problems:
        print(f"lint_sync: {len(problems)} problem(s):\n", file=sys.stderr)
        for p in problems:
            print(p, file=sys.stderr)
        return 1
    print(f"lint_sync: OK ({len(files)} files checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
