"""AOT pipeline tests: HLO text artifacts are well-formed and the lowered
graphs execute with the same numerics as the eager path."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

ART_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "..", "artifacts")


def _have_artifacts():
    return os.path.exists(os.path.join(ART_DIR, "manifest.json"))


def test_to_hlo_text_roundtrip(rng):
    """Lower a small lookup graph and sanity-check the emitted HLO text."""
    spec_d = jax.ShapeDtypeStruct((256,), jnp.uint64)
    spec_n = jax.ShapeDtypeStruct((), jnp.uint64)
    lowered = jax.jit(lambda d, n: model.lookup_batch(d, n)).lower(spec_d, spec_n)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "u64[256]" in text
    # Parse it back through the XLA client to prove it is valid HLO text.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_manifest_complete():
    if not _have_artifacts():
        import pytest
        pytest.skip("run `make artifacts` first")
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    for b in aot.BATCH_SIZES:
        assert f"lookup_b{b}" in names
        assert f"migrate_b{b}" in names
    assert f"hist_b{aot.HIST_BATCH}" in names
    for a in manifest["artifacts"]:
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head


def test_lowered_graph_matches_eager(rng):
    """jit-compiled lookup (the exact graph that gets lowered) == eager ref."""
    d = jnp.asarray(rng.integers(0, 2 ** 64, size=4096, dtype=np.uint64))
    n = jnp.uint64(23)
    jitted = jax.jit(lambda dd, nn: model.lookup_batch(dd, nn))
    np.testing.assert_array_equal(
        np.asarray(jitted(d, n)), np.asarray(ref.lookup_ref(d, 23)))


def test_artifact_hlo_stable_under_relower(rng):
    """Re-lowering the same spec yields identical HLO text (deterministic
    build; guards the Makefile's content-based no-op)."""
    spec_d = jax.ShapeDtypeStruct((4096,), jnp.uint64)
    spec_n = jax.ShapeDtypeStruct((), jnp.uint64)
    f = lambda d, n: model.lookup_batch(d, n)  # noqa: E731
    t1 = aot.to_hlo_text(jax.jit(f).lower(spec_d, spec_n))
    t2 = aot.to_hlo_text(jax.jit(f).lower(spec_d, spec_n))
    assert t1 == t2
