"""Shared fixtures: enable x64 before any jax.numpy import."""

import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

# Make `compile.*` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import json  # noqa: E402

import numpy as np  # noqa: E402
import pytest  # noqa: E402

GOLDEN_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "tests", "golden",
    "binomial_golden.json",
)


@pytest.fixture(scope="session")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
