"""Shared fixtures: enable x64 before any jax.numpy import.

Also provides an offline stand-in for `hypothesis` when the real package
is absent (the CI lint job and the offline dev container run this suite
with stdlib + jax only): `@given`/`@settings` over `st.integers` degrade
to seeded random sweeps with the declared `max_examples` budget — the
same sweep style, reproducible, no dependency.
"""

import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import types
    import zlib

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rnd):
            return rnd.randint(self.min_value, self.max_value)

    def _settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            examples = getattr(fn, "_max_examples", 100)
            # Stable per-test seed (hash() is salted per process).
            seed = zlib.crc32(fn.__name__.encode())

            def run():
                rnd = random.Random(seed)
                for _ in range(examples):
                    drawn = {k: s.sample(rnd) for k, s in strategies.items()}
                    fn(**drawn)

            # Keep the collected name/doc, but NOT the wrapped signature
            # (pytest would read the strategy params as fixtures).
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            return run

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _Integers
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

# Make `compile.*` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import json  # noqa: E402

import numpy as np  # noqa: E402
import pytest  # noqa: E402

GOLDEN_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "tests", "golden",
    "binomial_golden.json",
)


@pytest.fixture(scope="session")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
