"""Layer-2 graph tests: migration_plan, balance_histogram, model shapes."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref, scalar_ref as sr


def _digests(rng, size):
    return jnp.asarray(rng.integers(0, 2 ** 64, size=size, dtype=np.uint64))


def test_migration_plan_consistency(rng):
    d = _digests(rng, 2048)
    old, new, moved, count = model.migration_plan(d, 16, 17, block=2048)
    old, new, moved = map(np.asarray, (old, new, moved))
    assert int(count) == int(moved.sum())
    np.testing.assert_array_equal(moved, (old != new).astype(np.uint8))
    # Monotonicity at the batch level: every moved key lands on bucket 16.
    assert (new[moved == 1] == 16).all()
    assert (new[moved == 0] == old[moved == 0]).all()


def test_migration_plan_matches_ref(rng):
    d = _digests(rng, 1024)
    old, new, _, _ = model.migration_plan(d, 9, 12, block=1024)
    np.testing.assert_array_equal(np.asarray(old),
                                  np.asarray(ref.lookup_ref(d, 9)))
    np.testing.assert_array_equal(np.asarray(new),
                                  np.asarray(ref.lookup_ref(d, 12)))


def test_migration_plan_expected_fraction(rng):
    """n -> n+1 should move ~1/(n+1) of the keys (consistent hashing)."""
    d = _digests(rng, 65536)
    _, _, _, count = model.migration_plan(d, 50, 51, block=65536)
    frac = int(count) / 65536
    assert abs(frac - 1 / 51) < 0.01, frac


def test_migration_plan_scale_down_disruption(rng):
    """n+1 -> n: only keys on the removed bucket move."""
    d = _digests(rng, 8192)
    old, new, moved, _ = model.migration_plan(d, 33, 32, block=8192)
    old, new, moved = map(np.asarray, (old, new, moved))
    assert (old[moved == 1] == 32).all()


def test_balance_histogram_counts(rng):
    d = _digests(rng, 65536)
    n = 100
    counts = np.asarray(model.balance_histogram(d, n, block=65536))
    assert counts.shape == (model.HIST_NMAX,)
    assert counts.sum() == 65536
    assert (counts[n:] == 0).all()
    buckets = np.asarray(ref.lookup_ref(d, n))
    want = np.bincount(buckets, minlength=model.HIST_NMAX).astype(np.uint64)
    np.testing.assert_array_equal(counts, want)


def test_balance_histogram_stddev_bound(rng):
    """Empirical relative stddev stays under ~4% at mean=1000 (Fig. 7)."""
    n = 64
    k = n * 1000
    d = _digests(rng, k)
    counts = np.asarray(model.balance_histogram(d, n, block=k))[:n]
    rel_std = counts.std() / counts.mean()
    assert rel_std < 0.06, rel_std


def test_eq6_sigma_max_bound(rng):
    """Eq. 6: at ω=5, σ_max ≈ 0.045·q; measured σ must stay below
    the bound (+ sampling slack) at the maximizing n."""
    omega = 5
    q = 1000
    m = 32
    n = int((2 + omega) / (1 + omega) * m)  # maximizer of Eq. 5
    k = q * n
    rng2 = np.random.default_rng(77)
    d = jnp.asarray(rng2.integers(0, 2 ** 64, size=k, dtype=np.uint64))
    buckets = np.asarray(ref.lookup_ref(d, n, omega=omega))
    counts = np.bincount(buckets, minlength=n)
    sigma_pred = (k / n) * np.sqrt((n - m) / m * ((2 * m - n) / (2 * m)) ** omega)
    sigma_max = q * np.sqrt(1 / (1 + omega) * (omega / (2 * (1 + omega))) ** omega)
    # sampling noise adds ~sqrt(q) per bucket on top of the structural term
    assert counts.std() < sigma_max + 3 * np.sqrt(q), (counts.std(), sigma_max)
    assert sigma_pred <= sigma_max * 1.001


def test_scalar_eq3_closed_form():
    """Eq. 3 algebra: closed form equals the direct probability calc."""
    for n, omega in [(11, 6), (24, 4), (33, 2), (9, 1)]:
        e = sr.next_pow2(n)
        m = e >> 1
        p_level = (n - m) / n * (1 - ((e - n) / e) ** omega)
        k_level = p_level / (n - m)  # per-bucket mass, lowest level
        k_minor = (1 - p_level) / m  # per-bucket mass, minor tree
        gap = (k_minor - k_level) * n
        closed = (1 / 2 ** omega) * (1 + (n - m) / m) * (1 - (n - m) / m) ** omega
        assert abs(gap - closed) < 1e-12, (n, omega, gap, closed)
