"""Property tests for the literal scalar transcription of Alg. 1/2.

These are the *semantic* tests of the paper's claims (§3 consistency
properties, §5 analysis), checked on the specification implementation:

* range          — lookup(h, n) ∈ [0, n)
* determinism    — pure function of (h, n, ω)
* monotonicity   — n → n+1 moves keys only onto the new bucket (§5.2)
* minimal disruption — n+1 → n moves only keys of the removed bucket (§5.3)
* balance        — empirical imbalance within the Eq. 3 bound (§5.4)
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import scalar_ref as sr

U64 = st.integers(min_value=0, max_value=2 ** 64 - 1)


@given(h=U64, n=st.integers(min_value=1, max_value=200000),
       omega=st.integers(min_value=1, max_value=10))
@settings(max_examples=300, deadline=None)
def test_lookup_in_range(h, n, omega):
    b = sr.lookup(h, n, omega)
    assert 0 <= b < n


@given(h=U64, n=st.integers(min_value=1, max_value=5000))
@settings(max_examples=100, deadline=None)
def test_lookup_deterministic(h, n):
    assert sr.lookup(h, n) == sr.lookup(h, n)


@given(h=U64, n=st.integers(min_value=1, max_value=3000))
@settings(max_examples=400, deadline=None)
def test_monotonicity_single_step(h, n):
    """Adding bucket n: a key stays put or moves to the new bucket n."""
    before = sr.lookup(h, n)
    after = sr.lookup(h, n + 1)
    assert after == before or after == n


@given(h=U64, n=st.integers(min_value=2, max_value=3000))
@settings(max_examples=400, deadline=None)
def test_minimal_disruption_single_step(h, n):
    """Removing bucket n-1: only its keys relocate."""
    before = sr.lookup(h, n)
    after = sr.lookup(h, n - 1)
    if before != n - 1:
        assert after == before


def test_monotonicity_full_sweep():
    """Paths of a fixed key set are monotone across n = 1..129 (crosses
    several power-of-two level changes, the tricky case in §5.3)."""
    rng = np.random.default_rng(7)
    digests = rng.integers(0, 2 ** 64, size=500, dtype=np.uint64)
    prev = [sr.lookup(int(h), 1) for h in digests]
    for n in range(2, 130):
        cur = [sr.lookup(int(h), n) for h in digests]
        for b0, b1 in zip(prev, cur):
            assert b1 == b0 or b1 == n - 1, (n, b0, b1)
        prev = cur


def test_power_of_two_boundary_disruption():
    """n = M+1 -> M removes the whole lowest level (Fig. 4 scenario):
    keys on buckets [0, M) must not move."""
    rng = np.random.default_rng(11)
    digests = rng.integers(0, 2 ** 64, size=2000, dtype=np.uint64)
    for m in (2, 4, 8, 16, 64, 256):
        for h in digests[:500]:
            before = sr.lookup(int(h), m + 1)
            after = sr.lookup(int(h), m)
            if before != m:
                assert after == before, (m, before, after)


def test_balance_eq3_bound():
    """Empirical relative gap between minor-tree and lowest-level buckets
    stays within ~the Eq. 3 closed form (sampling tolerance 3 sigma)."""
    rng = np.random.default_rng(3)
    k = 200000
    digests = rng.integers(0, 2 ** 64, size=k, dtype=np.uint64)
    for n, omega in [(11, 6), (24, 6), (11, 3), (48, 4)]:
        e = sr.next_pow2(n)
        m = e >> 1
        counts = np.zeros(n, dtype=np.int64)
        for h in digests:
            counts[sr.lookup(int(h), n, omega)] += 1
        k_minor = counts[:m].mean()
        k_level = counts[m:].mean()
        gap = (k_minor - k_level) / (k / n)
        bound = (1 / 2 ** omega) * (1 + (n - m) / m) * ((1 - (n - m) / m) ** omega)
        # gap must be positive-ish (imbalance towards the minor tree) and
        # within the bound plus sampling noise.
        sigma_noise = 3 * np.sqrt(n / k)
        assert gap <= bound + sigma_noise, (n, omega, gap, bound)


def test_balance_uniformity_chi2():
    """Gross balance: no bucket deviates wildly from k/n."""
    rng = np.random.default_rng(5)
    k = 100000
    digests = rng.integers(0, 2 ** 64, size=k, dtype=np.uint64)
    for n in (10, 31, 64, 100):
        counts = np.zeros(n, dtype=np.int64)
        for h in digests:
            counts[sr.lookup(int(h), n)] += 1
        rel = counts / (k / n)
        assert rel.min() > 0.80 and rel.max() < 1.25, (n, rel.min(), rel.max())


def test_golden_self_consistency(golden):
    """The checked-in golden file matches the current scalar reference."""
    for case in golden["lookup"]:
        n, omega = case["n"], case["omega"]
        for h_str, want in zip(case["digests"], case["buckets"]):
            assert sr.lookup(int(h_str), n, omega) == want


def test_golden_primitives(golden):
    p = golden["primitives"]
    for rec in p["splitmix64_fin"]:
        assert sr.splitmix64_fin(int(rec["in"])) == int(rec["out"])
    for rec in p["next_hash"]:
        assert sr.next_hash(int(rec["in"])) == int(rec["out"])
    for rec in p["hash2"]:
        assert sr.hash2(int(rec["h"]), rec["f"]) == int(rec["out"])
    for rec in p["relocate"]:
        assert sr.relocate_within_level(rec["b"], int(rec["h"])) == rec["out"]


def test_relocate_stays_in_level():
    """Alg. 2 invariant: the relocated bucket has the same depth as b."""
    rng = np.random.default_rng(9)
    for _ in range(2000):
        b = int(rng.integers(2, 2 ** 32, dtype=np.uint64))
        h = int(rng.integers(0, 2 ** 64, dtype=np.uint64))
        c = sr.relocate_within_level(b, h)
        assert sr.highest_one_bit_index(c) == sr.highest_one_bit_index(b)


def test_relocate_uniform_within_level():
    """Keys relocated from one bucket spread uniformly across its level."""
    d = 6  # level with 64 nodes: [64, 127]
    b = 77
    counts = np.zeros(64, dtype=np.int64)
    rng = np.random.default_rng(13)
    trials = 64000
    for _ in range(trials):
        h = int(rng.integers(0, 2 ** 64, dtype=np.uint64))
        c = sr.relocate_within_level(b, h)
        assert 64 <= c < 128
        counts[c - 64] += 1
    rel = counts / (trials / 64)
    assert rel.min() > 0.75 and rel.max() < 1.3


def test_intrinsic_imbalance_decreases_with_omega():
    """§4.4: unbalanced key fraction < 1/2^ω — larger ω, smaller gap."""
    rng = np.random.default_rng(17)
    k = 120000
    digests = rng.integers(0, 2 ** 64, size=k, dtype=np.uint64)
    n = 11
    m = 8
    gaps = []
    for omega in (1, 3, 6):
        counts = np.zeros(n, dtype=np.int64)
        for h in digests:
            counts[sr.lookup(int(h), n, omega)] += 1
        gaps.append((counts[:m].mean() - counts[m:].mean()) / (k / n))
    assert gaps[0] > gaps[1] > gaps[2] - 0.02  # decreasing (noise slack)
