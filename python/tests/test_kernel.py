"""Pallas kernel vs references — the CORE correctness signal.

Three-way parity: scalar transcription (spec) == jnp reference == Pallas
kernel, bit-for-bit, across hypothesis-driven shape/n/ω sweeps, golden
vectors, block-size variations, and adversarial digests.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import binomial, ref, scalar_ref as sr


def _digests(rng, size):
    return rng.integers(0, 2 ** 64, size=size, dtype=np.uint64)


# ---------------------------------------------------------------- jnp ref

@given(n=st.integers(min_value=1, max_value=300000),
       omega=st.integers(min_value=1, max_value=8),
       seed=st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=60, deadline=None)
def test_ref_matches_scalar(n, omega, seed):
    rng = np.random.default_rng(seed)
    d = _digests(rng, 64)
    want = np.array([sr.lookup(int(h), n, omega) for h in d], dtype=np.uint32)
    got = np.asarray(ref.lookup_ref(jnp.asarray(d), n, omega=omega))
    np.testing.assert_array_equal(want, got)


def test_ref_edge_digests():
    edges = np.array([0, 1, 2, 2 ** 63, 2 ** 64 - 1, sr.PHI64], dtype=np.uint64)
    for n in (1, 2, 3, 8, 9, 1024, 1025):
        want = np.array([sr.lookup(int(h), n) for h in edges], dtype=np.uint32)
        got = np.asarray(ref.lookup_ref(jnp.asarray(edges), n))
        np.testing.assert_array_equal(want, got)


# ------------------------------------------------------------- pallas

@given(n=st.integers(min_value=1, max_value=300000),
       omega=st.integers(min_value=1, max_value=8),
       batch_pow=st.integers(min_value=4, max_value=10),
       seed=st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=40, deadline=None)
def test_pallas_matches_ref_shapes(n, omega, batch_pow, seed):
    """Hypothesis sweep over batch sizes (16..1024) and cluster sizes."""
    rng = np.random.default_rng(seed)
    d = jnp.asarray(_digests(rng, 2 ** batch_pow))
    want = np.asarray(ref.lookup_ref(d, n, omega=omega))
    got = np.asarray(binomial.lookup_pallas(d, n, omega=omega, block=2 ** 4))
    np.testing.assert_array_equal(want, got)


def test_pallas_block_size_invariance(rng):
    """Result must not depend on the BlockSpec tiling."""
    d = jnp.asarray(_digests(rng, 1024))
    base = np.asarray(binomial.lookup_pallas(d, 37, block=1024))
    for block in (16, 64, 128, 256, 512):
        got = np.asarray(binomial.lookup_pallas(d, 37, block=block))
        np.testing.assert_array_equal(base, got)


def test_pallas_ragged_batch_fallback(rng):
    """Batch not divisible by block: single-block fallback still correct."""
    d = jnp.asarray(_digests(rng, 1000))  # not divisible by 8192
    want = np.asarray(ref.lookup_ref(d, 99))
    got = np.asarray(binomial.lookup_pallas(d, 99))
    np.testing.assert_array_equal(want, got)


def test_pallas_golden(golden):
    """Pallas kernel reproduces the checked-in cross-language vectors."""
    for case in golden["lookup"]:
        d = jnp.asarray(np.array([int(s) for s in case["digests"]],
                                 dtype=np.uint64))
        got = np.asarray(
            binomial.lookup_pallas(d, case["n"], omega=case["omega"]))
        np.testing.assert_array_equal(
            np.array(case["buckets"], dtype=np.uint32), got,
            err_msg=f"n={case['n']} omega={case['omega']}")


def test_pallas_n_one_all_zero(rng):
    d = jnp.asarray(_digests(rng, 256))
    got = np.asarray(binomial.lookup_pallas(d, 1, block=256))
    assert (got == 0).all()


def test_pallas_range_large_n(rng):
    d = jnp.asarray(_digests(rng, 4096))
    for n in (10, 1000, 100000, 2 ** 20 + 3):
        got = np.asarray(binomial.lookup_pallas(d, n, block=4096))
        assert got.max() < n


# ------------------------------------------------- primitive parity

@given(seed=st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=30, deadline=None)
def test_splitmix_parity(seed):
    rng = np.random.default_rng(seed)
    z = _digests(rng, 32)
    want = np.array([sr.splitmix64_fin(int(x)) for x in z], dtype=np.uint64)
    got = np.asarray(ref.splitmix64_fin(jnp.asarray(z)))
    np.testing.assert_array_equal(want, got)


@given(seed=st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=30, deadline=None)
def test_relocate_parity(seed):
    rng = np.random.default_rng(seed)
    b = rng.integers(0, 2 ** 32, size=32, dtype=np.uint64)
    h = _digests(rng, 32)
    want = np.array([sr.relocate_within_level(int(bb), int(hh))
                     for bb, hh in zip(b, h)], dtype=np.uint64)
    got = np.asarray(ref.relocate_within_level(jnp.asarray(b), jnp.asarray(h)))
    np.testing.assert_array_equal(want, got)


def test_next_pow2_parity():
    ns = np.array([1, 2, 3, 4, 5, 7, 8, 9, 1023, 1024, 1025, 2 ** 31],
                  dtype=np.uint64)
    want = np.array([sr.next_pow2(int(x)) for x in ns], dtype=np.uint64)
    got = np.asarray(ref.next_pow2(jnp.asarray(ns)))
    np.testing.assert_array_equal(want, got)
