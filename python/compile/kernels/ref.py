"""Pure-jnp vectorized oracle for the BinomialHash lookup.

Branch-free reformulation of ``scalar_ref.lookup``: the ω-round loop is
unrolled and per-lane control flow becomes ``jnp.where`` selects.  This is
the correctness oracle the Pallas kernel (``binomial.py``) is tested
against, and it is itself tested bit-for-bit against the literal scalar
transcription in ``scalar_ref.py``.

Requires ``jax_enable_x64`` (u64 lattice arithmetic); ``model.py`` and the
test suite enable it before importing jax.numpy.
"""

import jax.numpy as jnp

# Python-int constants: materialized with jnp.uint64(...) inside each
# function so Pallas kernels don't capture them as closure constants.
PHI64 = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB

DEFAULT_OMEGA = 6


def splitmix64_fin(z):
    """splitmix64 finalizer, elementwise over u64 lanes (wrapping)."""
    z = z.astype(jnp.uint64)
    z = z ^ (z >> jnp.uint64(30))
    z = z * jnp.uint64(_MIX1)
    z = z ^ (z >> jnp.uint64(27))
    z = z * jnp.uint64(_MIX2)
    z = z ^ (z >> jnp.uint64(31))
    return z


def next_hash(h):
    """Rehash stream: h_{i+1} = fin(h_i + PHI64)."""
    return splitmix64_fin(h + jnp.uint64(PHI64))


def hash2(h, f):
    """Seeded hash of Alg. 2 line 7 (f is the level mask, u64 lanes)."""
    return splitmix64_fin(h ^ (f * jnp.uint64(PHI64)))


def smear(x):
    """Propagate the highest set bit downward: smear(b) = 2^(d+1) - 1."""
    x = x.astype(jnp.uint64)
    for s in (1, 2, 4, 8, 16, 32):
        x = x | (x >> jnp.uint64(s))
    return x


def relocate_within_level(b, h):
    """Vectorized Algorithm 2.

    ``f = smear(b) >> 1`` equals ``2^d - 1`` for b >= 2 (and 0 for b in
    {0, 1}), so the b < 2 early-return folds into a single select.
    """
    b = b.astype(jnp.uint64)
    f = smear(b) >> jnp.uint64(1)  # 2^d - 1  (0 when b < 2)
    i = hash2(h, f) & f
    relocated = (f + jnp.uint64(1)) + i
    return jnp.where(b < jnp.uint64(2), b, relocated)


def next_pow2(n):
    """Smallest power of two >= n, n >= 1 (u64)."""
    n = n.astype(jnp.uint64)
    return smear(n - jnp.uint64(1)) + jnp.uint64(1)


def lookup_ref(digests, n, omega=DEFAULT_OMEGA):
    """Vectorized Algorithm 1 over a batch of u64 digests.

    Args:
      digests: u64[B] array of key digests (``hash(key)``).
      n: scalar cluster size (python int or u64 scalar array), n >= 1.
      omega: unroll depth ω (compile-time constant).

    Returns:
      u32[B] buckets, each in ``[0, n)``.
    """
    h0 = digests.astype(jnp.uint64)
    n = jnp.asarray(n, dtype=jnp.uint64)
    e = next_pow2(jnp.maximum(n, jnp.uint64(2)))
    m = e >> jnp.uint64(1)

    # Block A / C result: congruent remap of the ORIGINAL digest against
    # the minor tree, then relocate within its level (Alg. 1 lines 7-8/15-16).
    d = h0 & (m - jnp.uint64(1))
    minor = relocate_within_level(d, h0)

    done = jnp.zeros(h0.shape, dtype=bool)
    res = jnp.zeros(h0.shape, dtype=jnp.uint64)
    hi = h0
    for _ in range(omega):
        b = hi & (e - jnp.uint64(1))  # line 4
        c = relocate_within_level(b, hi)  # line 5
        in_a = c < m  # block A
        in_b = jnp.logical_and(c >= m, c < n)  # block B
        hit = jnp.logical_and(jnp.logical_not(done), jnp.logical_or(in_a, in_b))
        res = jnp.where(hit, jnp.where(in_a, minor, c), res)
        done = jnp.logical_or(done, hit)
        hi = next_hash(hi)  # line 13
    res = jnp.where(done, res, minor)  # block C
    res = jnp.where(n <= jnp.uint64(1), jnp.uint64(0), res)
    return res.astype(jnp.uint32)
