"""L1 kernels: Pallas BinomialHash lookup + pure references."""
