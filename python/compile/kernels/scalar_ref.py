"""Literal, line-by-line scalar transcription of the paper's Algorithms 1 & 2.

This is the *specification* implementation: every other implementation in
this repository (the vectorized jnp reference in ``ref.py``, the Pallas
kernel in ``binomial.py``, and the Rust ``algorithms::binomial`` module)
must agree with it bit-for-bit.  Golden vectors for the cross-language
parity tests are generated from this file (see ``gen_golden.py``).

Hash-function contract (DESIGN.md §2):

* ``PHI64``           — the 64-bit golden ratio, splitmix64's increment.
* ``splitmix64_fin``  — splitmix64's finalizer, used as the universal mixer.
* rehash stream       — ``h_{i+1} = splitmix64_fin(h_i + PHI64)`` realises
  the paper's family of independent hash functions ``hash^{i+1}(key)``.
* ``hash2(h, f)``     — the seeded hash of Alg. 2 line 7:
  ``splitmix64_fin(h ^ (f * PHI64))``.

All arithmetic is modulo 2**64 (wrapping), mirroring u64 in Rust.
"""

MASK64 = (1 << 64) - 1
PHI64 = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB


def splitmix64_fin(z: int) -> int:
    """splitmix64 finalizer (Steele et al.); bijective mixer on u64."""
    z &= MASK64
    z ^= z >> 30
    z = (z * _MIX1) & MASK64
    z ^= z >> 27
    z = (z * _MIX2) & MASK64
    z ^= z >> 31
    return z


def next_hash(h: int) -> int:
    """The paper's ``hash^{i+1}(key)`` rehash stream (Alg. 1 line 13)."""
    return splitmix64_fin((h + PHI64) & MASK64)


def hash2(h: int, f: int) -> int:
    """Seeded hash of Alg. 2 line 7: ``r <- hash(h, f)``."""
    return splitmix64_fin(h ^ ((f * PHI64) & MASK64))


def highest_one_bit_index(b: int) -> int:
    """Index of the highest set bit (Alg. 2 line 5); b must be >= 1."""
    assert b >= 1
    return b.bit_length() - 1


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (capacity E of the enclosing tree)."""
    assert n >= 1
    return 1 << (n - 1).bit_length() if n > 1 else 1


def relocate_within_level(b: int, h: int) -> int:
    """Algorithm 2: uniformly relocate bucket ``b`` within its tree level.

    Level 0 (bucket 0) and level 1 (bucket 1) hold a single node each and
    are returned unmodified.  Otherwise ``d`` is the depth of ``b``,
    ``f = 2^d - 1`` masks a uniform offset within the level, and the
    relocated bucket is ``2^d + i``.
    """
    if b < 2:
        return b
    d = highest_one_bit_index(b)
    f = (1 << d) - 1
    r = hash2(h, f)
    i = r & f
    return (1 << d) + i


def lookup(h0: int, n: int, omega: int = 6) -> int:
    """Algorithm 1: map digest ``h0`` to a bucket in ``[0, n)``.

    ``h0`` plays the role of ``hash(key)`` (the caller hashes the key; the
    benchmark path feeds uniform u64 digests directly, as in the paper).
    """
    assert n >= 1
    if n == 1:
        return 0
    h0 &= MASK64
    e = next_pow2(n)  # capacity E of the enclosing tree
    m = e >> 1  # capacity M of the minor tree
    h = h0
    hi = h0
    for _ in range(omega):
        b = hi & (e - 1)  # line 4
        c = relocate_within_level(b, hi)  # line 5
        if c < m:  # block A
            d = h & (m - 1)
            return relocate_within_level(d, h)
        if c < n:  # block B
            return c
        hi = next_hash(hi)  # line 13
    d = h & (m - 1)  # block C
    return relocate_within_level(d, h)
