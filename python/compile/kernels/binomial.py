"""Pallas kernel for batched BinomialHash lookup (Layer 1).

The paper's hot-spot — Algorithm 1 + Algorithm 2 over a stream of u64
digests — expressed as a Pallas kernel so the HBM→VMEM schedule is
explicit (BlockSpec tiles the digest stream in ``block`` sized chunks; one
grid step per chunk).  The body is branch-free straight-line integer
vector code: ω unrolled rehash rounds, each ~30 elementwise u64 ops,
resolved by selects — pure VPU work on a real TPU, no MXU, no cross-lane
traffic (see DESIGN.md §Hardware-Adaptation).

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust PJRT CPU client.

The cluster size ``n`` is a runtime input (shape ``(1,)`` u64) so one AOT
artifact serves every cluster size; ω is a compile-time constant.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 8192


def _lookup_kernel(n_ref, h_ref, o_ref, *, omega):
    """Kernel body: one VMEM block of digests -> one block of buckets."""
    h0 = h_ref[...]
    n = n_ref[0]
    e = ref.next_pow2(jnp.maximum(n, jnp.uint64(2)))
    m = e >> jnp.uint64(1)

    # Minor-tree fallback (blocks A and C use the ORIGINAL digest h0).
    d = h0 & (m - jnp.uint64(1))
    minor = ref.relocate_within_level(d, h0)

    done = jnp.zeros(h0.shape, dtype=bool)
    res = jnp.zeros(h0.shape, dtype=jnp.uint64)
    hi = h0
    for _ in range(omega):
        b = hi & (e - jnp.uint64(1))
        c = ref.relocate_within_level(b, hi)
        in_a = c < m
        in_b = jnp.logical_and(c >= m, c < n)
        hit = jnp.logical_and(jnp.logical_not(done), jnp.logical_or(in_a, in_b))
        res = jnp.where(hit, jnp.where(in_a, minor, c), res)
        done = jnp.logical_or(done, hit)
        hi = ref.next_hash(hi)
    res = jnp.where(done, res, minor)
    res = jnp.where(n <= jnp.uint64(1), jnp.uint64(0), res)
    o_ref[...] = res.astype(jnp.uint32)


def lookup_pallas(digests, n, omega=ref.DEFAULT_OMEGA, block=DEFAULT_BLOCK):
    """Batched BinomialHash lookup via pallas_call.

    Args:
      digests: u64[B]; B must be a multiple of ``block`` (the AOT driver
        pads; the convenience wrapper below handles ragged batches).
      n: scalar or (1,) u64 cluster size.
      omega: unroll depth (compile-time).
      block: digests per grid step (VMEM tile: block*8 bytes in, block*4
        out — 8192 → 96 KiB/step incl. double-buffering headroom).

    Returns: u32[B] buckets in [0, n).
    """
    (b_total,) = digests.shape
    if b_total % block != 0:
        block = b_total  # single-block fallback for ragged sizes
    n_arr = jnp.asarray(n, dtype=jnp.uint64).reshape((1,))
    grid = (b_total // block,)
    return pl.pallas_call(
        functools.partial(_lookup_kernel, omega=omega),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # n: broadcast to every step
            pl.BlockSpec((block,), lambda i: (i,)),  # digest tile
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_total,), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(n_arr, digests.astype(jnp.uint64))
