"""Build-time compile path: L2 model graphs + L1 Pallas kernels + AOT."""
