"""Layer 2 — JAX compute graphs for the BinomialHash placement engine.

These functions are the graphs the Rust coordinator executes through PJRT
after ``aot.py`` lowers them to HLO text.  They compose the Layer-1 Pallas
kernel (``kernels.binomial``) into the bulk operations the rebalancer
needs:

* ``lookup_batch``     — place a batch of digests on an n-node cluster.
* ``migration_plan``   — old/new placement + moved mask for a topology
                         change (the rebalance planner's inner product).
* ``balance_histogram``— per-bucket key counts for balance telemetry.

All graphs take the cluster size(s) as *runtime* scalar inputs so a single
AOT artifact serves every topology; only the batch size and ω are baked in
at lowering time.
"""

import jax

jax.config.update("jax_enable_x64", True)  # u64 digest arithmetic

import jax.numpy as jnp  # noqa: E402

from .kernels import binomial, ref  # noqa: E402

DEFAULT_OMEGA = ref.DEFAULT_OMEGA
# Maximum cluster size the histogram artifact supports (fixed output shape).
HIST_NMAX = 1024


def lookup_batch(digests, n, omega=DEFAULT_OMEGA, block=binomial.DEFAULT_BLOCK):
    """u64[B] digests, scalar u64 n  ->  u32[B] buckets (Pallas kernel)."""
    return binomial.lookup_pallas(digests, n, omega=omega, block=block)


def migration_plan(digests, n_old, n_new, omega=DEFAULT_OMEGA,
                   block=binomial.DEFAULT_BLOCK):
    """Placement under two topologies plus the moved mask.

    Returns ``(old u32[B], new u32[B], moved u8[B], moved_count u64)``.
    XLA fuses the two kernel invocations' surrounding element-wise work;
    the moved count is reduced on-device so the coordinator reads back a
    scalar when it only needs the movement fraction.
    """
    old = binomial.lookup_pallas(digests, n_old, omega=omega, block=block)
    new = binomial.lookup_pallas(digests, n_new, omega=omega, block=block)
    moved = (old != new).astype(jnp.uint8)
    moved_count = moved.astype(jnp.uint64).sum()
    return old, new, moved, moved_count


def balance_histogram(digests, n, omega=DEFAULT_OMEGA,
                      block=binomial.DEFAULT_BLOCK, nmax=HIST_NMAX):
    """Per-bucket key counts: u64[nmax] (entries >= n are zero)."""
    buckets = binomial.lookup_pallas(digests, n, omega=omega, block=block)
    counts = jnp.zeros((nmax,), dtype=jnp.uint64).at[buckets].add(
        jnp.uint64(1), mode="drop"
    )
    return counts
