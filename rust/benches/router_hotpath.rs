//! Router hot-path bench: end-to-end in-process request latency
//! (placement + shard dispatch) and raw placement cost, measuring what the
//! paper's constant-time claim buys the *system* (L3 target: placement is
//! never the router bottleneck).
//!
//! Five phases per cluster size: PUT, GET, batched MGET/MPUT (batch
//! sizes 1/8/64, reported as ns per *key* and keys/s — the number the
//! batched data plane exists to move), GET-under-churn, and
//! GET-while-failed-over.  Churn hammers reads while a background admin
//! thread cycles scale-up/scale-down, so it prices the epoch-snapshot
//! design (readers never block on a migration; mid-migration keys cost
//! one extra hop via dual-read).  The failover phase runs on a memento
//! cluster (the fault-tolerant wrapper the paper's §7 points to) with
//! one shard failed: it prices the degraded data path — the replacement
//! chain walk, the `is_failed` guard, and the marooned-key
//! `UNAVAILABLE` short-circuit that answers instead of dialing a dead
//! shard — reporting p50/p99 alongside ns/op.  The driver goes through
//! `Router::handle_ref` with borrowed keys and `Arc` values — the same
//! allocation-free path the servers use.
//!
//! A standalone `placement_batch` phase prices the batched placement
//! kernel itself: scalar `bucket` vs lane-parallel `bucket_batch`
//! ns/key over the same digests at batch 64 / 1k / 64k (binomial,
//! n = 16), so the kernel's speedup is tracked release over release.
//!
//! A standalone replication phase (memento, n = 16, `factor = 2`)
//! prices what a second copy costs each op: PUT with its replica
//! fan-out, steady GET (unchanged path — replicas cost writes, not
//! healthy reads), degraded GET served via surviving replicas
//! (p50/p99, zero UNAVAILABLE expected), and the anti-entropy RESTORE
//! (digest round-trips + skipped stripe scans from the router's
//! metrics).
//!
//! A Zipfian hot-key phase (theta 0.99) prices the router's hot-key
//! cache: GET ns/op with the cache on vs off over the same skewed key
//! stream, plus a 2:1 heterogeneous-weight `Weighted` cluster whose
//! measured per-shard load factor is reported beside the paper's
//! Eq. (3) relative-imbalance bound.
//!
//! Custom harness (`harness = false`): ops/s + ns/op over seeded key sets,
//! printed human-readably *and* written as `BENCH_router.json` (override
//! the path with `BENCH_OUT`) — CI uploads the JSON so the perf
//! trajectory is tracked release over release.

use std::fmt::Write as _;
use std::hint::black_box;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use binhash::metrics::LatencyHistogram;
use binhash::proto::{Request, RequestRef, Response, Value};
use binhash::router::{local_cluster, BatchScratch, Router};
use binhash::workload::StringKeys;

const OPS: usize = 200_000;

fn ns_op(d: Duration, ops: usize) -> f64 {
    d.as_nanos() as f64 / ops as f64
}

/// One `{"ns_op": ..., "ops_per_sec": ...}` JSON object.
fn op_json(ns: f64) -> String {
    format!("{{\"ns_op\": {ns:.1}, \"ops_per_sec\": {:.0}}}", 1e9 / ns)
}

fn main() {
    let mut clusters_json = Vec::new();
    for n in [4u32, 16, 64] {
        let router = Router::new(local_cluster("binomial", n).unwrap());
        let mut gen = StringKeys::new(7, 8, 32);
        let keys: Vec<String> = (0..OPS).map(|_| gen.next_key()).collect();
        let values: Vec<Value> =
            (0..256).map(|i| vec![i as u8; 32].into()).collect();

        // PUT phase (first insert per key allocates its map entry;
        // repeats of hot keys overwrite in place).
        let t0 = Instant::now();
        for (i, k) in keys.iter().enumerate() {
            let r = router
                .handle_ref(RequestRef::Put { key: k, value: values[i & 0xFF].clone() });
            black_box(r);
        }
        let put = t0.elapsed();

        // GET phase (steady topology).
        let t0 = Instant::now();
        for k in &keys {
            let r = router.handle_ref(RequestRef::Get { key: k });
            black_box(r);
        }
        let get = t0.elapsed();

        // Batch phase (steady topology): MGET/MPUT keybatches through
        // `handle_batch` with reused scratch — the per-connection server
        // path.  ns per key, so batch=1 prices the batch machinery's
        // overhead and batch=64 its amortization against the singleton
        // GET above.
        let mut batch_json = Vec::new();
        let mut mget64_ns = f64::NAN;
        for bs in [1usize, 8, 64] {
            let mut scratch = BatchScratch::new();
            let mut out = Vec::new();
            let mget_reqs: Vec<Request> = keys
                .chunks(bs)
                .map(|c| Request::MGet { keys: c.to_vec() })
                .collect();
            let mput_reqs: Vec<Request> = keys
                .chunks(bs)
                .map(|c| Request::MPut {
                    keys: c.to_vec(),
                    values: (0..c.len()).map(|j| values[j & 0xFF].clone()).collect(),
                })
                .collect();

            let t0 = Instant::now();
            for req in &mget_reqs {
                let (op, batch) = req.as_view().into_batch().unwrap();
                router.handle_batch(op, &batch, &mut scratch, &mut out);
                black_box(&out);
            }
            let mget_ns_key = ns_op(t0.elapsed(), OPS);
            if bs == 64 {
                mget64_ns = mget_ns_key;
            }

            let t0 = Instant::now();
            for req in &mput_reqs {
                let (op, batch) = req.as_view().into_batch().unwrap();
                router.handle_batch(op, &batch, &mut scratch, &mut out);
                black_box(&out);
            }
            let mput_ns_key = ns_op(t0.elapsed(), OPS);

            println!(
                "      batch={bs:<3} mget: {mget_ns_key:>8.0} ns/key ({:>9.0} keys/s)   \
                 mput: {mput_ns_key:>8.0} ns/key ({:>9.0} keys/s)",
                1e9 / mget_ns_key,
                1e9 / mput_ns_key,
            );
            let mut b = String::new();
            write!(
                b,
                "{{\"batch\": {bs}, \
                 \"mget\": {{\"ns_key\": {mget_ns_key:.1}, \"keys_per_sec\": {:.0}}}, \
                 \"mput\": {{\"ns_key\": {mput_ns_key:.1}, \"keys_per_sec\": {:.0}}}}}",
                1e9 / mget_ns_key,
                1e9 / mput_ns_key,
            )
            .expect("write to String");
            batch_json.push(b);
        }
        // keys/s of MGET@64 over the singleton GET phase — the
        // batched-data-plane acceptance ratio (≥2× expected).
        let batch_speedup = ns_op(get, OPS) / mget64_ns;
        println!("      mget@64 speedup over singleton GET: {batch_speedup:.2}x");

        // GET phase under topology churn: a background thread cycles
        // scale-up/scale-down while this thread keeps reading.
        let stop = Arc::new(AtomicBool::new(false));
        let admin = {
            let router = router.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut cycles = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    router.scale_up().expect("scale_up");
                    router.scale_down().expect("scale_down");
                    cycles += 1;
                }
                cycles
            })
        };
        let t0 = Instant::now();
        for k in &keys {
            let r = router.handle_ref(RequestRef::Get { key: k });
            black_box(r);
        }
        let churn = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        let cycles = admin.join().expect("admin thread");

        // Failover phase: a memento cluster of the same size with one
        // shard failed.  GETs split into survivor hits (priced per-op
        // with p50/p99) and marooned UNAVAILABLE answers (counted — they
        // must short-circuit, not dial a dead shard).
        let fo_router = Router::new(local_cluster("memento", n).unwrap());
        for (i, k) in keys.iter().enumerate() {
            let r = fo_router
                .handle_ref(RequestRef::Put { key: k, value: values[i & 0xFF].clone() });
            black_box(r);
        }
        fo_router.fail_shard(n / 2).expect("fail_shard");
        // ns/op from a bare loop, exactly like the steady/churn phases —
        // comparing the JSON numbers must price the degraded path, not
        // per-op instrumentation overhead.
        let t0 = Instant::now();
        for k in &keys {
            let r = fo_router.handle_ref(RequestRef::Get { key: k });
            black_box(r);
        }
        let failover = t0.elapsed();
        // Separate instrumented pass for the tail percentiles and the
        // marooned count.
        let fo_hist = LatencyHistogram::new();
        let mut fo_unavailable = 0u64;
        for k in &keys {
            let t1 = Instant::now();
            let r = fo_router.handle_ref(RequestRef::Get { key: k });
            fo_hist.record(t1.elapsed());
            if matches!(r, Response::Err(_)) {
                fo_unavailable += 1;
            }
            black_box(r);
        }

        let put_ns = ns_op(put, OPS);
        let get_ns = ns_op(get, OPS);
        let churn_ns = ns_op(churn, OPS);
        let dual_reads = router.metrics.dual_reads.load(Ordering::Relaxed);
        let batches = router.metrics.migration_batches.load(Ordering::Relaxed);
        let place_p50 = router.metrics.placement_latency.quantile_ns(0.5);
        let place_p99 = router.metrics.placement_latency.quantile_ns(0.99);
        let place_mean = router.metrics.placement_latency.mean_ns();
        println!(
            "n={n:<4} put: {put_ns:>8.0} ns/op ({:>9.0} op/s)   get: {get_ns:>8.0} ns/op ({:>9.0} op/s)",
            1e9 / put_ns,
            1e9 / get_ns
        );
        println!(
            "      get under churn: {churn_ns:>8.0} ns/op ({:>9.0} op/s) across {cycles} scale cycles, \
             {dual_reads} dual-reads, {batches} migration batches",
            1e9 / churn_ns,
        );
        println!(
            "      placement p50={place_p50}ns p99={place_p99}ns mean={place_mean:.0}ns  \
             (of end-to-end mean {:.0}ns)",
            router.metrics.latency.mean_ns(),
        );
        let failover_ns = ns_op(failover, OPS);
        let fo_p50 = fo_hist.quantile_ns(0.5);
        let fo_p99 = fo_hist.quantile_ns(0.99);
        println!(
            "      get while failed over (memento, 1/{n} shards down): \
             {failover_ns:>8.0} ns/op ({:>9.0} op/s)  p50={fo_p50}ns p99={fo_p99}ns  \
             {fo_unavailable} marooned keys answered UNAVAILABLE",
            1e9 / failover_ns,
        );

        let mut c = String::new();
        write!(
            c,
            "    {{\"n\": {n}, \
             \"steady\": {{\"put\": {}, \"get\": {}}}, \
             \"batch\": {{\"sizes\": [{}], \"mget64_vs_get\": {batch_speedup:.2}}}, \
             \"churn\": {{\"get\": {}, \"scale_cycles\": {cycles}, \
             \"dual_reads\": {dual_reads}, \"migration_batches\": {batches}}}, \
             \"failover\": {{\"get\": {}, \"engine\": \"memento\", \
             \"failed_shards\": 1, \"p50\": {fo_p50}, \"p99\": {fo_p99}, \
             \"unavailable\": {fo_unavailable}}}, \
             \"placement_ns\": {{\"p50\": {place_p50}, \"p99\": {place_p99}, \
             \"mean\": {place_mean:.1}}}}}",
            op_json(put_ns),
            op_json(get_ns),
            batch_json.join(", "),
            op_json(churn_ns),
            op_json(failover_ns),
        )
        .expect("write to String");
        clusters_json.push(c);
    }

    let placement_batch = placement_batch_json();
    let replication = replication_json();
    let zipf = zipf_json();
    let fanin = fanin_json();
    let json = format!(
        "{{\n  \"bench\": \"router_hotpath\",\n  \"ops_per_phase\": {OPS},\n  \
         \"clusters\": [\n{}\n  ],\n  \"placement_batch\": {placement_batch},\n  \
         \"replication\": {replication},\n  \
         \"zipf\": {zipf},\n  \"fanin\": {fanin}\n}}\n",
        clusters_json.join(",\n")
    );
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_router.json".to_string());
    std::fs::write(&out, &json).expect("write bench JSON");
    println!("wrote {out}");
}

/// Batched-placement phase: the binomial engine's scalar `bucket` loop
/// vs the lane-parallel `bucket_batch` kernel over the same digests, at
/// batch 64 / 1k / 64k — the in-process twin of the `perf_variants`
/// table, carried in the JSON so the kernel's per-release speedup is
/// diffed by `tools/bench_compare.py`.  Returns the phase's JSON object.
fn placement_batch_json() -> String {
    use binhash::algorithms::binomial::BinomialHash;
    use binhash::algorithms::ConsistentHasher;
    use binhash::workload::UniformDigests;

    const N: u32 = 16;
    const TOTAL: usize = 1 << 18;
    const REPS: usize = 5;
    let digests = UniformDigests::new(0xBA7C).take_vec(TOTAL);
    let engine = BinomialHash::new(N);
    let median = |mut xs: Vec<f64>| -> f64 {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };
    let mut sizes_json = Vec::new();
    for batch in [64usize, 1_024, 65_536] {
        let keys = (TOTAL / batch) * batch;
        let mut out = vec![0u32; batch];
        let mut scalar_reps = Vec::with_capacity(REPS);
        let mut batched_reps = Vec::with_capacity(REPS);
        for _ in 0..REPS {
            let t0 = Instant::now();
            for chunk in digests[..keys].chunks_exact(batch) {
                for (slot, &d) in out.iter_mut().zip(chunk) {
                    *slot = engine.bucket(d);
                }
                black_box(&out);
            }
            scalar_reps.push(ns_op(t0.elapsed(), keys));
            let t0 = Instant::now();
            for chunk in digests[..keys].chunks_exact(batch) {
                engine.bucket_batch(chunk, &mut out);
                black_box(&out);
            }
            batched_reps.push(ns_op(t0.elapsed(), keys));
        }
        let scalar = median(scalar_reps);
        let batched = median(batched_reps);
        let speedup = scalar / batched;
        println!(
            "placement_batch (binomial n={N}) batch={batch:<6} \
             scalar: {scalar:>6.2} ns/key   batched: {batched:>6.2} ns/key   \
             speedup {speedup:.2}x"
        );
        sizes_json.push(format!(
            "{{\"batch\": {batch}, \"scalar_ns_key\": {scalar:.2}, \
             \"batched_ns_key\": {batched:.2}, \"speedup\": {speedup:.2}}}"
        ));
    }
    format!(
        "{{\"engine\": \"binomial\", \"n\": {N}, \"sizes\": [{}]}}",
        sizes_json.join(", ")
    )
}

/// Replication phase: memento n = 16 with `factor = 2` (primary-ack
/// writes).  Prices the replica fan-out per PUT, confirms steady GETs
/// are unchanged, serves a degraded sweep entirely from surviving
/// replicas, and reports the anti-entropy RESTORE's round-trip and
/// skipped-stripe counts.  Returns the phase's JSON object.
fn replication_json() -> String {
    use binhash::shard::{Shard, ShardClient};
    const N: u32 = 16;
    let router = Router::with_replication(
        local_cluster("memento", N).unwrap(),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        2,
        false,
    );
    let mut gen = StringKeys::new(9, 8, 32);
    let keys: Vec<String> = (0..OPS).map(|_| gen.next_key()).collect();
    let values: Vec<Value> = (0..256).map(|i| vec![i as u8; 32].into()).collect();

    // PUT at factor 2: primary write + one replica write per op.
    let t0 = Instant::now();
    for (i, k) in keys.iter().enumerate() {
        let r =
            router.handle_ref(RequestRef::Put { key: k, value: values[i & 0xFF].clone() });
        black_box(r);
    }
    let put_ns = ns_op(t0.elapsed(), OPS);

    // Steady GET at factor 2: identical to the factor-1 path (replicas
    // cost writes, not healthy reads) — the JSON pairs it with the
    // steady phases above to prove exactly that.
    let t0 = Instant::now();
    for k in &keys {
        black_box(router.handle_ref(RequestRef::Get { key: k }));
    }
    let get_ns = ns_op(t0.elapsed(), OPS);

    // Degraded GET via replicas: with one shard down the marooned slice
    // is served by the surviving copies — zero UNAVAILABLE expected.
    router.fail_shard(N / 2).expect("fail_shard");
    let t0 = Instant::now();
    for k in &keys {
        black_box(router.handle_ref(RequestRef::Get { key: k }));
    }
    let deg_ns = ns_op(t0.elapsed(), OPS);
    // Separate instrumented pass for the tail percentiles.
    let hist = LatencyHistogram::new();
    let mut unavailable = 0u64;
    for k in &keys {
        let t1 = Instant::now();
        let r = router.handle_ref(RequestRef::Get { key: k });
        hist.record(t1.elapsed());
        if matches!(r, Response::Err(_)) {
            unavailable += 1;
        }
        black_box(r);
    }
    let p50 = hist.quantile_ns(0.5);
    let p99 = hist.quantile_ns(0.99);

    // Anti-entropy RESTORE: round-trips spent vs stripe scans skipped
    // by the digest exchange (the full re-stream would have paid
    // `round_trips + skipped - digest prologue`).
    let rt0 = router.metrics.migration_round_trips.load(Ordering::Relaxed);
    let sk0 = router.metrics.ae_stripes_skipped.load(Ordering::Relaxed);
    router.restore_shard(N / 2).expect("restore_shard");
    let round_trips = router.metrics.migration_round_trips.load(Ordering::Relaxed) - rt0;
    let skipped = router.metrics.ae_stripes_skipped.load(Ordering::Relaxed) - sk0;

    println!(
        "replication (memento n={N}, factor=2): put: {put_ns:>8.0} ns/op ({:>9.0} op/s)   \
         get: {get_ns:>8.0} ns/op ({:>9.0} op/s)",
        1e9 / put_ns,
        1e9 / get_ns,
    );
    println!(
        "      degraded get via replicas: {deg_ns:>8.0} ns/op ({:>9.0} op/s)  \
         p50={p50}ns p99={p99}ns  {unavailable} UNAVAILABLE (0 expected)",
        1e9 / deg_ns,
    );
    println!(
        "      anti-entropy restore: {round_trips} round-trips, \
         {skipped} stripe scans skipped by digests"
    );
    format!(
        "{{\"engine\": \"memento\", \"n\": {N}, \"factor\": 2, \
         \"put\": {}, \"get\": {}, \"degraded_get\": {}, \
         \"degraded_p50\": {p50}, \"degraded_p99\": {p99}, \
         \"unavailable\": {unavailable}, \
         \"restore_round_trips\": {round_trips}, \
         \"restore_stripes_skipped\": {skipped}}}",
        op_json(put_ns),
        op_json(get_ns),
        op_json(deg_ns),
    )
}

/// Zipfian hot-key phase: the same skewed key stream (theta 0.99 over
/// a 100k-id universe) driven through two identical binomial routers,
/// one with the hot-key cache off and one with it on — the delta is
/// what a refcount-bump hit saves over the shard round-trip.  Then a
/// 2:1 heterogeneous-weight `Weighted` cluster (four weight-2 shards,
/// four weight-1) serves a uniform key set and the measured per-shard
/// load factor — raw max/mean and weight-normalized — is reported
/// beside the paper's Eq. (3) relative-imbalance bound `2^-ω`.
/// Returns the phase's JSON object.
fn zipf_json() -> String {
    use binhash::algorithms::binomial::DEFAULT_OMEGA;
    use binhash::algorithms::weighted::Weighted;
    use binhash::cluster::Cluster;
    use binhash::shard::{Shard, ShardClient};
    use binhash::stats::theory;
    use binhash::workload::ZipfKeys;

    const N: u32 = 16;
    const UNIVERSE: usize = 100_000;
    const THETA: f64 = 0.99;
    const HOT_KEYS: usize = 4096;

    let mut z = ZipfKeys::new(11, UNIVERSE, THETA);
    let keys: Vec<String> = (0..OPS).map(|_| z.next_key().0).collect();
    let values: Vec<Value> = (0..256).map(|i| vec![i as u8; 32].into()).collect();

    let off = Router::new(local_cluster("binomial", N).unwrap());
    let on = Router::with_placement(
        local_cluster("binomial", N).unwrap(),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        1,
        false,
        HOT_KEYS,
    );
    // Load the full id universe into both routers.
    for id in 0..UNIVERSE {
        let key = format!("obj-{id}");
        let value = values[id & 0xFF].clone();
        black_box(off.handle_ref(RequestRef::Put { key: &key, value: value.clone() }));
        black_box(on.handle_ref(RequestRef::Put { key: &key, value }));
    }

    // Cache off: every GET pays placement + shard dispatch.
    let t0 = Instant::now();
    for k in &keys {
        black_box(off.handle_ref(RequestRef::Get { key: k }));
    }
    let off_ns = ns_op(t0.elapsed(), OPS);

    // Cache on: one warm pass fills the hot set, then the measured pass
    // serves the head of the distribution from the cache.
    for k in &keys {
        black_box(on.handle_ref(RequestRef::Get { key: k }));
    }
    let hits0 = on.metrics.hot_hits.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for k in &keys {
        black_box(on.handle_ref(RequestRef::Get { key: k }));
    }
    let on_ns = ns_op(t0.elapsed(), OPS);
    let hits = on.metrics.hot_hits.load(Ordering::Relaxed) - hits0;
    let evictions = on.metrics.hot_evictions.load(Ordering::Relaxed);
    let hit_rate = hits as f64 / OPS as f64;

    // 2:1 heterogeneous weights over a binomial vbucket space: the
    // weight-2 shards own two virtual buckets each, so W = 12.
    let weights: Vec<u32> = vec![2, 2, 2, 2, 1, 1, 1, 1];
    let shards_n = weights.len() as u32;
    let total_w: u32 = weights.iter().sum();
    let weighted = Weighted::new("binomial", &weights, 1).expect("weighted binomial");
    let vbuckets = weighted.virtual_buckets();
    let shards = (0..shards_n).map(|i| ShardClient::Local(Shard::new(i))).collect();
    let wrouter = Router::with_placement(
        Cluster::new(Box::new(weighted), shards),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        1,
        false,
        0,
    );
    let mut gen = StringKeys::new(13, 8, 32);
    let wkeys: Vec<String> = (0..UNIVERSE).map(|_| gen.next_key()).collect();
    for (i, k) in wkeys.iter().enumerate() {
        let r = wrouter
            .handle_ref(RequestRef::Put { key: k, value: values[i & 0xFF].clone() });
        black_box(r);
    }
    // Measure the per-shard load over the uniform GET sweep only (the
    // theory bound models uniform keys).
    wrouter.metrics.routed.reset();
    let t0 = Instant::now();
    for k in &wkeys {
        black_box(wrouter.handle_ref(RequestRef::Get { key: k }));
    }
    let wget_ns = ns_op(t0.elapsed(), wkeys.len());
    let raw_lf = wrouter.metrics.routed.load_factor(shards_n);
    // Weight-normalized load factor: observed share over the w_b/W fair
    // share — 1.0 is perfectly weight-proportional.
    let counts: Vec<u64> = (0..shards_n).map(|b| wrouter.metrics.routed.count(b)).collect();
    let total: u64 = counts.iter().sum();
    let weighted_lf = counts
        .iter()
        .zip(&weights)
        .map(|(&c, &w)| c as f64 * total_w as f64 / (total as f64 * w as f64))
        .fold(0.0f64, f64::max);
    let bound = theory::relative_imbalance_bound(DEFAULT_OMEGA);

    println!(
        "zipf (binomial n={N}, theta={THETA}, universe={UNIVERSE}): \
         get cache-off: {off_ns:>8.0} ns/op ({:>9.0} op/s)   \
         cache-on ({HOT_KEYS} keys): {on_ns:>8.0} ns/op ({:>9.0} op/s)  \
         hit-rate {hit_rate:.2}, {evictions} evictions",
        1e9 / off_ns,
        1e9 / on_ns,
    );
    println!(
        "      weighted 2:1 ({shards_n} shards, W={vbuckets}): get: {wget_ns:>8.0} ns/op  \
         load_factor={raw_lf:.3} weight-normalized={weighted_lf:.4} \
         (theory imbalance bound 2^-{DEFAULT_OMEGA} = {bound:.4})"
    );
    format!(
        "{{\"engine\": \"binomial\", \"n\": {N}, \"theta\": {THETA}, \
         \"universe\": {UNIVERSE}, \"hot_cache_keys\": {HOT_KEYS}, \
         \"get_cache_off\": {}, \"get_cache_on\": {}, \
         \"hit_rate\": {hit_rate:.3}, \"hot_evictions\": {evictions}, \
         \"cache_speedup\": {:.2}, \
         \"weighted\": {{\"shards\": {shards_n}, \"virtual_buckets\": {vbuckets}, \
         \"weights\": \"4x2+4x1\", \"get\": {}, \
         \"load_factor\": {raw_lf:.4}, \"weighted_load_factor\": {weighted_lf:.4}, \
         \"measured_imbalance\": {:.4}, \
         \"theory_imbalance_bound\": {bound:.6}, \"omega\": {DEFAULT_OMEGA}}}}}",
        op_json(off_ns),
        op_json(on_ns),
        off_ns / on_ns,
        op_json(wget_ns),
        weighted_lf - 1.0,
    )
}

/// High-fan-in phase: an event-mode `net::Server` holding `FANIN_CONNS`
/// idle connections while a hot connection drives request/response
/// roundtrips through the same loops — prices what 10k parked sockets
/// cost the data path (readiness bookkeeping, slab pressure) versus the
/// in-process numbers above.  Returns the phase's JSON object (or
/// `null` where the readiness server is unavailable).
#[cfg(target_os = "linux")]
fn fanin_json() -> String {
    use std::io::BufReader;
    use std::net::{TcpListener, TcpStream};

    use binhash::net::ServerOpts;
    use binhash::proto;

    // Idle fleet held open while a hot connection keeps working through
    // the same event loops.
    const FANIN_CONNS: usize = 10_000;
    const FANIN_HOT_OPS: usize = 50_000;
    const FANIN_LOOPS: usize = 4;

    // The fleet needs ~2 fds per connection (both ends live in this
    // process); raise the limit before the first connect rather than
    // racing the server thread's own raise.
    let _ = binhash::net::sys::raise_nofile_limit();

    let router = Router::new(local_cluster("binomial", 16).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fanin listener");
    let opts = ServerOpts {
        loops: FANIN_LOOPS,
        max_conns: FANIN_CONNS + 64,
        ..ServerOpts::default()
    };
    let server = router.server(listener, opts).expect("fanin server");
    let addr = server.local_addr();
    let handle = server.handle();
    let srv = std::thread::spawn(move || server.run());

    // Connection-establishment rate: open the idle fleet.
    let t0 = Instant::now();
    let idle: Vec<TcpStream> = (0..FANIN_CONNS)
        .map(|_| TcpStream::connect(addr).expect("fanin connect"))
        .collect();
    let connect_ns = ns_op(t0.elapsed(), FANIN_CONNS);

    // Hot subset: pipeless request/response roundtrips riding above the
    // idle fleet.
    let sock = TcpStream::connect(addr).expect("hot connect");
    sock.set_nodelay(true).expect("nodelay");
    let mut rd = BufReader::new(sock.try_clone().expect("clone"));
    let mut wr = sock;
    let put = Request::Put { key: "hot".into(), value: vec![7u8; 64].into() };
    proto::write_request(&mut wr, &put).expect("seed put");
    assert!(matches!(proto::read_response(&mut rd).expect("seed resp"), Response::Ok));
    let get = Request::Get { key: "hot".into() };
    let hist = LatencyHistogram::new();
    let t0 = Instant::now();
    for _ in 0..FANIN_HOT_OPS {
        let t1 = Instant::now();
        proto::write_request(&mut wr, &get).expect("hot get");
        let r = proto::read_response(&mut rd).expect("hot resp");
        hist.record(t1.elapsed());
        black_box(r);
    }
    let get_ns = ns_op(t0.elapsed(), FANIN_HOT_OPS);
    let p50 = hist.quantile_ns(0.5);
    let p99 = hist.quantile_ns(0.99);

    drop(idle);
    drop((rd, wr));
    handle.stop();
    srv.join().expect("server thread").expect("server run");

    println!(
        "fanin: {FANIN_CONNS} conns over {FANIN_LOOPS} loops  \
         connect: {connect_ns:>8.0} ns/conn ({:>9.0} conn/s)   \
         hot get: {get_ns:>8.0} ns/op ({:>9.0} op/s)  p50={p50}ns p99={p99}ns",
        1e9 / connect_ns,
        1e9 / get_ns,
    );
    format!(
        "{{\"connections\": {FANIN_CONNS}, \"loops\": {FANIN_LOOPS}, \
         \"connect\": {}, \"get\": {}, \"p50\": {p50}, \"p99\": {p99}}}",
        op_json(connect_ns),
        op_json(get_ns),
    )
}

#[cfg(not(target_os = "linux"))]
fn fanin_json() -> String {
    "null".to_string()
}
