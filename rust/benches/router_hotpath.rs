//! Router hot-path bench: end-to-end in-process request latency
//! (placement + shard dispatch) and raw placement cost, measuring what the
//! paper's constant-time claim buys the *system* (L3 target: placement is
//! never the router bottleneck).
//!
//! Custom harness (`harness = false`): ops/s + ns/op over seeded key sets.

use std::hint::black_box;
use std::time::Instant;

use binhash::proto::Request;
use binhash::router::{local_cluster, Router};
use binhash::workload::StringKeys;

const OPS: usize = 200_000;

fn main() {
    for n in [4u32, 16, 64] {
        let router = Router::new(local_cluster("binomial", n).unwrap());
        let mut gen = StringKeys::new(7, 8, 32);
        let keys: Vec<String> = (0..OPS).map(|_| gen.next_key()).collect();

        // PUT phase.
        let t0 = Instant::now();
        for (i, k) in keys.iter().enumerate() {
            let r = router.handle(Request::Put { key: k.clone(), value: vec![(i & 0xFF) as u8] });
            black_box(r);
        }
        let put = t0.elapsed();

        // GET phase.
        let t0 = Instant::now();
        for k in &keys {
            let r = router.handle(Request::Get { key: k.clone() });
            black_box(r);
        }
        let get = t0.elapsed();

        let put_ns = put.as_nanos() as f64 / OPS as f64;
        let get_ns = get.as_nanos() as f64 / OPS as f64;
        println!(
            "n={n:<4} put: {put_ns:>8.0} ns/op ({:>9.0} op/s)   get: {get_ns:>8.0} ns/op ({:>9.0} op/s)",
            1e9 / put_ns,
            1e9 / get_ns
        );
        println!(
            "      placement p50={}ns p99={}ns mean={:.0}ns  (of end-to-end mean {:.0}ns)",
            router.metrics.placement_latency.quantile_ns(0.5),
            router.metrics.placement_latency.quantile_ns(0.99),
            router.metrics.placement_latency.mean_ns(),
            router.metrics.latency.mean_ns(),
        );
    }
}
