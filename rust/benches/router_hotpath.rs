//! Router hot-path bench: end-to-end in-process request latency
//! (placement + shard dispatch) and raw placement cost, measuring what the
//! paper's constant-time claim buys the *system* (L3 target: placement is
//! never the router bottleneck).
//!
//! Three phases per cluster size: PUT, GET, and GET-under-churn — the
//! latter hammers reads while a background admin thread cycles
//! scale-up/scale-down, so it prices the epoch-snapshot design (readers
//! never block on a migration; mid-migration keys cost one extra hop via
//! dual-read).
//!
//! Custom harness (`harness = false`): ops/s + ns/op over seeded key sets.

use std::hint::black_box;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use binhash::proto::Request;
use binhash::router::{local_cluster, Router};
use binhash::workload::StringKeys;

const OPS: usize = 200_000;

fn main() {
    for n in [4u32, 16, 64] {
        let router = Router::new(local_cluster("binomial", n).unwrap());
        let mut gen = StringKeys::new(7, 8, 32);
        let keys: Vec<String> = (0..OPS).map(|_| gen.next_key()).collect();

        // PUT phase.
        let t0 = Instant::now();
        for (i, k) in keys.iter().enumerate() {
            let r = router.handle(Request::Put { key: k.clone(), value: vec![(i & 0xFF) as u8] });
            black_box(r);
        }
        let put = t0.elapsed();

        // GET phase (steady topology).
        let t0 = Instant::now();
        for k in &keys {
            let r = router.handle(Request::Get { key: k.clone() });
            black_box(r);
        }
        let get = t0.elapsed();

        // GET phase under topology churn: a background thread cycles
        // scale-up/scale-down while this thread keeps reading.
        let stop = Arc::new(AtomicBool::new(false));
        let admin = {
            let router = router.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut cycles = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    router.scale_up().expect("scale_up");
                    router.scale_down().expect("scale_down");
                    cycles += 1;
                }
                cycles
            })
        };
        let t0 = Instant::now();
        for k in &keys {
            let r = router.handle(Request::Get { key: k.clone() });
            black_box(r);
        }
        let churn = t0.elapsed();
        stop.store(true, Ordering::Relaxed);
        let cycles = admin.join().expect("admin thread");

        let put_ns = put.as_nanos() as f64 / OPS as f64;
        let get_ns = get.as_nanos() as f64 / OPS as f64;
        let churn_ns = churn.as_nanos() as f64 / OPS as f64;
        println!(
            "n={n:<4} put: {put_ns:>8.0} ns/op ({:>9.0} op/s)   get: {get_ns:>8.0} ns/op ({:>9.0} op/s)",
            1e9 / put_ns,
            1e9 / get_ns
        );
        println!(
            "      get under churn: {churn_ns:>8.0} ns/op ({:>9.0} op/s) across {cycles} scale cycles, \
             {} dual-reads, {} migration batches",
            1e9 / churn_ns,
            router.metrics.dual_reads.load(Ordering::Relaxed),
            router.metrics.migration_batches.load(Ordering::Relaxed),
        );
        println!(
            "      placement p50={}ns p99={}ns mean={:.0}ns  (of end-to-end mean {:.0}ns)",
            router.metrics.placement_latency.quantile_ns(0.5),
            router.metrics.placement_latency.quantile_ns(0.99),
            router.metrics.placement_latency.mean_ns(),
            router.metrics.latency.mean_ns(),
        );
    }
}
