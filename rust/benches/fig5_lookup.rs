//! Fig. 5 bench: per-lookup latency for each algorithm × cluster size.
//!
//! Custom harness (`harness = false`; the build is offline, no criterion):
//! median-of-5 timing batches over 1M pre-generated uniform digests, with
//! warm-up and `black_box` sinks.  Run via `cargo bench --bench
//! fig5_lookup`; the fuller sweep with CSV output lives in
//! `bench_figs fig5`.

use std::hint::black_box;
use std::time::Instant;

use binhash::algorithms::{self, ConsistentHasher};
use binhash::workload::UniformDigests;

const SIZES: &[u32] = &[10, 1_000, 100_000];
const ALGOS: &[&str] = &["binomial", "jumpback", "powerch", "fliphash", "jump"];
const BATCH: usize = 1_000_000;
const REPS: usize = 5;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench_one(engine: &dyn ConsistentHasher, digests: &[u64]) -> f64 {
    let mut sink = 0u64;
    // Warm-up.
    for &d in &digests[..BATCH / 10] {
        sink = sink.wrapping_add(engine.bucket(d) as u64);
    }
    let mut samples = Vec::with_capacity(REPS);
    for _ in 0..REPS {
        let t0 = Instant::now();
        for &d in digests {
            sink = sink.wrapping_add(engine.bucket(d) as u64);
        }
        samples.push(t0.elapsed().as_nanos() as f64 / digests.len() as f64);
    }
    black_box(sink);
    median(samples)
}

fn main() {
    let digests = UniformDigests::new(0xBE_7C_4).take_vec(BATCH);
    println!("fig5_lookup: median ns/lookup over {BATCH} digests x {REPS} reps");
    print!("{:<12}", "algorithm");
    for n in SIZES {
        print!("{:>14}", format!("n={n}"));
    }
    println!();
    for name in ALGOS {
        print!("{name:<12}");
        for &n in SIZES {
            let engine = algorithms::by_name(name, n).unwrap();
            let ns = bench_one(engine.as_ref(), &digests);
            print!("{ns:>14.2}");
        }
        println!();
    }
    println!(
        "\nexpected shape (paper Fig. 5): binomial ≈ jumpback < powerch ≈ fliphash,\n\
         all flat in n; jump grows O(log n)."
    );
}
