//! Perf-variant harness: isolates the L3 hot-path costs and candidate
//! optimizations, one variable at a time (ROADMAP.md tracks which
//! candidates were accepted or rejected; `BENCH_router.json` carries the
//! release-over-release trajectory).
//!
//! Variants measured:
//!  * `free fn`        — `binomial::lookup` direct call (the router's path)
//!  * `dyn dispatch`   — through `Box<dyn ConsistentHasher>` (registry path)
//!  * `batch8`         — the lane-parallel `bucket_batch` kernel (the
//!                       batch data plane and rebalancer path)
//!  * `xxh+lookup`     — string key end-to-end placement (hash + lookup)
//!
//! Plus the batched-placement table the ISSUE tracks (scalar vs
//! `bucket_batch` ns/key at batch 64 / 1k / 64k — `router_hotpath.rs`
//! carries the same comparison into `BENCH_router.json` as the
//! `placement_batch` phase) and a placement-vs-routing breakdown: engine
//! lookup ns vs full `Router::handle_ref` GET ns on a warm local
//! cluster, so the routing overhead ratio (everything around the paper's
//! constant-time lookup) is tracked release over release.

use std::hint::black_box;
use std::time::Instant;

use binhash::algorithms::{self, binomial, ConsistentHasher};
use binhash::hashing::xxhash64;
use binhash::proto::{RequestRef, Response};
use binhash::router::{local_cluster, Router};
use binhash::workload::UniformDigests;

const BATCH: usize = 2_000_000;
const REPS: usize = 7;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn time_ns<F: FnMut() -> u64>(mut f: F, per: usize) -> f64 {
    let mut samples = Vec::with_capacity(REPS);
    let mut sink = 0u64;
    for _ in 0..REPS {
        let t0 = Instant::now();
        sink = sink.wrapping_add(f());
        samples.push(t0.elapsed().as_nanos() as f64 / per as f64);
    }
    black_box(sink);
    median(samples)
}

/// Candidate: lookup with E/M hoisted out (placement-engine form).
#[inline]
fn lookup_pre(h0: u64, n: u32, e: u64, m: u64, omega: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    let mut hi = h0;
    for _ in 0..omega {
        let b = hi & (e - 1);
        let c = binomial::relocate_within_level(b, hi);
        if c < m {
            let d = h0 & (m - 1);
            return binomial::relocate_within_level(d, h0) as u32;
        }
        if c < n as u64 {
            return c as u32;
        }
        hi = binhash::hashing::next_hash(hi);
    }
    let d = h0 & (m - 1);
    binomial::relocate_within_level(d, h0) as u32
}

/// Candidate: branchless relocate (always compute, select at the end).
#[inline(always)]
fn relocate_branchless(b: u64, h: u64) -> u64 {
    let d = 63 - (b | 2).leading_zeros();
    let f = (1u64 << d) - 1;
    let i = binhash::hashing::hash2(h, f) & f;
    let r = (1u64 << d) + i;
    if b < 2 {
        b
    } else {
        r
    }
}

#[inline]
fn lookup_branchless(h0: u64, n: u32, omega: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    let e = binhash::hashing::next_pow2(n as u64);
    let m = e >> 1;
    let mut hi = h0;
    for _ in 0..omega {
        let b = hi & (e - 1);
        let c = relocate_branchless(b, hi);
        if c < m {
            let d = h0 & (m - 1);
            return relocate_branchless(d, h0) as u32;
        }
        if c < n as u64 {
            return c as u32;
        }
        hi = binhash::hashing::next_hash(hi);
    }
    let d = h0 & (m - 1);
    relocate_branchless(d, h0) as u32
}

fn main() {
    let digests = UniformDigests::new(0x9E_4F).take_vec(BATCH);
    let keys: Vec<String> = (0..100_000).map(|i| format!("tenant-3/obj-{i:08x}")).collect();

    println!("perf_variants: median of {REPS} reps over {BATCH} digests\n");
    for n in [11u32, 1_000, 100_000] {
        let free = time_ns(
            || {
                let mut acc = 0u64;
                for &d in &digests {
                    acc = acc.wrapping_add(binomial::lookup(d, n, 6) as u64);
                }
                acc
            },
            BATCH,
        );
        let engine = algorithms::by_name("binomial", n).unwrap();
        let dynd = time_ns(
            || {
                let mut acc = 0u64;
                for &d in &digests {
                    acc = acc.wrapping_add(engine.bucket(d) as u64);
                }
                acc
            },
            BATCH,
        );
        let mut out = vec![0u32; BATCH];
        let batch8 = time_ns(
            || {
                engine.bucket_batch(&digests, &mut out);
                out.iter().map(|&x| x as u64).sum()
            },
            BATCH,
        );
        let keyed = time_ns(
            || {
                let mut acc = 0u64;
                for k in &keys {
                    let d = xxhash64(k.as_bytes(), 0);
                    acc = acc.wrapping_add(binomial::lookup(d, n, 6) as u64);
                }
                acc
            },
            keys.len(),
        );
        let e = binhash::hashing::next_pow2(n as u64);
        let m = e >> 1;
        let pre = time_ns(
            || {
                let mut acc = 0u64;
                for &d in &digests {
                    acc = acc.wrapping_add(lookup_pre(d, n, e, m, 6) as u64);
                }
                acc
            },
            BATCH,
        );
        let branchless = time_ns(
            || {
                let mut acc = 0u64;
                for &d in &digests {
                    acc = acc.wrapping_add(lookup_branchless(d, n, 6) as u64);
                }
                acc
            },
            BATCH,
        );
        println!(
            "n={n:<7} free={free:>6.2}ns  dyn={dynd:>6.2}ns  batch8={batch8:>6.2}ns  \
             pre-EM={pre:>6.2}ns  branchless={branchless:>6.2}ns  key+hash={keyed:>6.2}ns"
        );
    }

    // --- Batched placement: scalar `bucket` loop vs the lane-parallel
    // `bucket_batch` kernel, per batch size.  The acceptance bar is
    // batched strictly below scalar at batch 1k and 64k; batch 64 shows
    // where the kernel's chunk setup amortizes.
    println!("\nbatched placement: scalar vs bucket_batch (ns/key):");
    for n in [11u32, 1_000, 100_000] {
        let engine = binomial::BinomialHash::new(n);
        for batch in [64usize, 1_024, 65_536] {
            let keys = (BATCH / batch) * batch;
            let mut out = vec![0u32; batch];
            let scalar = time_ns(
                || {
                    let mut acc = 0u64;
                    for chunk in digests[..keys].chunks_exact(batch) {
                        for (o, &d) in out.iter_mut().zip(chunk) {
                            *o = engine.bucket(d);
                        }
                        acc = acc.wrapping_add(out[batch - 1] as u64);
                    }
                    acc
                },
                keys,
            );
            let batched = time_ns(
                || {
                    let mut acc = 0u64;
                    for chunk in digests[..keys].chunks_exact(batch) {
                        engine.bucket_batch(chunk, &mut out);
                        acc = acc.wrapping_add(out[batch - 1] as u64);
                    }
                    acc
                },
                keys,
            );
            println!(
                "n={n:<7} batch={batch:<6} scalar={scalar:>6.2}ns/key  \
                 batched={batched:>6.2}ns/key  speedup={:.2}x",
                scalar / batched
            );
        }
    }

    // --- Placement vs routing: what a full local GET costs around the
    // engine lookup (snapshot load + digest + stripe map + Arc bump).
    // This ratio is the overhead the zero-allocation data path attacks.
    println!("\nplacement vs routing (local binomial cluster, warm keys):");
    const ROUTED_KEYS: usize = 100_000;
    for n in [4u32, 16, 64] {
        let router = Router::new(local_cluster("binomial", n).unwrap());
        let keys: Vec<String> =
            (0..ROUTED_KEYS).map(|i| format!("tenant-3/obj-{i:08x}")).collect();
        for k in &keys {
            router.handle_ref(RequestRef::Put { key: k, value: vec![0x5A; 32].into() });
        }
        let digests: Vec<u64> = keys.iter().map(|k| xxhash64(k.as_bytes(), 0)).collect();
        let engine = algorithms::by_name("binomial", n).unwrap();
        let place = time_ns(
            || {
                let mut acc = 0u64;
                for &d in &digests {
                    acc = acc.wrapping_add(engine.bucket(d) as u64);
                }
                acc
            },
            digests.len(),
        );
        let full = time_ns(
            || {
                let mut hits = 0u64;
                for k in &keys {
                    if matches!(
                        router.handle_ref(RequestRef::Get { key: k }),
                        Response::Val(_)
                    ) {
                        hits += 1;
                    }
                }
                assert_eq!(hits as usize, ROUTED_KEYS);
                hits
            },
            keys.len(),
        );
        println!(
            "n={n:<4} engine lookup={place:>6.2}ns  full GET handle={full:>7.2}ns  \
             routing overhead={:.1}x",
            full / place
        );
    }
}
