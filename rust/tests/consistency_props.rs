//! Property tests over the whole algorithm suite: the §3 consistency
//! properties checked with seeded random sweeps (in-tree property harness;
//! the build is offline, so no proptest crate — the sweep style matches
//! what proptest would generate, with fixed seeds for reproducibility).
//!
//! Two families:
//! * *stateless* algorithms are pure functions of `(digest, n)` — two
//!   instances at `n` and `n±1` are directly comparable;
//! * *stateful* algorithms (anchor, dx) carry construction state, so the
//!   properties are checked by mutating a single instance.

use binhash::algorithms::weighted::Weighted;
use binhash::algorithms::{self, ConsistentHasher, ALL_ALGORITHMS, ANTI_BASELINE};
use binhash::hashing::SplitMix64Rng;
use binhash::stats::BalanceStats;

/// Pure functions of (digest, n): instances are comparable across n.
/// (maglev is only approximately minimal and is reported, not asserted,
/// by `bench_figs disruption`.)
const STATELESS: &[&str] = &[
    "binomial",
    "jumpback",
    "powerch",
    "fliphash",
    "jump",
    "memento",
    "multiprobe",
    "ring",
    "rendezvous",
];

/// Construction-stateful: properties hold along one instance's lifecycle.
const STATEFUL: &[&str] = &["anchor", "dx"];

#[test]
fn lookup_always_in_range() {
    let mut rng = SplitMix64Rng::new(0x7e57);
    for name in ALL_ALGORITHMS {
        for n in [1u32, 2, 3, 5, 8, 9, 16, 17, 64, 100, 1000] {
            let h = algorithms::by_name(name, n).unwrap();
            for _ in 0..300 {
                let b = h.bucket(rng.next_u64());
                assert!(b < n, "{name}: bucket {b} out of range for n={n}");
            }
        }
    }
}

#[test]
fn lookup_deterministic() {
    let mut rng = SplitMix64Rng::new(0x7e58);
    for name in ALL_ALGORITHMS {
        let h = algorithms::by_name(name, 13).unwrap();
        for _ in 0..100 {
            let d = rng.next_u64();
            assert_eq!(h.bucket(d), h.bucket(d), "{name}");
        }
    }
}

#[test]
fn monotonicity_on_scale_up() {
    let mut rng = SplitMix64Rng::new(0x7e59);
    let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
    for name in STATELESS {
        for n in [2u32, 7, 8, 15, 16, 31, 50] {
            let a = algorithms::by_name(name, n).unwrap();
            let b = algorithms::by_name(name, n + 1).unwrap();
            for &d in &digests {
                let x = a.bucket(d);
                let y = b.bucket(d);
                assert!(
                    y == x || y == n,
                    "{name}: n={n} digest={d}: {x} -> {y} (not the new bucket)"
                );
            }
        }
    }
}

#[test]
fn minimal_disruption_on_scale_down() {
    let mut rng = SplitMix64Rng::new(0x7e5a);
    let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
    for name in STATELESS {
        for n in [3u32, 8, 9, 16, 17, 33, 64] {
            let a = algorithms::by_name(name, n).unwrap();
            let b = algorithms::by_name(name, n - 1).unwrap();
            for &d in &digests {
                let x = a.bucket(d);
                let y = b.bucket(d);
                if x != n - 1 {
                    assert_eq!(y, x, "{name}: n={n} digest={d}: settled key moved");
                }
            }
        }
    }
}

#[test]
fn stateful_monotonicity_and_disruption_via_mutation() {
    let mut rng = SplitMix64Rng::new(0x7e5f);
    let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
    for name in STATEFUL {
        let mut h = algorithms::by_name(name, 8).unwrap();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        // Scale up: keys move only onto the new bucket.
        let added = h.add_bucket();
        let up: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        for (i, (&x, &y)) in before.iter().zip(&up).enumerate() {
            assert!(y == x || y == added, "{name}: key {i} {x}->{y} != {added}");
        }
        // Scale back down: exact inverse.
        h.remove_bucket();
        let down: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, down, "{name}: add+remove not identity");
    }
}

#[test]
fn add_remove_roundtrip_is_identity() {
    let mut rng = SplitMix64Rng::new(0x7e5b);
    let digests: Vec<u64> = (0..2_000).map(|_| rng.next_u64()).collect();
    for name in ALL_ALGORITHMS {
        if *name == "maglev" {
            continue; // approximate by design
        }
        let mut h = algorithms::by_name(name, 9).unwrap();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        h.add_bucket();
        h.remove_bucket();
        let after: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, after, "{name}: add+remove is not identity");
    }
}

#[test]
fn monotonicity_along_growth_path() {
    // Walk n = 1..=65 (crossing five power-of-two boundaries) and verify
    // every key's path only ever moves onto the newest bucket.
    let mut rng = SplitMix64Rng::new(0x7e5c);
    let digests: Vec<u64> = (0..500).map(|_| rng.next_u64()).collect();
    for name in STATELESS {
        let mut prev: Vec<u32> =
            digests.iter().map(|&d| algorithms::by_name(name, 1).unwrap().bucket(d)).collect();
        for n in 2u32..=65 {
            let h = algorithms::by_name(name, n).unwrap();
            for (i, &d) in digests.iter().enumerate() {
                let cur = h.bucket(d);
                assert!(
                    cur == prev[i] || cur == n - 1,
                    "{name}: key {i} jumped {} -> {cur} at n={n}",
                    prev[i]
                );
                prev[i] = cur;
            }
        }
    }
    // Stateful: same walk along one instance's lifecycle.
    for name in STATEFUL {
        let mut h = algorithms::by_name(name, 1).unwrap();
        let mut prev: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        for n in 2u32..=33 {
            let added = h.add_bucket();
            assert_eq!(added, n - 1, "{name}");
            for (i, &d) in digests.iter().enumerate() {
                let cur = h.bucket(d);
                assert!(
                    cur == prev[i] || cur == n - 1,
                    "{name}: key {i} jumped {} -> {cur} at n={n}",
                    prev[i]
                );
                prev[i] = cur;
            }
        }
    }
}

#[test]
fn balance_within_tolerance() {
    let k = 60_000usize;
    for name in ALL_ALGORITHMS {
        // ring with default vnodes is noticeably less balanced; allow more.
        let tolerance = match *name {
            "ring" => 0.35,
            "multiprobe" => 0.15,
            _ => 0.08,
        };
        let h = algorithms::by_name(name, 12).unwrap();
        let mut counts = vec![0u64; 12];
        let mut rng = SplitMix64Rng::new(0x7e5d);
        for _ in 0..k {
            counts[h.bucket(rng.next_u64()) as usize] += 1;
        }
        let s = BalanceStats::from_counts(&counts);
        assert!(
            s.rel_stddev() < tolerance,
            "{name}: rel stddev {:.3} over tolerance {tolerance}",
            s.rel_stddev()
        );
    }
}

#[test]
fn movement_fraction_near_ideal() {
    // Scale n -> n+1: the moved fraction must be ~1/(n+1), not ~1/2 like
    // naive modulo hashing.
    let mut rng = SplitMix64Rng::new(0x7e5e);
    let digests: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
    for name in STATELESS {
        for n in [10u32, 32, 99] {
            let a = algorithms::by_name(name, n).unwrap();
            let b = algorithms::by_name(name, n + 1).unwrap();
            let moved = digests.iter().filter(|&&d| a.bucket(d) != b.bucket(d)).count();
            let frac = moved as f64 / digests.len() as f64;
            let ideal = 1.0 / (n + 1) as f64;
            assert!(
                frac < ideal * 1.6 + 0.01,
                "{name}: n={n} moved {frac:.4} vs ideal {ideal:.4}"
            );
        }
    }
    for name in STATEFUL {
        for n in [10u32, 32] {
            let mut h = algorithms::by_name(name, n).unwrap();
            let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
            h.add_bucket();
            let moved =
                digests.iter().zip(&before).filter(|&(&d, &x)| h.bucket(d) != x).count();
            let frac = moved as f64 / digests.len() as f64;
            let ideal = 1.0 / (n + 1) as f64;
            assert!(
                frac < ideal * 1.6 + 0.01,
                "{name}: n={n} moved {frac:.4} vs ideal {ideal:.4}"
            );
        }
    }
}

/// Every engine name the `Weighted` adapter must wrap (the 12 registered
/// algorithms plus the modulo anti-baseline).
fn all_engines() -> impl Iterator<Item = &'static str> {
    ALL_ALGORITHMS.iter().copied().chain(std::iter::once(ANTI_BASELINE))
}

/// Engines whose scale-up moves keys only onto the new bucket — the set
/// the monotone `Weighted` properties can be asserted for (maglev is
/// approximate, modulo reshuffles by design).
fn monotone_engines() -> impl Iterator<Item = &'static str> {
    STATELESS.iter().copied().chain(STATEFUL.iter().copied())
}

#[test]
fn weighted_wrapper_keeps_lookups_in_shard_range() {
    let mut rng = SplitMix64Rng::new(0x7e60);
    for name in all_engines() {
        let w = Weighted::new(name, &[2, 1, 3, 1], 1).unwrap();
        assert_eq!(w.len(), 4, "{name}");
        for _ in 0..500 {
            let b = w.bucket(rng.next_u64());
            assert!(b < 4, "{name}: shard {b} out of range");
        }
    }
}

#[test]
fn weighted_scale_up_is_monotone_and_roundtrips() {
    let mut rng = SplitMix64Rng::new(0x7e61);
    let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
    for name in monotone_engines() {
        let mut w = Weighted::new(name, &[2, 1, 3, 1], 2).unwrap();
        let before: Vec<u32> = digests.iter().map(|&d| w.bucket(d)).collect();
        let added = w.add_bucket();
        assert_eq!(added, 4, "{name}: joiner id is the shard frontier");
        for (i, &d) in digests.iter().enumerate() {
            let cur = w.bucket(d);
            assert!(
                cur == before[i] || cur == added,
                "{name}: key {i} jumped {} -> {cur} (not the joiner)",
                before[i]
            );
        }
        w.remove_bucket();
        let after: Vec<u32> = digests.iter().map(|&d| w.bucket(d)).collect();
        assert_eq!(before, after, "{name}: weighted add+remove is not identity");
    }
}

#[test]
fn weighted_set_weight_growth_moves_keys_only_onto_the_grown_shard() {
    let mut rng = SplitMix64Rng::new(0x7e62);
    let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
    for name in monotone_engines() {
        let mut w = Weighted::new(name, &[1, 1, 1, 1], 1).unwrap();
        let before: Vec<u32> = digests.iter().map(|&d| w.bucket(d)).collect();
        w.set_weight(2, 3).unwrap();
        for (i, &d) in digests.iter().enumerate() {
            let cur = w.bucket(d);
            assert!(
                cur == before[i] || cur == 2,
                "{name}: key {i} moved {} -> {cur}, not onto the grown shard",
                before[i]
            );
        }
    }
}

#[test]
fn weighted_minimal_disruption_tracks_the_engine_and_tail_alignment() {
    for name in all_engines() {
        let bare = algorithms::by_name(name, 6).unwrap();
        let mut w = Weighted::uniform(name, 6).unwrap();
        assert_eq!(
            w.minimal_disruption(),
            bare.minimal_disruption(),
            "{name}: uniform wrapper must mirror the engine's claim"
        );
        if !bare.minimal_disruption() {
            continue;
        }
        // Growing the tail shard keeps its virtual buckets tail-dense...
        w.set_weight(5, 2).unwrap();
        assert!(w.minimal_disruption(), "{name}: tail-shard growth broke tail alignment");
        // ...but growing an interior shard parks its new virtual bucket
        // at the engine tail, so a shrink would need reassignment.
        w.set_weight(1, 2).unwrap();
        assert!(
            !w.minimal_disruption(),
            "{name}: interior growth must disable the fast-shrink claim"
        );
    }
}

#[test]
fn bucket_batch_is_scalar_bucket_for_every_engine() {
    // The batched-placement contract: `bucket_batch` writes exactly what
    // the scalar `bucket` loop would, for all 13 engines and the
    // `Weighted` wrapper, across random n (including n = 1 and
    // power-of-two boundaries where the binomial kernel's tree capacity
    // jumps), random batch lengths straddling its 8-lane chunking, and
    // random digests.
    let mut rng = SplitMix64Rng::new(0x7e63);
    let ns = [1u32, 2, 3, 7, 8, 9, 16, 17, 63, 64, 65, 100];
    for name in all_engines() {
        for _ in 0..6 {
            let n = ns[(rng.next_u64() % ns.len() as u64) as usize];
            let len = (rng.next_u64() % 40) as usize;
            let digests: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let mut out = vec![u32::MAX; len];
            let h = algorithms::by_name(name, n).unwrap();
            h.bucket_batch(&digests, &mut out);
            for (d, got) in digests.iter().zip(&out) {
                assert_eq!(*got, h.bucket(*d), "{name}: n={n} digest={d:#x}");
            }
        }
    }
    // Random ω through the binomial engine directly (the only engine
    // the parameter exists on) — block C must batch identically too.
    for _ in 0..8 {
        use binhash::algorithms::binomial::BinomialHash;
        let n = ns[(rng.next_u64() % ns.len() as u64) as usize];
        let omega = 1 + (rng.next_u64() % 8) as u32;
        let h = BinomialHash::with_omega(n, omega);
        let digests: Vec<u64> = (0..37).map(|_| rng.next_u64()).collect();
        let mut out = vec![u32::MAX; digests.len()];
        h.bucket_batch(&digests, &mut out);
        for (d, got) in digests.iter().zip(&out) {
            assert_eq!(*got, h.bucket(*d), "binomial: n={n} omega={omega} digest={d:#x}");
        }
    }
    // The Weighted wrapper over every engine: the owner map must apply
    // per lane on top of the inner batched kernel.
    for name in all_engines() {
        let w = Weighted::new(name, &[2, 1, 3, 1], 1).unwrap();
        let digests: Vec<u64> = (0..67).map(|_| rng.next_u64()).collect();
        let mut out = vec![u32::MAX; digests.len()];
        w.bucket_batch(&digests, &mut out);
        for (d, got) in digests.iter().zip(&out) {
            assert_eq!(*got, w.bucket(*d), "weighted({name}): digest={d:#x}");
        }
    }
}

#[test]
fn string_key_api_consistent_with_digest_api() {
    for name in ALL_ALGORITHMS {
        let h = algorithms::by_name(name, 17).unwrap();
        for key in [b"a".as_slice(), b"tenant-1/bucket-2/obj-3", b"\xff\x00binary"] {
            let d = binhash::hashing::xxhash64(key, 0);
            assert_eq!(h.bucket_for_key(key), h.bucket(d), "{name}");
        }
    }
}
