//! Failover integration sweep: all three fault-tolerant engines (anchor,
//! dx, memento) fail over and restore *through the router*.
//!
//! Pins the acceptance contract of the failover subsystem:
//!
//! * `FAIL <id>` publishes a degraded epoch with O(1) engine work and no
//!   shard I/O — it works even when the failed shard is a dead TCP
//!   endpoint that would hang any dial;
//! * while degraded, no request routes to the dead shard: reachable keys
//!   serve normally, marooned ones answer a distinguishable
//!   `UNAVAILABLE` error, and a re-PUT makes a key reachable again;
//! * `RESTORE <id>` rejoins the shard empty (WIPE) and migrates the keys
//!   written to survivors during the outage back onto it — deleted keys
//!   stay dead, and engines with restore-order constraints (anchor)
//!   reject out-of-order restores cleanly;
//! * scaling while degraded composes for dx (frontier growth) and fails
//!   fast with the engine's reason for anchor and memento;
//! * with `replication.factor` ≥ 2 a failure loses nothing: every key
//!   written before the FAIL still answers (zero `UNAVAILABLE`), a
//!   degraded DEL reads back `NIL` instead of a false `UNAVAILABLE`,
//!   fallback reads repair the owner, and RESTORE converges by digest
//!   anti-entropy in strictly fewer round-trips than a full re-stream.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use binhash::algorithms::{by_name, ConsistentHasher};
use binhash::cluster::Cluster;
use binhash::proto::{self, Request, Response, Value};
use binhash::router::{local_cluster, Router};
use binhash::shard::{key_digest, RemotePool, Shard, ShardClient};

const FT_ENGINES: &[&str] = &["anchor", "dx", "memento"];

fn val(i: usize) -> Value {
    vec![i as u8, (i >> 8) as u8, 0xEE].into()
}

/// GET through the router, classifying the degraded-read contract.
enum Read {
    Hit(Value),
    Miss,
    Unavailable,
}

fn classify(router: &Router, key: &str) -> Read {
    match router.handle(Request::Get { key: key.into() }) {
        Response::Val(v) => Read::Hit(v),
        Response::Nil => Read::Miss,
        Response::Err(msg) => {
            assert!(msg.starts_with("UNAVAILABLE"), "unexpected error for {key}: {msg}");
            Read::Unavailable
        }
        other => panic!("{key}: {other:?}"),
    }
}

#[test]
fn every_fault_tolerant_engine_fails_over_and_restores_through_the_router() {
    const KEYS: usize = 600;
    const FAILED: u32 = 2;
    for name in FT_ENGINES {
        let router = Router::new(local_cluster(name, 5).unwrap());
        for i in 0..KEYS {
            assert_eq!(
                router.handle(Request::Put { key: format!("f{i}"), value: val(i) }),
                Response::Ok,
                "{name}"
            );
        }
        // The healthy placement tells us which keys will be marooned.
        let pre_fail = by_name(name, 5).unwrap();
        let marooned: Vec<usize> = (0..KEYS)
            .filter(|i| pre_fail.bucket(key_digest(&format!("f{i}"))) == FAILED)
            .collect();
        assert!(!marooned.is_empty(), "{name}: keyset never hit bucket {FAILED}");

        assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(4), "{name}");
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("state=degraded"), "{name}: {s}");
                assert!(s.contains("failed=2"), "{name}: {s}");
                assert!(s.contains("failovers=1"), "{name}: {s}");
            }
            other => panic!("{name}: {other:?}"),
        }
        // Degraded serving: reachable keys answer, marooned ones answer
        // UNAVAILABLE — and nothing hangs or misroutes.
        for i in 0..KEYS {
            match classify(&router, &format!("f{i}")) {
                Read::Hit(v) => {
                    assert_eq!(v, val(i), "{name}: f{i} corrupted");
                    assert!(
                        !marooned.contains(&i),
                        "{name}: marooned f{i} served from a dead shard?"
                    );
                }
                Read::Unavailable => {
                    assert!(marooned.contains(&i), "{name}: reachable f{i} unavailable");
                }
                Read::Miss => panic!("{name}: f{i} silently missing while degraded"),
            }
        }
        // COUNT skips the dead shard: exactly the reachable keys.
        assert_eq!(
            router.handle(Request::Count),
            Response::Num((KEYS - marooned.len()) as u64),
            "{name}"
        );
        assert!(router.shard_count(FAILED).is_err(), "{name}: shard_count dialed a dead shard");

        // A write supersedes the marooned copy: the key is reachable
        // again immediately, and survives the later restore migration.
        let rewritten = marooned[0];
        assert_eq!(
            router.handle(Request::Put {
                key: format!("f{rewritten}"),
                value: b"rewritten".to_vec().into()
            }),
            Response::Ok,
            "{name}"
        );
        assert_eq!(
            router.handle(Request::Get { key: format!("f{rewritten}") }),
            Response::Val(b"rewritten".to_vec().into()),
            "{name}: re-PUT key still unavailable"
        );
        // Re-failing an already-failed shard is a clean rejection.
        assert!(matches!(router.handle(Request::Fail { shard: FAILED }), Response::Err(_)));

        assert_eq!(
            router.handle(Request::Restore { shard: FAILED }),
            Response::Num(5),
            "{name}"
        );
        let snap = router.snapshot();
        assert!(!snap.is_migrating() && !snap.is_degraded(), "{name}: restore did not settle");
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("state=steady"), "{name}: {s}");
                assert!(s.contains("failed=-"), "{name}: {s}");
                assert!(s.contains("restores=1"), "{name}: {s}");
            }
            other => panic!("{name}: {other:?}"),
        }
        // Post-restore: survivors intact, the rewritten key migrated
        // back, never-rewritten marooned keys are lost (this router runs
        // factor 1, so their only copy died with the shard —
        // `replication_factor_two_serves_every_key_through_a_failure`
        // pins the factor-2 contract where nothing is lost), and nothing
        // answers UNAVAILABLE anymore.
        for i in 0..KEYS {
            match classify(&router, &format!("f{i}")) {
                Read::Hit(v) => {
                    if i == rewritten {
                        assert_eq!(v.as_ref(), &b"rewritten"[..], "{name}");
                    } else {
                        assert_eq!(v, val(i), "{name}: f{i} corrupted by restore");
                        assert!(!marooned.contains(&i), "{name}: f{i} resurrected stale data");
                    }
                }
                Read::Miss => {
                    assert!(
                        marooned.contains(&i) && i != rewritten,
                        "{name}: reachable f{i} lost by restore"
                    );
                }
                Read::Unavailable => panic!("{name}: f{i} unavailable after restore"),
            }
        }
        // The restored shard owns its keyspace again: keys written while
        // it was down migrated back.
        assert!(router.shard_count(FAILED).unwrap() > 0, "{name}: restored shard left empty");
        // And the cluster scales again now that it is healthy.
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(6), "{name}");
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(5), "{name}");
    }
}

#[test]
fn batched_ops_isolate_marooned_keys_while_degraded() {
    // One MGET spanning survivors and marooned keys: the failed bucket's
    // keys answer their per-key `ERR UNAVAILABLE`, every other
    // sub-response stands — a dead shard never poisons the batch.
    const KEYS: usize = 400;
    const FAILED: u32 = 2;
    let router = Router::new(local_cluster("memento", 5).unwrap());
    let keys: Vec<String> = (0..KEYS).map(|i| format!("bf{i}")).collect();
    let values: Vec<Value> = (0..KEYS).map(val).collect();
    match router.handle(Request::MPut { keys: keys.clone(), values }) {
        Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
        other => panic!("{other:?}"),
    }
    let pre_fail = by_name("memento", 5).unwrap();
    let marooned: Vec<usize> = (0..KEYS)
        .filter(|i| pre_fail.bucket(key_digest(&keys[*i])) == FAILED)
        .collect();
    assert!(!marooned.is_empty(), "keyset never hit bucket {FAILED}");
    assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(4));

    match router.handle(Request::MGet { keys: keys.clone() }) {
        Response::Multi(subs) => {
            assert_eq!(subs.len(), KEYS);
            for (i, sub) in subs.iter().enumerate() {
                if marooned.contains(&i) {
                    match sub {
                        Response::Err(msg) => {
                            assert!(msg.starts_with("UNAVAILABLE"), "bf{i}: {msg}")
                        }
                        other => panic!("marooned bf{i} answered {other:?}"),
                    }
                } else {
                    assert_eq!(*sub, Response::Val(val(i)), "survivor bf{i} poisoned");
                }
            }
        }
        other => panic!("{other:?}"),
    }
    // A batched re-PUT makes marooned keys reachable again (each lands on
    // its surviving owner), and the next MGET serves the whole batch.
    let re_keys: Vec<String> = marooned.iter().map(|&i| keys[i].clone()).collect();
    let re_values: Vec<Value> = marooned.iter().map(|&i| val(i)).collect();
    match router.handle(Request::MPut { keys: re_keys, values: re_values }) {
        Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
        other => panic!("{other:?}"),
    }
    match router.handle(Request::MGet { keys }) {
        Response::Multi(subs) => {
            for (i, sub) in subs.iter().enumerate() {
                assert_eq!(*sub, Response::Val(val(i)), "bf{i} after batched re-PUT");
            }
        }
        other => panic!("{other:?}"),
    }
    // The batch counters surfaced in STATS moved.
    match router.handle(Request::Stats) {
        Response::Info(s) => {
            assert!(s.contains("state=degraded"), "{s}");
            assert!(!s.contains("mget_keys=0"), "{s}");
            assert!(!s.contains("mput_keys=0"), "{s}");
            assert!(!s.contains("batch_fanouts=0"), "{s}");
        }
        other => panic!("{other:?}"),
    }
    // Restore converges with batched traffic having run throughout.
    assert_eq!(router.handle(Request::Restore { shard: FAILED }), Response::Num(5));
    match router.handle(Request::MGet {
        keys: (0..KEYS).map(|i| format!("bf{i}")).collect(),
    }) {
        Response::Multi(subs) => {
            for (i, sub) in subs.iter().enumerate() {
                assert_eq!(*sub, Response::Val(val(i)), "bf{i} after restore");
            }
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn fail_never_dials_the_dead_shard_even_over_tcp() {
    // The failed shard here is a *dead TCP endpoint* — any code path
    // that dials it would error (or hang, with a black-holed address);
    // FAIL must succeed instantly and the data path must route around
    // it.  RESTORE, by contrast, must dial it (WIPE) and therefore fails
    // cleanly while it is still dead.
    // Port 1 is privileged and unbindable by test processes: connects
    // are refused instantly, and no parallel test can accidentally
    // start listening there (a dropped ephemeral port could be reused).
    let dead_addr = "127.0.0.1:1".parse().unwrap();
    let engine = by_name("memento", 3).unwrap();
    let shards = vec![
        ShardClient::Local(Shard::new(0)),
        ShardClient::Local(Shard::new(1)),
        ShardClient::Remote(RemotePool::new(dead_addr, 1)),
    ];
    let router = Router::new(Cluster::new(engine, shards));

    assert_eq!(router.handle(Request::Fail { shard: 2 }), Response::Num(2));
    // Writes land on survivors; reads of them never touch the dead
    // endpoint.
    for i in 0..100 {
        assert_eq!(
            router.handle(Request::Put { key: format!("d{i}"), value: val(i) }),
            Response::Ok
        );
        assert_eq!(
            router.handle(Request::Get { key: format!("d{i}") }),
            Response::Val(val(i))
        );
    }
    // An absent key whose pre-failure owner is the dead shard answers
    // UNAVAILABLE instantly instead of dialing a dead connection.
    let healthy = by_name("memento", 3).unwrap();
    let ghost = (0..)
        .map(|i| format!("ghost{i}"))
        .find(|k| healthy.bucket(key_digest(k)) == 2)
        .unwrap();
    assert!(matches!(
        router.handle(Request::Get { key: ghost.clone() }),
        Response::Err(msg) if msg.starts_with("UNAVAILABLE")
    ));
    // COUNT and STATS skip it too.
    assert_eq!(router.handle(Request::Count), Response::Num(100));
    // RESTORE needs the shard back (WIPE round-trip): while it is still
    // dead this fails cleanly and mutates nothing.
    assert!(matches!(router.handle(Request::Restore { shard: 2 }), Response::Err(_)));
    let snap = router.snapshot();
    assert!(snap.is_degraded(), "failed restore must leave the degraded epoch in place");
    assert_eq!(router.handle(Request::Count), Response::Num(100));
}

#[test]
fn anchor_enforces_restore_order_cleanly() {
    let router = Router::new(local_cluster("anchor", 6).unwrap());
    for i in 0..200 {
        router.handle(Request::Put { key: format!("a{i}"), value: val(i) });
    }
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(5));
    assert_eq!(router.handle(Request::Fail { shard: 4 }), Response::Num(4));
    // Anchor restores in reverse removal order: 4 first, then 1 — the
    // violation answers ERR (naming the required bucket), never panics
    // under the admin lock.
    match router.handle(Request::Restore { shard: 1 }) {
        Response::Err(msg) => assert!(msg.contains('4'), "{msg}"),
        other => panic!("{other:?}"),
    }
    assert_eq!(router.handle(Request::Restore { shard: 4 }), Response::Num(5));
    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(6));
    assert!(!router.snapshot().is_degraded());
    // Still serving and scalable after the ordered recovery.
    for i in 0..200 {
        match classify(&router, &format!("a{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i)),
            Read::Miss => {} // marooned data died with its shard
            Read::Unavailable => panic!("a{i} unavailable after full recovery"),
        }
    }
    assert_eq!(router.handle(Request::ScaleUp), Response::Num(7));
}

#[test]
fn memento_survives_multiple_overlapping_failures() {
    let router = Router::new(local_cluster("memento", 6).unwrap());
    for i in 0..400 {
        router.handle(Request::Put { key: format!("m{i}"), value: val(i) });
    }
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(5));
    assert_eq!(router.handle(Request::Fail { shard: 3 }), Response::Num(4));
    match router.handle(Request::Stats) {
        Response::Info(s) => assert!(s.contains("failed=1,3"), "{s}"),
        other => panic!("{other:?}"),
    }
    // Scaling is blocked with *both* buckets named.
    match router.handle(Request::ScaleUp) {
        Response::Err(msg) => {
            assert!(msg.contains("memento"), "{msg}");
            assert!(msg.contains("failed buckets: 1,3"), "{msg}");
            assert!(msg.contains("RESTORE"), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    // Every read respects the two-failure degraded contract.
    for i in 0..400 {
        match classify(&router, &format!("m{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i), "m{i} corrupted"),
            Read::Unavailable => {}
            Read::Miss => panic!("m{i} silently missing while degraded"),
        }
    }
    // Memento restores in any order.
    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(5));
    assert_eq!(router.handle(Request::Restore { shard: 3 }), Response::Num(6));
    assert!(!router.snapshot().is_degraded());
    assert_eq!(router.handle(Request::ScaleUp), Response::Num(7));
}

#[test]
fn dx_scales_while_degraded() {
    // dx's add frontier is disjoint from its failure holes, so a
    // degraded dx cluster can still grow (and retire a working frontier
    // bucket) — the scale composes with the outstanding failure instead
    // of being blanket-rejected.
    let router = Router::new(local_cluster("dx", 4).unwrap());
    for i in 0..400 {
        router.handle(Request::Put { key: format!("x{i}"), value: val(i) });
    }
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(3));
    // Grow: the new bucket takes id 4 (the frontier), shards stay
    // addressable, keys migrate onto it from the *reachable* shards.
    assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
    let snap = router.snapshot();
    assert_eq!(snap.shards.len(), 5);
    assert!(snap.is_degraded());
    assert!(router.shard_count(4).unwrap() > 0, "joining shard received no keys");
    // Shrink it again while still degraded.
    assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
    assert_eq!(router.snapshot().shards.len(), 4);
    // Reads held the degraded contract across both scales.
    let mut unavailable = 0;
    for i in 0..400 {
        match classify(&router, &format!("x{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i), "x{i} corrupted"),
            Read::Unavailable => unavailable += 1,
            Read::Miss => panic!("x{i} silently missing"),
        }
    }
    assert!(unavailable > 0, "no key was marooned on failed bucket 1");
    // Recover, then verify the cluster is fully healthy.
    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(4));
    assert!(!router.snapshot().is_degraded());
    for i in 0..400 {
        match classify(&router, &format!("x{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i)),
            Read::Miss => {} // marooned data died with the shard
            Read::Unavailable => panic!("x{i} unavailable after restore"),
        }
    }
}

#[test]
fn second_failure_after_degraded_scale_still_answers_unavailable() {
    // fail 1 → scale up (bucket 4 joins while degraded; keys migrate
    // onto it) → fail 4.  Keys marooned on the *post-scale* bucket must
    // still answer UNAVAILABLE, never a silent NIL: the marooned record
    // is kept per failure (paired with the engine as of that removal),
    // because an engine frozen at the first failure could never name a
    // bucket that joined afterwards.
    let router = Router::new(local_cluster("dx", 4).unwrap());
    for i in 0..400 {
        router.handle(Request::Put { key: format!("y{i}"), value: val(i) });
    }
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(3));
    assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
    // Which keys physically live on the joining bucket now?
    let on_new: Vec<usize> = {
        let snap = router.snapshot();
        (0..400).filter(|i| snap.route(key_digest(&format!("y{i}"))).0 == 4).collect()
    };
    assert!(!on_new.is_empty(), "scale-up moved nothing onto bucket 4");
    assert_eq!(router.handle(Request::Fail { shard: 4 }), Response::Num(3));
    for &i in &on_new {
        match router.handle(Request::Get { key: format!("y{i}") }) {
            Response::Err(msg) => {
                assert!(msg.starts_with("UNAVAILABLE"), "y{i}: {msg}");
                assert!(msg.contains("shard 4"), "y{i}: wrong marooning shard: {msg}");
            }
            other => panic!("y{i} marooned on the post-scale bucket answered {other:?}"),
        }
    }
    // Everything else still honors the degraded contract.
    for i in (0..400).filter(|i| !on_new.contains(i)) {
        match classify(&router, &format!("y{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i), "y{i} corrupted"),
            Read::Unavailable => {} // marooned on bucket 1
            Read::Miss => panic!("y{i} silently missing while degraded"),
        }
    }
    // Both failures restore independently (any order for dx).
    assert_eq!(router.handle(Request::Restore { shard: 4 }), Response::Num(4));
    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(5));
    assert!(!router.snapshot().is_degraded());
}

#[test]
fn failover_admin_validation() {
    let router = Router::new(local_cluster("memento", 3).unwrap());
    // Out of range.
    assert!(matches!(router.handle(Request::Fail { shard: 9 }), Response::Err(_)));
    // Restore on a healthy cluster.
    match router.handle(Request::Restore { shard: 1 }) {
        Response::Err(msg) => assert!(msg.contains("healthy"), "{msg}"),
        other => panic!("{other:?}"),
    }
    assert_eq!(router.handle(Request::Fail { shard: 0 }), Response::Num(2));
    // Restore of a shard that is not the failed one names the failed set.
    match router.handle(Request::Restore { shard: 1 }) {
        Response::Err(msg) => assert!(msg.contains("failed buckets: 0"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // Double-fail of the same shard.
    assert!(matches!(router.handle(Request::Fail { shard: 0 }), Response::Err(_)));
    // Failing down to the last working shard is refused.
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(1));
    match router.handle(Request::Fail { shard: 2 }) {
        Response::Err(msg) => assert!(msg.contains("last working"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // Nothing above corrupted the topology: restore everything and go.
    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(2));
    assert_eq!(router.handle(Request::Restore { shard: 0 }), Response::Num(3));
    assert!(!router.snapshot().is_degraded());
    assert_eq!(router.events().len(), 4, "2 FAILs + 2 RESTOREs recorded");
}

#[test]
fn failover_drives_over_the_wire() {
    // FAIL/RESTORE are router admin wire ops: drive a full cycle through
    // a real TCP connection (and confirm a shard server rejects them).
    let router = Router::new(local_cluster("dx", 3).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let r = router.clone();
    std::thread::spawn(move || {
        let _ = r.serve(listener);
    });

    let sock = TcpStream::connect(addr).unwrap();
    let mut rd = std::io::BufReader::new(sock.try_clone().unwrap());
    let mut wr = sock;
    proto::write_request(&mut wr, &Request::Put { key: "wk".into(), value: val(1) }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    proto::write_request(&mut wr, &Request::Fail { shard: 1 }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Num(2));
    proto::write_request(&mut wr, &Request::Stats).unwrap();
    match proto::read_response(&mut rd).unwrap() {
        Response::Info(s) => assert!(s.contains("failed=1"), "{s}"),
        other => panic!("{other:?}"),
    }
    proto::write_request(&mut wr, &Request::Restore { shard: 1 }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Num(3));
    proto::write_request(&mut wr, &Request::Get { key: "wk".into() }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(1)));

    // A standalone shard server is not a coordinator.
    let shard = Shard::new(7);
    let slistener = TcpListener::bind("127.0.0.1:0").unwrap();
    let saddr = slistener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = binhash::shard::serve(shard, slistener);
    });
    let c = ShardClient::Remote(RemotePool::new(saddr, 1));
    assert!(matches!(c.call(&Request::Fail { shard: 0 }).unwrap(), Response::Err(_)));
}

#[test]
fn restored_shard_is_isolated_from_its_stale_past() {
    // Regression guard for resurrection-through-restore: values that
    // physically sit on the failed shard (here: we can reach inside the
    // Local handle) must not reappear after RESTORE — the wipe precedes
    // the rejoin.
    let router = Router::new(local_cluster("memento", 3).unwrap());
    let stale_holder = match &router.snapshot().shards[1] {
        ShardClient::Local(s) => s.clone(),
        _ => unreachable!(),
    };
    // Keys owned by bucket 1 under the healthy engine.
    let healthy = by_name("memento", 3).unwrap();
    let owned: Vec<String> = (0..2_000)
        .map(|i| format!("s{i}"))
        .filter(|k| healthy.bucket(key_digest(k)) == 1)
        .take(50)
        .collect();
    assert!(owned.len() >= 10);
    for k in &owned {
        assert_eq!(
            router.handle(Request::Put { key: k.clone(), value: b"pre".to_vec().into() }),
            Response::Ok
        );
    }
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(2));
    // While degraded: delete one, overwrite another (both land on
    // survivors), leave the rest marooned.
    let deleted = &owned[0];
    let overwritten = &owned[1];
    router.handle(Request::Del { key: deleted.clone() });
    assert_eq!(
        router.handle(Request::Put {
            key: overwritten.clone(),
            value: b"post".to_vec().into()
        }),
        Response::Ok
    );
    // The dead shard still physically holds every "pre" value.
    assert_eq!(stale_holder.count(), owned.len() as u64);

    assert_eq!(router.handle(Request::Restore { shard: 1 }), Response::Num(3));
    // The stale copies are gone from the shard map itself...
    assert!(
        stale_holder.get(deleted, key_digest(deleted)).is_none(),
        "wipe left the deleted key's stale value on the restored shard"
    );
    // ...the delete stuck, the overwrite won, the marooned rest are lost
    // (not resurrected with stale data).
    assert_eq!(router.handle(Request::Get { key: deleted.clone() }), Response::Nil);
    assert_eq!(
        router.handle(Request::Get { key: overwritten.clone() }),
        Response::Val(b"post".to_vec().into())
    );
    for k in &owned[2..] {
        assert_eq!(
            router.handle(Request::Get { key: k.clone() }),
            Response::Nil,
            "{k} resurrected stale data through the restore"
        );
    }
}

/// Router with `replication.factor = factor` over in-process shards
/// (`write_mode = "primary"`).
fn replicated_router(name: &str, n: u32, factor: u32) -> Arc<Router> {
    Router::with_replication(
        local_cluster(name, n).unwrap(),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        factor,
        false,
    )
}

#[test]
fn replication_factor_two_serves_every_key_through_a_failure() {
    // THE replication acceptance test: with `replication.factor = 2`, a
    // shard failure loses no data — every key written before the FAIL
    // still answers its value.  Zero UNAVAILABLE, zero silent misses.
    // The identity that makes it cheap: a key's rank-1 replica is
    // derived from the same per-failure engine fork the degraded path
    // routes with, so after FAIL the key's *new* primary already holds
    // the surviving copy and plain routing serves it.
    const KEYS: usize = 500;
    const FAILED: u32 = 2;
    for name in FT_ENGINES {
        let router = replicated_router(name, 5, 2);
        for i in 0..KEYS {
            assert_eq!(
                router.handle(Request::Put { key: format!("r{i}"), value: val(i) }),
                Response::Ok,
                "{name}"
            );
        }
        assert_eq!(
            router.metrics.replica_writes.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
            KEYS as u64,
            "{name}: every PUT fans out exactly one replica write"
        );
        assert_eq!(
            router.metrics.replica_write_failures.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
            0,
            "{name}"
        );
        // Sanity: the keyset exercises the bucket we are about to fail.
        let pre_fail = by_name(name, 5).unwrap();
        let marooned: Vec<usize> = (0..KEYS)
            .filter(|i| pre_fail.bucket(key_digest(&format!("r{i}"))) == FAILED)
            .collect();
        assert!(!marooned.is_empty(), "{name}: keyset never hit bucket {FAILED}");

        assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(4), "{name}");
        for i in 0..KEYS {
            match classify(&router, &format!("r{i}")) {
                Read::Hit(v) => assert_eq!(v, val(i), "{name}: r{i} corrupted"),
                Read::Miss => panic!("{name}: r{i} lost despite replication"),
                Read::Unavailable => panic!("{name}: r{i} UNAVAILABLE despite replication"),
            }
        }
        assert_eq!(
            router.metrics.unavailable.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
            0,
            "{name}: a single failure at factor 2 can never maroon a key"
        );
        // Batched reads honor the same contract.
        match router.handle(Request::MGet { keys: (0..KEYS).map(|i| format!("r{i}")).collect() })
        {
            Response::Multi(subs) => {
                for (i, sub) in subs.iter().enumerate() {
                    assert_eq!(*sub, Response::Val(val(i)), "{name}: batched r{i}");
                }
            }
            other => panic!("{name}: {other:?}"),
        }
        // Restore converges, re-fills the shard, and keeps every answer.
        assert_eq!(
            router.handle(Request::Restore { shard: FAILED }),
            Response::Num(5),
            "{name}"
        );
        assert!(!router.snapshot().is_degraded(), "{name}: restore did not settle");
        assert!(router.shard_count(FAILED).unwrap() > 0, "{name}: restored shard left empty");
        for i in 0..KEYS {
            match classify(&router, &format!("r{i}")) {
                Read::Hit(v) => assert_eq!(v, val(i), "{name}: r{i} after restore"),
                Read::Miss => panic!("{name}: r{i} lost by the restore"),
                Read::Unavailable => panic!("{name}: r{i} unavailable after restore"),
            }
        }
    }
}

#[test]
fn weighted_factor_two_cluster_survives_a_fail_restore_cycle() {
    // The same factor-2 guarantee through the placement stack's weighted
    // layer: a `Weighted<memento>` cluster at 2:1 heterogeneous weights
    // fails its heaviest shard, serves every key from replicas (zero
    // UNAVAILABLE, zero misses), honors deletes while degraded, and the
    // restore converges without resurrecting them.
    use binhash::algorithms::weighted::Weighted;
    const KEYS: usize = 500;
    const FAILED: u32 = 0; // the heavy shard — worst case for replica spread
    const DEL_START: usize = KEYS - 50;
    let weights = [2u32, 1, 1, 2];

    let engine = Weighted::new("memento", &weights, 1).unwrap();
    let shards = (0..weights.len() as u32).map(|i| ShardClient::Local(Shard::new(i))).collect();
    let router = Router::with_replication(
        Cluster::new(Box::new(engine), shards),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        2,
        false,
    );
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("wf{i}"), value: val(i) }),
            Response::Ok
        );
    }
    // Sanity: the keyset exercises the heavy shard we are about to fail.
    let healthy = Weighted::new("memento", &weights, 1).unwrap();
    let marooned: Vec<usize> = (0..KEYS)
        .filter(|i| healthy.bucket(key_digest(&format!("wf{i}"))) == FAILED)
        .collect();
    assert!(!marooned.is_empty(), "keyset never hit the heavy shard");

    assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(3));
    for i in 0..KEYS {
        match classify(&router, &format!("wf{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i), "wf{i} corrupted"),
            Read::Miss => panic!("wf{i} lost despite replication"),
            Read::Unavailable => panic!("wf{i} UNAVAILABLE despite replication"),
        }
    }
    assert_eq!(
        router.metrics.unavailable.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        0,
        "one failure at factor 2 can never maroon a key, weighted or not"
    );
    // Deletes while degraded fan out to every surviving copy...
    for i in DEL_START..KEYS {
        assert_eq!(router.handle(Request::Del { key: format!("wf{i}") }), Response::Ok, "wf{i}");
    }

    assert_eq!(router.handle(Request::Restore { shard: FAILED }), Response::Num(4));
    let snap = router.snapshot();
    assert!(!snap.is_migrating() && !snap.is_degraded(), "restore did not settle");
    assert_eq!(
        snap.engine.as_weighted().unwrap().weights(),
        &weights,
        "restore perturbed the weight table"
    );
    assert!(router.shard_count(FAILED).unwrap() > 0, "restored heavy shard left empty");
    // ...surviving keys answer through the restore, deleted keys stay dead.
    for i in 0..DEL_START {
        match classify(&router, &format!("wf{i}")) {
            Read::Hit(v) => assert_eq!(v, val(i), "wf{i} after restore"),
            Read::Miss => panic!("wf{i} lost by the restore"),
            Read::Unavailable => panic!("wf{i} unavailable after restore"),
        }
    }
    for i in DEL_START..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("wf{i}") }),
            Response::Nil,
            "deleted key wf{i} resurrected by the restore"
        );
    }
}

#[test]
fn put_then_del_while_degraded_answers_nil_not_unavailable() {
    // Regression for the factor-1 degraded-read hole: PUT a key, fail
    // its primary, DEL it while degraded, GET it back.  A factor-1
    // router cannot distinguish "deleted" from "marooned on the dead
    // shard" and answers UNAVAILABLE; with a live replica the router
    // *knows* — the delete reached every surviving copy, so the honest
    // answer is NIL.
    const FAILED: u32 = 1;
    for name in FT_ENGINES {
        let router = replicated_router(name, 4, 2);
        let healthy = by_name(name, 4).unwrap();
        let key = (0..)
            .map(|i| format!("pd{i}"))
            .find(|k| healthy.bucket(key_digest(k)) == FAILED)
            .unwrap();
        assert_eq!(
            router.handle(Request::Put { key: key.clone(), value: val(7) }),
            Response::Ok,
            "{name}"
        );
        assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(3), "{name}");
        // Still served, from the surviving copy...
        assert_eq!(
            router.handle(Request::Get { key: key.clone() }),
            Response::Val(val(7)),
            "{name}"
        );
        // ...deleted while degraded (the delete fans out to replicas)...
        assert_eq!(router.handle(Request::Del { key: key.clone() }), Response::Ok, "{name}");
        // ...and the post-delete read is NIL, not a false UNAVAILABLE.
        assert_eq!(router.handle(Request::Get { key: key.clone() }), Response::Nil, "{name}");
        // A key that never existed answers NIL too: one failure cannot
        // have taken both copies of a factor-2 key (pigeonhole).
        assert_eq!(
            router.handle(Request::Get { key: "pd-never-written".into() }),
            Response::Nil,
            "{name}"
        );
    }
}

#[test]
fn factor_three_reads_fall_back_past_a_torn_copy_and_repair() {
    // factor = 3: copies on the primary and two ranked replicas.  Fail
    // the primary, then simulate a torn fan-out by deleting the rank-1
    // copy straight out of the owning shard's map (the copy a flaky
    // network write never landed).  The degraded read misses its owner,
    // probes the remaining holders, serves the rank-2 copy, and
    // read-repairs it back onto the owner so the next read is direct.
    let router = replicated_router("memento", 5, 3);
    let key = "torn0".to_string();
    let d = key_digest(&key);
    let (p, r1, r2) = {
        let snap = router.snapshot();
        let p = snap.route(d).0;
        let mut reps = Vec::new();
        snap.replicas_into(d, p, &mut reps);
        assert_eq!(reps.len(), 2, "factor 3 must yield two replicas");
        (p, reps[0], reps[1])
    };
    assert_eq!(router.handle(Request::Put { key: key.clone(), value: val(9) }), Response::Ok);
    assert_eq!(router.handle(Request::Fail { shard: p }), Response::Num(4));
    // The degraded owner is the rank-1 replica (the fork identity).
    assert_eq!(router.snapshot().route(d).0, r1, "degraded owner must be the rank-1 replica");
    let owner_shard = match &router.snapshot().shards[r1 as usize] {
        ShardClient::Local(s) => s.clone(),
        _ => unreachable!(),
    };
    assert!(owner_shard.del(&key, d), "rank-1 copy missing before the torn-write simulation");
    // Owner misses → fallback probe finds the rank-2 copy.
    assert_eq!(
        router.handle(Request::Get { key: key.clone() }),
        Response::Val(val(9)),
        "fallback read failed (p={p} r1={r1} r2={r2})"
    );
    assert!(router.metrics.replica_reads.load(Ordering::Relaxed) >= 1); // ord: Relaxed — test-side telemetry read
    assert!(router.metrics.read_repairs.load(Ordering::Relaxed) >= 1); // ord: Relaxed — test-side telemetry read
    // Read repair restored the owner's copy: the next read is a direct
    // hit and the fallback counter stands still.
    assert!(owner_shard.get(&key, d).is_some(), "read repair left the owner empty");
    let before = router.metrics.replica_reads.load(Ordering::Relaxed); // ord: Relaxed — test-side telemetry read
    assert_eq!(router.handle(Request::Get { key: key.clone() }), Response::Val(val(9)));
    assert_eq!(
        router.metrics.replica_reads.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        before,
        "repaired key still reading through the fallback"
    );
}

#[test]
fn restore_converges_by_digest_anti_entropy_below_full_restream() {
    // RESTORE wipes the rejoining shard and re-streams its keyspace from
    // the survivors.  The anti-entropy streams open with one DIGEST
    // exchange per side and skip every (source, stripe) whose digest
    // already matches the wiped destination — for a sparse keyspace most
    // stripes are empty on both sides, so the digest prologue must pay
    // for itself: strictly fewer round-trips than the full re-stream
    // (every stripe of every source scanned).
    const KEYS: usize = 20;
    const FAILED: u32 = 2;
    let router = replicated_router("memento", 5, 2);
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("ae{i}"), value: val(i) }),
            Response::Ok
        );
    }
    assert_eq!(router.handle(Request::Fail { shard: FAILED }), Response::Num(4));
    let rt0 = router.metrics.migration_round_trips.load(Ordering::Relaxed); // ord: Relaxed — test-side telemetry read
    let sk0 = router.metrics.ae_stripes_skipped.load(Ordering::Relaxed); // ord: Relaxed — test-side telemetry read
    assert_eq!(router.handle(Request::Restore { shard: FAILED }), Response::Num(5));
    let rt = router.metrics.migration_round_trips.load(Ordering::Relaxed) - rt0; // ord: Relaxed — test-side telemetry read
    let skipped = router.metrics.ae_stripes_skipped.load(Ordering::Relaxed) - sk0; // ord: Relaxed — test-side telemetry read
    assert!(skipped > 0, "anti-entropy skipped nothing");
    // The digest prologue cost 1 (destination) + `sources` round-trips
    // and saved `skipped` stripe scans, so the full re-stream would have
    // spent `rt - (1 + sources) + skipped`.  `sources` is at most the 4
    // survivors — using the upper bound only strengthens the assertion.
    let sources = 4u64;
    let full_restream = rt - (1 + sources) + skipped;
    assert!(
        rt < full_restream,
        "anti-entropy restore must beat the full re-stream: \
         rt={rt} full={full_restream} skipped={skipped}"
    );
    // And it actually converged: steady state, every key answers, the
    // restored shard holds its keyspace again.
    assert!(!router.snapshot().is_degraded());
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("ae{i}") }),
            Response::Val(val(i)),
            "ae{i} after anti-entropy restore"
        );
    }
    assert!(router.shard_count(FAILED).unwrap() > 0, "restored shard left empty");
}

#[test]
fn snapshot_marooned_matches_engine_view() {
    // The router's UNAVAILABLE contract rests on
    // `PlacementSnapshot::marooned`; sanity-check it against the engine
    // for a live degraded router.
    let router = Router::new(local_cluster("dx", 4).unwrap());
    router.handle(Request::Fail { shard: 3 });
    let snap = router.snapshot();
    let healthy: Box<dyn ConsistentHasher> = by_name("dx", 4).unwrap();
    let mut hits = 0u64;
    for i in 0..2_000u64 {
        let d = key_digest(&format!("mm{i}"));
        let expect = healthy.bucket(d) == 3;
        assert_eq!(snap.marooned(d).is_some(), expect, "digest {d:#x}");
        hits += u64::from(expect);
    }
    assert!(hits > 0);
}
