//! Concurrent-scaling stress: the epoch-snapshot data path must keep every
//! key readable while topology changes are in flight.
//!
//! Reader threads hammer GETs over a fixed keyset while the main thread
//! runs scale-up/scale-down cycles (and, in the failover test, FAIL /
//! RESTORE cycles).  Invariants checked:
//!
//! * no GET ever observes a missing or wrong value (dual-read covers keys
//!   mid-migration; while degraded, a marooned key answers a
//!   distinguishable `UNAVAILABLE` error, never a wrong value);
//! * no request ever routes to a failed shard (its op counter freezes);
//! * epochs only move forward, by exactly one per topology change;
//! * the keyset is fully intact (count + per-key values) after the churn,
//!   and nothing deleted while degraded resurrects after a restore;
//! * replication's write fan-out survives fault injection: a
//!   [`binhash::shard::FlakyShard`] replica drives partial-write (Drop)
//!   and torn-fan-out (AckLost) schedules, and the router's counters,
//!   degraded reads, and delete fan-out stay honest about exactly which
//!   copies exist.
//!
//! Loom-free by design: real threads over the real router, seeded data,
//! bounded cycles.  The flaky schedules are deterministic
//! (`splitmix64(seed ^ call#)`), so the replication fault tests assert
//! per-call outcomes, not statistics.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use binhash::proto::{Request, Response, Value};
use binhash::router::{local_cluster, Router};

const KEYS: usize = 2_000;
const READERS: usize = 4;
const CYCLES: usize = 5;

fn value_for(i: usize) -> Value {
    vec![(i & 0xFF) as u8, ((i >> 8) & 0xFF) as u8, 0x5A].into()
}

#[test]
fn gets_never_fail_during_scale_cycles() {
    let router = Router::new(local_cluster("binomial", 3).unwrap());
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("sk{i}"), value: value_for(i) }),
            Response::Ok
        );
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for t in 0..READERS {
        let router = router.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || -> u64 {
            let mut i = t;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let idx = i % KEYS;
                match router.handle(Request::Get { key: format!("sk{idx}") }) {
                    Response::Val(v) => assert_eq!(v, value_for(idx), "key sk{idx} corrupted"),
                    other => panic!("key sk{idx} unreadable during scaling: {other:?}"),
                }
                i += 7; // co-prime stride: every reader covers the keyset
                reads += 1;
            }
            reads
        }));
    }

    let mut expect_epoch = router.topology().0;
    for _ in 0..CYCLES {
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        let (epoch, n, _) = router.topology();
        assert_eq!(n, 4);
        assert_eq!(epoch, expect_epoch + 1, "epoch must advance by one on scale-up");
        expect_epoch = epoch;

        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        let (epoch, n, _) = router.topology();
        assert_eq!(n, 3);
        assert_eq!(epoch, expect_epoch + 1, "epoch must advance by one on scale-down");
        expect_epoch = epoch;
    }

    stop.store(true, Ordering::Relaxed);
    let mut total_reads = 0u64;
    for handle in readers {
        total_reads += handle.join().expect("a reader thread panicked");
    }
    assert!(total_reads > 0, "readers made no progress");

    // Churn done: the keyset must be exactly intact.
    assert_eq!(router.handle(Request::Count), Response::Num(KEYS as u64));
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("sk{i}") }),
            Response::Val(value_for(i)),
            "key sk{i} lost after scale churn"
        );
    }
    assert!(!router.snapshot().is_migrating());
    assert_eq!(router.topology().0, 2 * CYCLES as u64);
}

#[test]
fn batched_gets_stay_consistent_during_scale_cycles() {
    // Readers hammer MGET keybatches (through per-thread reused scratch,
    // like a real connection) while the main thread cycles
    // scale-up/scale-down.  Every sub-response must be the right value in
    // the right position — keys mid-migration peel off to the dual-read
    // path per key, and a batch must never observe a miss or a torn
    // value.
    use binhash::router::BatchScratch;
    const BATCH: usize = 48;
    let router = Router::new(local_cluster("binomial", 3).unwrap());
    let keys: std::sync::Arc<Vec<String>> =
        std::sync::Arc::new((0..KEYS).map(|i| format!("cb{i}")).collect());
    {
        let values: Vec<Value> = (0..KEYS).map(value_for).collect();
        match router.handle(Request::MPut { keys: (*keys).clone(), values }) {
            Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
            other => panic!("{other:?}"),
        }
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for t in 0..READERS {
        let router = router.clone();
        let stop = stop.clone();
        let keys = keys.clone();
        readers.push(std::thread::spawn(move || -> u64 {
            let mut scratch = BatchScratch::new();
            let mut out = Vec::new();
            let mut start = t * 13;
            let mut batches = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // A wrapping window over the keyset, different per round.
                let lo = start % (KEYS - BATCH);
                let probe = Request::MGet { keys: keys[lo..lo + BATCH].to_vec() };
                let (op, batch) = probe.as_view().into_batch().unwrap();
                router.handle_batch(op, &batch, &mut scratch, &mut out);
                assert_eq!(out.len(), BATCH);
                for (j, sub) in out.iter().enumerate() {
                    let idx = lo + j;
                    match sub {
                        Response::Val(v) => {
                            assert_eq!(*v, value_for(idx), "cb{idx} torn during scaling")
                        }
                        other => panic!("cb{idx} unreadable in a batch: {other:?}"),
                    }
                }
                start += 31; // co-prime stride: windows sweep the keyset
                batches += 1;
            }
            batches
        }));
    }

    for _ in 0..CYCLES {
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
    }
    stop.store(true, Ordering::Relaxed);
    let mut total = 0u64;
    for h in readers {
        total += h.join().expect("a batched reader panicked");
    }
    assert!(total > 0, "batched readers made no progress");

    // Keyset exactly intact, and one clean batched sweep post-churn.
    assert_eq!(router.handle(Request::Count), Response::Num(KEYS as u64));
    match router.handle(Request::MGet { keys: (*keys).clone() }) {
        Response::Multi(subs) => {
            for (i, sub) in subs.iter().enumerate() {
                assert_eq!(*sub, Response::Val(value_for(i)), "cb{i} lost after churn");
            }
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn overwrites_and_deletes_land_correctly_during_migration_window() {
    // PUTs issued while epochs churn must win over any in-flight migration
    // copy of the same key (the copy step is PUTNX and the mid-migration
    // write path retires the old copy), and DELs must stick: the
    // mid-migration delete tombstones the new owner, so a racing
    // migration copy cannot resurrect the key.
    const N: usize = 1_000;
    let router = Router::new(local_cluster("binomial", 2).unwrap());
    for i in 0..N {
        router.handle(Request::Put { key: format!("w{i}"), value: value_for(i) });
    }

    let writer = {
        let router = router.clone();
        std::thread::spawn(move || {
            for i in 0..N / 2 {
                assert_eq!(
                    router.handle(Request::Put {
                        key: format!("w{i}"),
                        value: b"v2".to_vec().into()
                    }),
                    Response::Ok
                );
            }
        })
    };
    let deleter = {
        let router = router.clone();
        std::thread::spawn(move || {
            for i in (N - 100)..N {
                assert_eq!(
                    router.handle(Request::Del { key: format!("w{i}") }),
                    Response::Ok,
                    "delete of w{i} failed during migration"
                );
            }
        })
    };
    for _ in 0..3 {
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(3));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(2));
    }
    writer.join().expect("writer thread panicked");
    deleter.join().expect("deleter thread panicked");

    for i in 0..N / 2 {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Val(b"v2".to_vec().into()),
            "overwrite of w{i} lost during migration"
        );
    }
    for i in N / 2..(N - 100) {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Val(value_for(i)),
            "untouched key w{i} lost during migration"
        );
    }
    for i in (N - 100)..N {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Nil,
            "deleted key w{i} resurrected by a migration copy"
        );
    }
    assert_eq!(router.handle(Request::Count), Response::Num((N - 100) as u64));
}

#[test]
fn weighted_replicated_cluster_converges_through_scale_and_weight_churn() {
    // Weighted<memento> at replication factor 2: a scale cycle and a
    // weight change out-and-back are both incremental migrations through
    // the same epoch machinery, so readers must hold the no-wrong-value
    // contract throughout and the keyset must converge exactly — nothing
    // lost, nothing resurrected.
    use binhash::algorithms::{weighted::Weighted, ConsistentHasher};
    use binhash::cluster::Cluster;
    use binhash::shard::{Shard, ShardClient};

    const DEL_START: usize = KEYS - 200;

    let engine = Weighted::new("memento", &[1, 1, 1, 1], 1).unwrap();
    let shards = (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
    let router = Router::with_replication(
        Cluster::new(Box::new(engine), shards),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        2,
        false,
    );
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("wk{i}"), value: value_for(i) }),
            Response::Ok
        );
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for t in 0..READERS {
        let router = router.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || -> u64 {
            let mut i = t;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let idx = i % DEL_START; // stay clear of the deleted slice
                match router.handle(Request::Get { key: format!("wk{idx}") }) {
                    Response::Val(v) => assert_eq!(v, value_for(idx), "wk{idx} corrupted"),
                    other => panic!("wk{idx} unreadable during weighted churn: {other:?}"),
                }
                i += 7; // co-prime stride: every reader covers the keyset
                reads += 1;
            }
            reads
        }));
    }
    // Deleter: the tail slice must stay dead through every migration.
    let deleter = {
        let router = router.clone();
        std::thread::spawn(move || {
            for i in DEL_START..KEYS {
                match router.handle(Request::Del { key: format!("wk{i}") }) {
                    Response::Ok | Response::Nil => {}
                    other => panic!("delete of wk{i} failed during weighted churn: {other:?}"),
                }
            }
        })
    };

    let epoch0 = router.topology().0;
    // A scale cycle: the joiner arrives at weight 1 and retires cleanly.
    assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
    assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
    // A weight change out and back: interior shard 1 triples, then
    // returns to weight 1 — each step its own incremental migration.
    assert_eq!(router.set_weight(1, 3).unwrap(), 3);
    assert_eq!(router.set_weight(1, 1).unwrap(), 1);
    assert_eq!(router.topology().0, epoch0 + 4, "one epoch per topology change");

    deleter.join().expect("deleter thread panicked");
    stop.store(true, Ordering::Relaxed);
    let mut total = 0u64;
    for h in readers {
        total += h.join().expect("a reader thread panicked");
    }
    assert!(total > 0, "readers made no progress");

    // Converged: steady state, weight table restored, surviving keys
    // intact, deleted slice still dead.
    let snap = router.snapshot();
    assert!(!snap.is_migrating() && !snap.is_degraded());
    assert_eq!(snap.engine.as_weighted().unwrap().weights(), &[1, 1, 1, 1]);
    for i in 0..DEL_START {
        assert_eq!(
            router.handle(Request::Get { key: format!("wk{i}") }),
            Response::Val(value_for(i)),
            "wk{i} lost in weighted churn"
        );
    }
    for i in DEL_START..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("wk{i}") }),
            Response::Nil,
            "deleted key wk{i} resurrected by weighted churn"
        );
    }
}

/// `Shard::stats()` exposes the op counter as `ops=N`; parse it so the
/// test can prove the failed shard's counter *freezes* while degraded.
fn ops_of(shard: &std::sync::Arc<binhash::shard::Shard>) -> u64 {
    let stats = shard.stats();
    stats
        .split("ops=")
        .nth(1)
        .and_then(|t| t.split_whitespace().next())
        .and_then(|t| t.parse().ok())
        .expect("shard stats carries ops=")
}

#[test]
fn failover_under_concurrent_readers_writers_and_deleters() {
    use binhash::shard::ShardClient;

    const FKEYS: usize = 1_200;
    // Slices: A is continuously overwritten, B continuously deleted, C
    // untouched.
    const A_END: usize = 300;
    const B_START: usize = 900;
    const FAILED: u32 = 2;

    let router = Router::new(local_cluster("memento", 4).unwrap());
    for i in 0..FKEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("fk{i}"), value: value_for(i) }),
            Response::Ok
        );
    }
    let failed_shard = match &router.snapshot().shards[FAILED as usize] {
        ShardClient::Local(s) => s.clone(),
        _ => unreachable!("local cluster"),
    };

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    // Readers: a value, when present, is always one the cluster was
    // actually given; a degraded read answers a distinguishable
    // UNAVAILABLE, never a hang, a wrong value, or an alien error.
    for t in 0..3usize {
        let router = router.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || {
            let mut i = t;
            while !stop.load(Ordering::Relaxed) {
                let idx = i % FKEYS;
                match router.handle(Request::Get { key: format!("fk{idx}") }) {
                    Response::Val(v) => {
                        let overwritten = idx < A_END && v.as_ref() == &b"v2"[..];
                        assert!(
                            v == value_for(idx) || overwritten,
                            "fk{idx} read a value nobody wrote: {v:?}"
                        );
                    }
                    // Transiently absent (deleted, or marooned data that
                    // a restore wiped before the writer re-wrote it).
                    Response::Nil => {}
                    Response::Err(msg) => {
                        assert!(
                            msg.starts_with("UNAVAILABLE"),
                            "fk{idx}: unexpected error {msg:?}"
                        );
                    }
                    other => panic!("fk{idx}: {other:?}"),
                }
                i += 7;
            }
        }));
    }
    // Writer: slice A stays durable through failovers — a PUT while
    // degraded lands on a survivor and migrates back on restore.
    {
        let router = router.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for i in 0..A_END {
                    assert_eq!(
                        router.handle(Request::Put {
                            key: format!("fk{i}"),
                            value: b"v2".to_vec().into()
                        }),
                        Response::Ok,
                        "write of fk{i} failed during failover churn"
                    );
                }
            }
        }));
    }
    // Deleter: slice B must stay dead — no migration copy and no restore
    // may resurrect a deleted key.
    {
        let router = router.clone();
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                for i in B_START..FKEYS {
                    match router.handle(Request::Del { key: format!("fk{i}") }) {
                        Response::Ok | Response::Nil => {}
                        other => panic!("delete of fk{i} failed: {other:?}"),
                    }
                }
            }
        }));
    }

    // Two full FAIL → RESTORE cycles under the traffic above.
    for cycle in 0..2 {
        assert_eq!(
            router.handle(Request::Fail { shard: FAILED }),
            Response::Num(3),
            "cycle {cycle}: FAIL"
        );
        // Let requests that raced the publish drain (FAIL deliberately
        // skips the quiesce), then pin the core claim: the failed
        // shard's op counter freezes — no request routes to it.
        // lint_sync: allow — wall-clock settling in a stress test, not
        // product code waiting on another thread.
        #[allow(clippy::disallowed_methods)]
        std::thread::sleep(std::time::Duration::from_millis(60));
        let frozen = ops_of(&failed_shard);
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("state=degraded"), "cycle {cycle}: {s}");
                assert!(s.contains("failed=2"), "cycle {cycle}: {s}");
            }
            other => panic!("{other:?}"),
        }
        for i in (0..FKEYS).step_by(5) {
            let _ = router.handle(Request::Get { key: format!("fk{i}") });
        }
        // lint_sync: allow — wall-clock settling, as above.
        #[allow(clippy::disallowed_methods)]
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert_eq!(
            ops_of(&failed_shard),
            frozen,
            "cycle {cycle}: a request reached the failed shard while degraded"
        );
        assert_eq!(
            router.handle(Request::Restore { shard: FAILED }),
            Response::Num(4),
            "cycle {cycle}: RESTORE"
        );
    }

    stop.store(true, Ordering::Relaxed);
    for w in workers {
        w.join().expect("a worker thread panicked");
    }

    // Converged, healthy end state.
    let snap = router.snapshot();
    assert!(!snap.is_migrating() && !snap.is_degraded());
    assert_eq!(router.topology().0, 4, "two FAIL + two RESTORE epochs");

    // Slice A: one deterministic re-write proves full writability...
    for i in 0..A_END {
        assert_eq!(
            router.handle(Request::Put { key: format!("fk{i}"), value: b"v3".to_vec().into() }),
            Response::Ok
        );
    }
    for i in 0..A_END {
        assert_eq!(
            router.handle(Request::Get { key: format!("fk{i}") }),
            Response::Val(b"v3".to_vec().into()),
            "fk{i} lost after failover churn"
        );
    }
    // ...slice B stayed dead (no resurrection through restore or
    // migration copies)...
    for i in B_START..FKEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("fk{i}") }),
            Response::Nil,
            "deleted key fk{i} resurrected by failover churn"
        );
    }
    // ...and slice C never reads a value nobody wrote (a marooned key
    // wiped by a restore is absent, not corrupted — this router runs
    // factor 1; `replication.factor` ≥ 2 is what survives that loss, see
    // the flaky-replica tests below and tests/failover.rs).
    for i in A_END..B_START {
        match router.handle(Request::Get { key: format!("fk{i}") }) {
            Response::Val(v) => assert_eq!(v, value_for(i), "fk{i} corrupted"),
            Response::Nil => {}
            other => panic!("fk{i}: {other:?}"),
        }
    }
    // The restored shard serves again: it owns ~1/4 of the keyspace.
    assert!(
        router.shard_count(FAILED).unwrap() > 0,
        "restored shard {FAILED} never received keys back"
    );
}

/// Replicated router (`factor = 2`, `write_mode = "primary"`) over a
/// memento/4 cluster whose bucket 3 is the given flaky wrapper and
/// buckets 0–2 are clean locals — the fixture for the fault-injection
/// schedules below.
fn flaky_replica_router(flaky: &Arc<binhash::shard::FlakyShard>) -> Arc<Router> {
    use binhash::shard::{Shard, ShardClient};
    let engine = binhash::algorithms::by_name("memento", 4).unwrap();
    let shards = vec![
        ShardClient::Local(Shard::new(0)),
        ShardClient::Local(Shard::new(1)),
        ShardClient::Local(Shard::new(2)),
        ShardClient::Flaky(flaky.clone()),
    ];
    Router::with_replication(
        binhash::cluster::Cluster::new(engine, shards),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        2,
        false,
    )
}

/// Keys whose primary is bucket 1 and whose rank-1 replica is the flaky
/// bucket 3 — each PUT/DEL sends *exactly one* call to the flaky shard,
/// so flaky call slot `n` belongs to the `n`-th operation.
fn keys_with_flaky_replica(router: &Router, want: usize) -> Vec<String> {
    use binhash::shard::key_digest;
    let healthy = binhash::algorithms::by_name("memento", 4).unwrap();
    let snap = router.snapshot();
    let keys: Vec<String> = (0..100_000)
        .map(|i| format!("tz{i}"))
        .filter(|k| {
            let d = key_digest(k);
            healthy.bucket(d) == 1 && snap.first_replica(d, 1) == Some(3)
        })
        .take(want)
        .collect();
    assert_eq!(keys.len(), want, "keyset never pairs primary 1 with replica 3");
    keys
}

#[test]
fn partial_replica_writes_follow_the_deterministic_drop_schedule() {
    // Drop schedule at 50%: some replica writes vanish before reaching
    // the shard, the rest land.  The router must (a) keep acking the
    // primary-mode PUTs, (b) count exactly the dropped calls as
    // `replica_write_failures`, and (c) after the primary fails, answer
    // each key per its *actual* copy state — value if the copy landed,
    // honest NIL if the torn write lost it (never a false UNAVAILABLE:
    // one failure at factor 2 cannot maroon a key).
    use binhash::hashing::splitmix64;
    use binhash::shard::{FlakyMode, FlakyShard, Shard, ShardClient};
    const SEED: u64 = 0xF1A6;
    const PCT: u64 = 50;
    const N: usize = 40;
    let flaky = FlakyShard::wrap(ShardClient::Local(Shard::new(3)), FlakyMode::Drop, PCT, SEED);
    let router = flaky_replica_router(&flaky);
    let keys = keys_with_flaky_replica(&router, N);
    // The wrapper's schedule is pure: call `n` faults iff
    // `splitmix64(seed ^ n) % 100 < percent` — compute it up front.
    let dropped: Vec<bool> =
        (0..N as u64).map(|n| splitmix64(SEED ^ n) % 100 < PCT).collect();
    assert!(
        dropped.iter().any(|&b| b) && !dropped.iter().all(|&b| b),
        "degenerate schedule: change the seed"
    );

    for (i, k) in keys.iter().enumerate() {
        assert_eq!(
            router.handle(Request::Put { key: k.clone(), value: value_for(i) }),
            Response::Ok,
            "a dropped replica write must not fail the primary-acked PUT ({k})"
        );
    }
    let torn = dropped.iter().filter(|&&b| b).count() as u64;
    assert_eq!((flaky.calls(), flaky.injected()), (N as u64, torn));
    assert_eq!(
        router.metrics.replica_write_failures.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        torn,
        "failures must count exactly the dropped schedule slots"
    );
    // Replica state diverged exactly per schedule: only the landed
    // copies exist on the flaky shard's inner map.
    match flaky.inner() {
        ShardClient::Local(s) => assert_eq!(s.count(), N as u64 - torn),
        _ => unreachable!(),
    }

    // Fail the primary: the degraded owner is the flaky replica.  Each
    // GET consumes one flaky slot (the fallback probe only touches the
    // clean shards), so the per-key outcome is still fully determined.
    assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(3));
    let base = flaky.calls();
    for (j, k) in keys.iter().enumerate() {
        let read_faults = splitmix64(SEED ^ (base + j as u64)) % 100 < PCT;
        let got = router.handle(Request::Get { key: k.clone() });
        if read_faults {
            match got {
                Response::Err(msg) => assert!(msg.contains("injected fault"), "{k}: {msg}"),
                other => panic!("{k}: faulted read answered {other:?}"),
            }
        } else if dropped[j] {
            assert_eq!(got, Response::Nil, "{k}: torn-lost key must read honest NIL");
        } else {
            assert_eq!(got, Response::Val(value_for(j)), "{k}: landed copy lost");
        }
    }
    assert_eq!(
        router.metrics.unavailable.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        0,
        "no UNAVAILABLE below `factor` concurrent failures"
    );
}

#[test]
fn ack_lost_fan_out_diverges_then_delete_fan_out_reconverges() {
    // AckLost at 100%: every replica write LANDS but its ack is lost —
    // the counters say failure while the state says success (the classic
    // torn fan-out).  The divergence must be bounded by the delete
    // fan-out: DELs go to every replica regardless of the primary's
    // answer, so diverged copies cannot outlive their key.
    use binhash::shard::{FlakyMode, FlakyShard, Shard, ShardClient};
    const SEED: u64 = 0xACC;
    const N: usize = 24;
    let flaky =
        FlakyShard::wrap(ShardClient::Local(Shard::new(3)), FlakyMode::AckLost, 100, SEED);
    let router = flaky_replica_router(&flaky);
    let keys = keys_with_flaky_replica(&router, N);

    for (i, k) in keys.iter().enumerate() {
        assert_eq!(
            router.handle(Request::Put { key: k.clone(), value: value_for(i) }),
            Response::Ok,
            "{k}: lost ack must not fail the primary-acked PUT"
        );
    }
    assert_eq!(
        router.metrics.replica_write_failures.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        N as u64,
        "every lost ack counts as a replica write failure"
    );
    // ...yet every write physically landed: counters and state diverge,
    // which is exactly what the wrapper is built to produce.
    match flaky.inner() {
        ShardClient::Local(s) => assert_eq!(s.count(), N as u64, "AckLost must apply writes"),
        _ => unreachable!(),
    }

    // Deletes fan out unconditionally and reconverge the replica even
    // though every delete ack is lost too.
    for k in &keys {
        assert_eq!(router.handle(Request::Del { key: k.clone() }), Response::Ok, "{k}");
    }
    match flaky.inner() {
        ShardClient::Local(s) => {
            assert_eq!(s.count(), 0, "diverged replica copies outlived their keys")
        }
        _ => unreachable!(),
    }
    // Healthy-path reads (primary bucket 1 is alive) confirm NIL without
    // touching the flaky shard.
    let before = flaky.calls();
    for k in &keys {
        assert_eq!(router.handle(Request::Get { key: k.clone() }), Response::Nil, "{k}");
    }
    assert_eq!(flaky.calls(), before, "a healthy-primary read dialed the replica");
    assert_eq!(
        router.metrics.replica_write_failures.load(Ordering::Relaxed), // ord: Relaxed — test-side telemetry read
        2 * N as u64,
        "PUT and DEL fan-outs each counted their lost acks"
    );
}
