//! Concurrent-scaling stress: the epoch-snapshot data path must keep every
//! key readable while topology changes are in flight.
//!
//! Reader threads hammer GETs over a fixed keyset while the main thread
//! runs scale-up/scale-down cycles.  Invariants checked:
//!
//! * no GET ever observes a missing or wrong value (dual-read covers keys
//!   mid-migration);
//! * epochs only move forward, by exactly one per topology change;
//! * the keyset is fully intact (count + per-key values) after the churn.
//!
//! Loom-free by design: real threads over the real router, seeded data,
//! bounded cycles.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use binhash::proto::{Request, Response, Value};
use binhash::router::{local_cluster, Router};

const KEYS: usize = 2_000;
const READERS: usize = 4;
const CYCLES: usize = 5;

fn value_for(i: usize) -> Value {
    vec![(i & 0xFF) as u8, ((i >> 8) & 0xFF) as u8, 0x5A].into()
}

#[test]
fn gets_never_fail_during_scale_cycles() {
    let router = Router::new(local_cluster("binomial", 3).unwrap());
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("sk{i}"), value: value_for(i) }),
            Response::Ok
        );
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut readers = Vec::new();
    for t in 0..READERS {
        let router = router.clone();
        let stop = stop.clone();
        readers.push(std::thread::spawn(move || -> u64 {
            let mut i = t;
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let idx = i % KEYS;
                match router.handle(Request::Get { key: format!("sk{idx}") }) {
                    Response::Val(v) => assert_eq!(v, value_for(idx), "key sk{idx} corrupted"),
                    other => panic!("key sk{idx} unreadable during scaling: {other:?}"),
                }
                i += 7; // co-prime stride: every reader covers the keyset
                reads += 1;
            }
            reads
        }));
    }

    let mut expect_epoch = router.topology().0;
    for _ in 0..CYCLES {
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        let (epoch, n, _) = router.topology();
        assert_eq!(n, 4);
        assert_eq!(epoch, expect_epoch + 1, "epoch must advance by one on scale-up");
        expect_epoch = epoch;

        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        let (epoch, n, _) = router.topology();
        assert_eq!(n, 3);
        assert_eq!(epoch, expect_epoch + 1, "epoch must advance by one on scale-down");
        expect_epoch = epoch;
    }

    stop.store(true, Ordering::Relaxed);
    let mut total_reads = 0u64;
    for handle in readers {
        total_reads += handle.join().expect("a reader thread panicked");
    }
    assert!(total_reads > 0, "readers made no progress");

    // Churn done: the keyset must be exactly intact.
    assert_eq!(router.handle(Request::Count), Response::Num(KEYS as u64));
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("sk{i}") }),
            Response::Val(value_for(i)),
            "key sk{i} lost after scale churn"
        );
    }
    assert!(!router.snapshot().is_migrating());
    assert_eq!(router.topology().0, 2 * CYCLES as u64);
}

#[test]
fn overwrites_and_deletes_land_correctly_during_migration_window() {
    // PUTs issued while epochs churn must win over any in-flight migration
    // copy of the same key (the copy step is PUTNX and the mid-migration
    // write path retires the old copy), and DELs must stick: the
    // mid-migration delete tombstones the new owner, so a racing
    // migration copy cannot resurrect the key.
    const N: usize = 1_000;
    let router = Router::new(local_cluster("binomial", 2).unwrap());
    for i in 0..N {
        router.handle(Request::Put { key: format!("w{i}"), value: value_for(i) });
    }

    let writer = {
        let router = router.clone();
        std::thread::spawn(move || {
            for i in 0..N / 2 {
                assert_eq!(
                    router.handle(Request::Put {
                        key: format!("w{i}"),
                        value: b"v2".to_vec().into()
                    }),
                    Response::Ok
                );
            }
        })
    };
    let deleter = {
        let router = router.clone();
        std::thread::spawn(move || {
            for i in (N - 100)..N {
                assert_eq!(
                    router.handle(Request::Del { key: format!("w{i}") }),
                    Response::Ok,
                    "delete of w{i} failed during migration"
                );
            }
        })
    };
    for _ in 0..3 {
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(3));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(2));
    }
    writer.join().expect("writer thread panicked");
    deleter.join().expect("deleter thread panicked");

    for i in 0..N / 2 {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Val(b"v2".to_vec().into()),
            "overwrite of w{i} lost during migration"
        );
    }
    for i in N / 2..(N - 100) {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Val(value_for(i)),
            "untouched key w{i} lost during migration"
        );
    }
    for i in (N - 100)..N {
        assert_eq!(
            router.handle(Request::Get { key: format!("w{i}") }),
            Response::Nil,
            "deleted key w{i} resurrected by a migration copy"
        );
    }
    assert_eq!(router.handle(Request::Count), Response::Num((N - 100) as u64));
}
