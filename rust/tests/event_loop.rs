//! Event-server coverage: the readiness state machine must be
//! *behaviorally identical* to the blocking `proto::serve_framed` path.
//!
//! Two layers:
//!
//! * **Differential fuzz (no sockets)** — the same byte stream is fed to
//!   `proto::serve_framed` (reference) and to `net::ConnCore` split at
//!   arbitrary read boundaries, with responses collected in arbitrary
//!   write-chunk sizes.  Output bytes and connection fate (clean EOF vs
//!   framing drop) must match exactly — including truncated `MPUT`
//!   payloads cut mid-value.
//! * **Socket tests (Linux)** — a real `net::Server` in event mode:
//!   pipelined roundtrips, `ERR` recovery, backpressure under a
//!   non-reading client (asserting `partial_flushes` and
//!   `deferred_reads` actually moved), a many-connection smoke test,
//!   graceful shutdown, and the shard's event server.

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;

use binhash::hashing::SplitMix64Rng;
use binhash::net::{self, ConnCore, ServeMode, ServerOpts, Service};
use binhash::proto::{self, Request, Response, Value};
use binhash::router::{local_cluster, Router};
use binhash::sync::Arc;

fn val(bytes: &[u8]) -> Value {
    bytes.to_vec().into()
}

/// Fresh deterministic router (3 binomial shards) — both sides of a
/// differential run get their own so state evolves identically.
fn fresh_router() -> Arc<Router> {
    Router::new(local_cluster("binomial", 3).unwrap())
}

/// Reference behavior: run the blocking server over an in-memory stream.
/// Returns (response bytes, clean) where `clean` is false when the
/// connection would be dropped for a framing error.
fn run_blocking(stream: &[u8]) -> (Vec<u8>, bool) {
    let svc = fresh_router();
    let mut st = <Router as Service>::ConnState::default();
    let mut rd = BufReader::new(stream);
    let mut wr = Vec::new();
    // Fully qualified: Router also has an inherent `handle(Request)`.
    let clean = proto::serve_framed(&mut rd, &mut wr, |req, out| {
        Service::handle(&*svc, &mut st, req, out)
    })
    .is_ok();
    (wr, clean)
}

/// Process buffered frames to a fixed point, draining output in
/// `write_chunk`-sized pieces (exercising `out_pos` resumption).  The
/// loop mirrors the server's pump: `process` may stop at the high-water
/// mark, so re-run it each time a drain frees output space.
fn pump<S: Service>(
    core: &mut ConnCore,
    svc: &S,
    st: &mut S::ConnState,
    replies: &mut Vec<u8>,
    write_chunk: usize,
) {
    loop {
        let before = core.in_pending();
        core.process(svc, st);
        while core.out_pending() > 0 {
            let n = core.out_pending().min(write_chunk.max(1));
            replies.extend_from_slice(&core.output()[..n]);
            core.consume_output(n);
        }
        if core.in_pending() == before {
            break;
        }
    }
}

/// Event-path behavior: feed the same stream through a `ConnCore` in
/// `read_chunk`-sized pieces.  Returns (bytes, clean).
fn run_event(stream: &[u8], read_chunk: usize, write_chunk: usize) -> (Vec<u8>, bool) {
    let svc = fresh_router();
    let mut st = <Router as Service>::ConnState::default();
    let mut core = ConnCore::new();
    let mut replies = Vec::new();
    for piece in stream.chunks(read_chunk.max(1)) {
        core.push_input(piece);
        pump(&mut core, &*svc, &mut st, &mut replies, write_chunk);
    }
    core.finish_input(&*svc, &mut st);
    pump(&mut core, &*svc, &mut st, &mut replies, write_chunk);
    (replies, !core.is_broken())
}

/// Assert both personalities agree on `stream` for a spread of read and
/// write chunk sizes.
fn assert_differential(stream: &[u8], label: &str) {
    let (want, want_clean) = run_blocking(stream);
    let mut chunks = vec![1, 2, 3, 5, 7, 16, 64, 1024];
    chunks.push(stream.len().max(1));
    for &rc in &chunks {
        for &wc in &[1usize, 9, 4096] {
            let (got, got_clean) = run_event(stream, rc, wc);
            assert_eq!(
                got, want,
                "{label}: output diverged at read_chunk={rc} write_chunk={wc}"
            );
            assert_eq!(
                got_clean, want_clean,
                "{label}: connection fate diverged at read_chunk={rc} write_chunk={wc}"
            );
        }
    }
}

#[test]
fn differential_pipelined_singletons() {
    let mut s = Vec::new();
    proto::write_request(&mut s, &Request::Put { key: "a".into(), value: val(b"alpha\n\x00!") })
        .unwrap();
    proto::write_request(&mut s, &Request::Get { key: "a".into() }).unwrap();
    proto::write_request(&mut s, &Request::Get { key: "missing".into() }).unwrap();
    proto::write_request(&mut s, &Request::Count).unwrap();
    proto::write_request(&mut s, &Request::Del { key: "a".into() }).unwrap();
    assert_differential(&s, "pipelined singletons");
}

#[test]
fn differential_batches_and_recoverable_errors() {
    let mut s = Vec::new();
    proto::write_request(
        &mut s,
        &Request::MPut {
            keys: vec!["w0".into(), "w1".into(), "w2".into()],
            values: vec![val(b"a"), val(b"value with\nnewline"), val(&[0u8; 300])],
        },
    )
    .unwrap();
    s.extend_from_slice(b"MGET 99 onlyone\n"); // recoverable: ERR, keep conn
    proto::write_request(&mut s, &Request::MGet { keys: vec!["w1".into(), "nope".into()] })
        .unwrap();
    s.extend_from_slice(b"NONSENSE gibberish\n"); // recoverable
    proto::write_request(&mut s, &Request::MDel { keys: vec!["w0".into(), "w2".into()] }).unwrap();
    assert_differential(&s, "batches + recoverable errors");
}

#[test]
fn differential_truncated_mput_mid_value() {
    // A full MPUT frame, then the same frame cut mid-second-value: the
    // blocking path answers the first frame and errors on the second;
    // the event path must do exactly the same.
    let mut frame = Vec::new();
    proto::write_request(
        &mut frame,
        &Request::MPut {
            keys: vec!["k0".into(), "k1".into()],
            values: vec![val(b"0123456789"), val(b"abcdefghij")],
        },
    )
    .unwrap();
    let mut s = frame.clone();
    s.extend_from_slice(&frame[..frame.len() - 4]); // lose 4 payload bytes
    assert_differential(&s, "truncated MPUT mid-value");
}

#[test]
fn differential_unterminated_tail_and_framing_drops() {
    // Unterminated final line: read_line returns it without the newline.
    assert_differential(b"GET x\nCOUNT", "unterminated COUNT tail");
    // Unterminated PUT header announcing a payload EOF can't deliver.
    assert_differential(b"COUNT\nPUT k 5", "unterminated PUT header");
    // Oversized announced length: framing drop on both paths.
    assert_differential(b"COUNT\nPUT k 999999999999\n", "oversized length");
    // Bad key *before* a huge length: recoverable (key token is checked
    // first), connection stays up on both paths.
    assert_differential(b"PUT bad\x01key 999999999999\nCOUNT\n", "bad key precedes bad length");
}

#[test]
fn differential_fuzz_random_streams_and_boundaries() {
    let mut rng = SplitMix64Rng::new(0x5EED_CAFE);
    let commands: Vec<Vec<u8>> = {
        let mut c = Vec::new();
        let mut buf = Vec::new();
        let reqs = [
            Request::Put { key: "k1".into(), value: val(b"v1") },
            Request::Put { key: "k2".into(), value: val(&[7u8; 200]) },
            Request::Get { key: "k1".into() },
            Request::Get { key: "k2".into() },
            Request::Del { key: "k1".into() },
            // (no Stats here: its INFO line embeds wall-clock latency
            // quantiles, which can never be byte-identical across runs)
            Request::Count,
            Request::MGet { keys: vec!["k1".into(), "k2".into(), "zz".into()] },
            Request::MPut {
                keys: vec!["m0".into(), "m1".into()],
                values: vec![val(b"x"), val(b"yy\nzz")],
            },
            Request::MDel { keys: vec!["m0".into(), "k2".into()] },
        ];
        for r in &reqs {
            buf.clear();
            proto::write_request(&mut buf, r).unwrap();
            c.push(buf.clone());
        }
        c.push(b"MGET 99 onlyone\n".to_vec()); // recoverable parse error
        c.push(b"BOGUS\n".to_vec()); // recoverable parse error
        c
    };
    for round in 0..40 {
        // Random pipeline of 1..=8 commands, optionally truncated.
        let mut stream = Vec::new();
        let n = 1 + (rng.next_u64() as usize) % 8;
        for _ in 0..n {
            stream.extend_from_slice(&commands[(rng.next_u64() as usize) % commands.len()]);
        }
        if rng.next_u64() % 4 == 0 && !stream.is_empty() {
            let cut = 1 + (rng.next_u64() as usize) % stream.len();
            stream.truncate(cut);
        }
        let (want, want_clean) = run_blocking(&stream);
        for _ in 0..4 {
            let rc = 1 + (rng.next_u64() as usize) % 97;
            let wc = 1 + (rng.next_u64() as usize) % 33;
            let (got, got_clean) = run_event(&stream, rc, wc);
            assert_eq!(got, want, "round {round}: output diverged (rc={rc} wc={wc})");
            assert_eq!(got_clean, want_clean, "round {round}: fate diverged (rc={rc} wc={wc})");
        }
    }
}

// ---------------------------------------------------------------------
// Socket-level tests of the real event server (Linux readiness loops;
// elsewhere Server falls back to blocking and these still pass).
// ---------------------------------------------------------------------

/// Spawn a router event server; returns (addr, handle, server thread).
fn spawn_event_router(
    opts: ServerOpts,
) -> (std::net::SocketAddr, Arc<Router>, net::ServerHandle, thread::JoinHandle<anyhow::Result<()>>) {
    let router = fresh_router();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = Arc::clone(&router).server(listener, opts).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = thread::spawn(move || server.run());
    (addr, router, handle, join)
}

fn connect(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let sock = TcpStream::connect(addr).unwrap();
    sock.set_nodelay(true).unwrap();
    (BufReader::new(sock.try_clone().unwrap()), sock)
}

#[test]
fn event_server_roundtrips_pipelined_bursts_and_recovers_from_err() {
    let (addr, _router, handle, join) = spawn_event_router(ServerOpts::default());
    let (mut rd, mut wr) = connect(addr);

    let mut burst = Vec::new();
    proto::write_request(&mut burst, &Request::Put { key: "a".into(), value: val(b"1") }).unwrap();
    proto::write_request(
        &mut burst,
        &Request::MPut {
            keys: vec!["b".into(), "c".into()],
            values: vec![val(b"2"), val(b"3\nwith newline")],
        },
    )
    .unwrap();
    proto::write_request(&mut burst, &Request::Get { key: "a".into() }).unwrap();
    burst.extend_from_slice(b"MGET 99 onlyone\n"); // ERR, connection survives
    proto::write_request(&mut burst, &Request::MGet { keys: vec!["c".into(), "nope".into()] })
        .unwrap();
    wr.write_all(&burst).unwrap();
    wr.flush().unwrap();

    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    assert_eq!(
        proto::read_response(&mut rd).unwrap(),
        Response::Multi(vec![Response::Ok, Response::Ok])
    );
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"1")));
    assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
    assert_eq!(
        proto::read_response(&mut rd).unwrap(),
        Response::Multi(vec![Response::Val(val(b"3\nwith newline")), Response::Nil])
    );

    // STATS now reports the connection counters.
    proto::write_request(&mut wr, &Request::Stats).unwrap();
    match proto::read_response(&mut rd).unwrap() {
        Response::Info(s) => {
            assert!(s.contains("conns_accepted="), "STATS missing conn counters: {s}")
        }
        other => panic!("expected INFO, got {other:?}"),
    }

    drop((rd, wr));
    handle.stop();
    join.join().unwrap().unwrap();
}

#[test]
fn event_server_applies_backpressure_and_resumes_partial_flushes() {
    let (addr, router, handle, join) = spawn_event_router(ServerOpts::default());
    let (mut rd, mut wr) = connect(addr);

    // Seed one 64 KiB value, then pipeline several hundred GETs for it
    // WITHOUT reading any responses: ~19 MiB of replies swamp both the
    // socket buffers and the 256 KiB high-water mark, forcing partial
    // flushes (EWOULDBLOCK) and read-interest deferrals.
    let big = vec![0xABu8; 64 << 10];
    proto::write_request(&mut wr, &Request::Put { key: "big".into(), value: val(&big) }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);

    const GETS: usize = 300;
    let mut burst = Vec::new();
    for _ in 0..GETS {
        proto::write_request(&mut burst, &Request::Get { key: "big".into() }).unwrap();
    }
    wr.write_all(&burst).unwrap();
    wr.flush().unwrap();

    // Now read everything back; every reply must be the full value.
    for i in 0..GETS {
        match proto::read_response(&mut rd).unwrap() {
            Response::Val(v) => assert_eq!(v.len(), big.len(), "reply {i} truncated"),
            other => panic!("reply {i}: expected VAL, got {other:?}"),
        }
    }

    if cfg!(target_os = "linux") {
        use binhash::sync::Ordering;
        assert!(
            router.conns.partial_flushes.load(Ordering::Relaxed) > 0, // ord: test-only
            "a 19 MiB un-read response stream never hit EWOULDBLOCK?"
        );
        assert!(
            router.conns.deferred_reads.load(Ordering::Relaxed) > 0, // ord: test-only
            "pending output never crossed the high-water mark?"
        );
    }

    drop((rd, wr));
    handle.stop();
    join.join().unwrap().unwrap();
}

#[test]
fn event_server_sustains_hundreds_of_idle_connections() {
    let (addr, router, handle, join) = spawn_event_router(ServerOpts::default());

    // Open a pile of idle connections, then work through a hot subset.
    let idle: Vec<TcpStream> = (0..300).map(|_| TcpStream::connect(addr).unwrap()).collect();
    let (mut rd, mut wr) = connect(addr);
    proto::write_request(&mut wr, &Request::Put { key: "k".into(), value: val(b"v") }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    for _ in 0..100 {
        proto::write_request(&mut wr, &Request::Get { key: "k".into() }).unwrap();
    }
    for _ in 0..100 {
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"v")));
    }
    {
        use binhash::sync::Ordering;
        assert!(
            router.conns.accepted.load(Ordering::Relaxed) >= 301, // ord: test-only
            "accept counter missed connections"
        );
    }

    drop(idle);
    drop((rd, wr));
    handle.stop();
    join.join().unwrap().unwrap();
}

#[test]
fn event_server_max_conns_drops_over_cap() {
    let opts = ServerOpts { max_conns: 2, ..ServerOpts::default() };
    let (addr, router, handle, join) = spawn_event_router(opts);

    // Two conns fit; a storm of extras must be dropped (closed), and the
    // survivors keep working.
    let (mut rd, mut wr) = connect(addr);
    let (mut rd2, mut wr2) = connect(addr);
    proto::write_request(&mut wr, &Request::Count).unwrap();
    assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Num(_)));

    let extras: Vec<TcpStream> = (0..20).map(|_| TcpStream::connect(addr).unwrap()).collect();
    // A dropped connection reads EOF; give the server a moment by doing
    // useful work on the surviving conn first.
    proto::write_request(&mut wr2, &Request::Count).unwrap();
    assert!(matches!(proto::read_response(&mut rd2).unwrap(), Response::Num(_)));
    let mut saw_eof = false;
    for extra in extras {
        extra.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 1];
        if matches!((&extra).read(&mut buf), Ok(0)) {
            saw_eof = true;
            break;
        }
    }
    assert!(saw_eof, "no over-cap connection was dropped");
    {
        use binhash::sync::Ordering;
        assert!(
            router.conns.dropped.load(Ordering::Relaxed) > 0, // ord: test-only
            "dropped counter never moved"
        );
    }

    drop((rd, wr, rd2, wr2));
    handle.stop();
    join.join().unwrap().unwrap();
}

#[test]
fn graceful_stop_drains_inflight_connections() {
    let (addr, _router, handle, join) = spawn_event_router(ServerOpts::default());
    let (mut rd, mut wr) = connect(addr);
    proto::write_request(&mut wr, &Request::Put { key: "k".into(), value: val(b"v") }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);

    handle.stop();
    join.join().unwrap().unwrap();

    // The server is gone: the open connection reads EOF once drained.
    let mut rest = Vec::new();
    rd.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "unexpected trailing bytes after drain: {rest:?}");

    // stop() is idempotent.
    handle.stop();
}

#[test]
fn blocking_mode_server_roundtrips_and_stops() {
    let opts = ServerOpts { mode: ServeMode::Blocking, ..ServerOpts::default() };
    let (addr, _router, handle, join) = spawn_event_router(opts);
    let (mut rd, mut wr) = connect(addr);
    proto::write_request(&mut wr, &Request::Put { key: "b".into(), value: val(b"9") }).unwrap();
    proto::write_request(&mut wr, &Request::Get { key: "b".into() }).unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"9")));
    drop((rd, wr));
    handle.stop();
    join.join().unwrap().unwrap();
}

#[test]
fn shard_event_server_roundtrips() {
    use binhash::shard::{self, Shard};
    let shard = Shard::new(0);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = shard::server(shard, listener, ServerOpts::default()).unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = thread::spawn(move || server.run());

    let (mut rd, mut wr) = connect(addr);
    proto::write_request(&mut wr, &Request::Put { key: "s".into(), value: val(b"shard") })
        .unwrap();
    proto::write_request(&mut wr, &Request::Get { key: "s".into() }).unwrap();
    proto::write_request(
        &mut wr,
        &Request::MGet { keys: vec!["s".into(), "absent".into()] },
    )
    .unwrap();
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"shard")));
    assert_eq!(
        proto::read_response(&mut rd).unwrap(),
        Response::Multi(vec![Response::Val(val(b"shard")), Response::Nil])
    );

    drop((rd, wr));
    handle.stop();
    join.join().unwrap().unwrap();
}
