//! Deterministic-schedule model checks over the lock-free data plane
//! (`cargo test --release --features model --test model`).
//!
//! Each test drives real product code — `sync::cell::SnapshotCell`, the
//! shard's striped tombstone semantics, the router's fail→scale→fail
//! machinery — through adversarial thread interleavings chosen by the
//! explorer in `sync::model`.  A failure prints the schedule seed (or
//! the exact choice trace) and a ready-to-paste replay command; see the
//! `binhash::sync` module docs for the `MODEL_SEED` / `MODEL_TRACE` /
//! `MODEL_SCHEDULES` / `MODEL_MAX_STEPS` protocol.
//!
//! The two historical races are pinned as regressions:
//!
//! * **PR 3, pre-swap reader ticket race** — a snapshot reader that had
//!   loaded the raw pointer but not yet bumped its strong count could be
//!   raced by a publisher reclaiming the superseded snapshot.  Pinned
//!   via a *simulated-reclamation twin* of the protocol (no real frees,
//!   so the broken variant is UB-free and its use-after-reclaim is a
//!   plain assertion) — the explorer must find the race in the ungated
//!   twin and must never find it in the gated one.
//! * **PR 4, fail→scale→fail marooned-record bug** — scaling while
//!   degraded used to drop the maroon records of an earlier failure, so
//!   reads of lost keys answered `NIL` (silent data loss) instead of
//!   `UNAVAILABLE`.  Pinned by sweeping a named seed window over the
//!   full fail→scale→fail sequence with a concurrent reader.
#![cfg(feature = "model")]

use binhash::proto::{Request, Response, Value};
use binhash::router::{local_cluster, Router};
use binhash::shard::{key_digest, Shard};
use binhash::sync::cell::SnapshotCell;
use binhash::sync::model::{self, spawn};
use binhash::sync::{spin_yield, Arc, AtomicBool, AtomicI64, AtomicU64, Ordering};

/// Payload whose integrity a torn or use-after-reclaim read would break.
struct Versioned {
    version: u64,
    shadow: u64,
}

impl Versioned {
    fn new(version: u64) -> Self {
        Self { version, shadow: version.wrapping_mul(7).wrapping_add(13) }
    }

    fn check(&self) {
        assert_eq!(
            self.shadow,
            self.version.wrapping_mul(7).wrapping_add(13),
            "torn snapshot read: version {} with foreign shadow {}",
            self.version,
            self.shadow
        );
    }
}

fn val(bytes: &[u8]) -> Value {
    bytes.to_vec().into()
}

// ---------------------------------------------------------------------
// SnapshotCell: the publish/read gate
// ---------------------------------------------------------------------

/// Acceptance criterion: ≥ 10,000 *distinct* schedules of the
/// publish/read gate, all upholding: no torn read across a publish, no
/// stale regression within a reader, no use-after-reclaim (the drop
/// ledger must balance exactly), and completion within the step budget
/// (no starvation in the parity drain).
#[test]
fn gate_explores_10k_distinct_schedules() {
    use std::sync::atomic::AtomicU64 as RawU64;
    let distinct = model::explore("snapshot-gate", 12_000, || {
        let drops = Arc::new(RawU64::new(0));
        struct Tracked {
            v: Versioned,
            drops: Arc<RawU64>,
        }
        impl Drop for Tracked {
            fn drop(&mut self) {
                self.drops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
        let cell = Arc::new(SnapshotCell::new(Tracked {
            v: Versioned::new(0),
            drops: Arc::clone(&drops),
        }));
        let writer = {
            let cell = Arc::clone(&cell);
            let drops = Arc::clone(&drops);
            spawn(move || {
                for ver in 1..=2 {
                    drop(cell.store(Tracked { v: Versioned::new(ver), drops: Arc::clone(&drops) }));
                }
            })
        };
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let cell = Arc::clone(&cell);
                spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..2 {
                        let snap = cell.load();
                        snap.v.check();
                        assert!(
                            snap.v.version >= last,
                            "reader saw version {} after {last}",
                            snap.v.version
                        );
                        last = snap.v.version;
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(cell.load().v.version, 2, "final load must see the last store");
        // Use-after-reclaim / leak ledger: with all reader handles
        // dropped, exactly the two superseded versions are gone...
        assert_eq!(drops.load(std::sync::atomic::Ordering::Relaxed), 2);
        drop(cell);
        // ...and dropping the cell reclaims the final one, exactly once.
        assert_eq!(drops.load(std::sync::atomic::Ordering::Relaxed), 3);
    });
    assert!(
        distinct >= 10_000,
        "expected ≥ 10,000 distinct gate schedules, explored {distinct}"
    );
}

/// Bounded-exhaustive sweep of the smallest interesting op count: one
/// store racing one load.  Every schedule in the (capped) space must
/// uphold the gate invariants.
#[test]
fn gate_exhaustive_one_store_one_load() {
    let runs = model::explore_exhaustive("snapshot-gate-exhaustive", 20_000, || {
        let cell = Arc::new(SnapshotCell::new(Versioned::new(0)));
        let writer = {
            let cell = Arc::clone(&cell);
            spawn(move || {
                drop(cell.store(Versioned::new(1)));
            })
        };
        let snap = cell.load();
        snap.check();
        assert!(snap.version <= 1);
        writer.join().unwrap();
        assert_eq!(cell.load().version, 1);
    });
    assert!(runs > 10, "exhaustive search degenerated to {runs} schedules");
}

/// Parity-drain liveness: three readers hammer `load` while the writer
/// publishes three generations.  Readers arriving during a drain land
/// in the other parity slot, so neither side can starve the other —
/// every explored schedule must complete within the step budget (the
/// budget abort *is* the starvation detector).
#[test]
fn gate_parity_drain_starves_nobody() {
    model::explore("gate-parity-drain", 2_000, || {
        let cell = Arc::new(SnapshotCell::new(Versioned::new(0)));
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let cell = Arc::clone(&cell);
                spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..3 {
                        let snap = cell.load();
                        snap.check();
                        assert!(snap.version >= last);
                        last = snap.version;
                    }
                })
            })
            .collect();
        for ver in 1..=3 {
            drop(cell.store(Versioned::new(ver)));
        }
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(cell.load().version, 3);
    });
}

// ---------------------------------------------------------------------
// Shard: tombstone vs. PUTNX resurrection, purge ordering
// ---------------------------------------------------------------------

/// A mid-migration `DELTOMB` must beat the migration's `PUTNX` copy in
/// *every* interleaving: whichever order the stripe lock grants, the
/// key stays dead until the tombstones are purged at settle — after
/// which fresh writes are admitted again.
#[test]
fn tombstone_bars_putnx_resurrection_under_all_schedules() {
    let runs = model::explore_exhaustive("deltomb-vs-putnx", 20_000, || {
        let shard = Shard::new(0);
        let digest = key_digest("k");
        shard.put("k", val(b"old"), digest);
        // The migration copier read "old" from the source and now races
        // the client's delete to the destination stripe.
        let copier = {
            let shard = Arc::clone(&shard);
            spawn(move || shard.put_nx("k", val(b"old"), key_digest("k")))
        };
        let existed = shard.del_tomb("k", digest);
        let copied = copier.join().unwrap();
        assert!(existed, "the client delete must observe the stored key");
        assert!(!copied, "PUTNX must refuse: the key is live or tombstoned in every order");
        assert_eq!(
            shard.get("k", digest).map(|v| v.to_vec()),
            None,
            "DELTOMB'd key resurrected by a migration PUTNX"
        );
        // Purge ordering: only the settle-phase purge ends the
        // tombstone's veto; a later (post-migration) write is admitted.
        assert_eq!(shard.purge_tombstones(), 1);
        assert!(shard.put_nx("k", val(b"new"), digest), "post-settle write must be admitted");
    });
    assert!(runs > 10, "exhaustive search degenerated to {runs} schedules");
}

// ---------------------------------------------------------------------
// Regression: PR 3 pre-swap reader ticket race (simulated reclamation)
// ---------------------------------------------------------------------

/// Named seed window for the PR 3 regression: seeds are probed in fixed
/// order from this base, so the first failing seed is stable across
/// runs and machines — a *named* schedule without shipping a trace file.
const PR3_SEED_BASE: u64 = 0xB1A0_0003;

/// Simulated-reclamation twin of the snapshot gate.  Versions are small
/// integers; a side table of reader refcounts and reclaimed flags
/// stands in for `Arc` reclamation.  Because nothing is really freed,
/// the *broken* (pre-PR 3, ungated) protocol is UB-free here and its
/// use-after-reclaim shows up as a deterministic assertion instead of
/// heap corruption.
struct SimCell {
    cur: AtomicU64,
    generation: AtomicU64,
    gate: [AtomicU64; 2],
    rc: Vec<AtomicI64>,
    reclaimed: Vec<AtomicBool>,
    gated: bool,
}

impl SimCell {
    fn new(gated: bool, versions: usize) -> Self {
        Self {
            cur: AtomicU64::new(0),
            generation: AtomicU64::new(0),
            gate: [AtomicU64::new(0), AtomicU64::new(0)],
            rc: (0..versions).map(|_| AtomicI64::new(0)).collect(),
            reclaimed: (0..versions).map(|_| AtomicBool::new(false)).collect(),
            gated,
        }
    }

    /// Reader: pin the current version (refcount bump), assert it was
    /// not reclaimed in the load→bump window, unpin.
    fn read(&self) {
        if self.gated {
            loop {
                let gen = self.generation.load(Ordering::SeqCst);
                let slot = &self.gate[(gen & 1) as usize];
                slot.fetch_add(1, Ordering::SeqCst);
                if self.generation.load(Ordering::SeqCst) == gen {
                    let v = self.cur.load(Ordering::SeqCst) as usize;
                    self.rc[v].fetch_add(1, Ordering::SeqCst);
                    assert!(
                        !self.reclaimed[v].load(Ordering::SeqCst),
                        "use-after-reclaim: version {v} reclaimed inside the reader's \
                         load-then-bump window"
                    );
                    slot.fetch_sub(1, Ordering::SeqCst);
                    self.rc[v].fetch_sub(1, Ordering::SeqCst);
                    return;
                }
                slot.fetch_sub(1, Ordering::SeqCst);
            }
        } else {
            // The PR 3 bug: no reader gate — the publisher cannot see a
            // reader that has loaded `cur` but not yet bumped `rc`.
            let v = self.cur.load(Ordering::SeqCst) as usize;
            self.rc[v].fetch_add(1, Ordering::SeqCst);
            assert!(
                !self.reclaimed[v].load(Ordering::SeqCst),
                "use-after-reclaim: version {v} reclaimed inside the reader's \
                 load-then-bump window"
            );
            self.rc[v].fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Publisher: swap to `new`, (if gated) drain the superseded parity
    /// slot, wait for pinned readers, then reclaim the old version.
    fn publish(&self, new: u64) {
        let old = self.cur.swap(new, Ordering::SeqCst) as usize;
        let gen = self.generation.fetch_add(1, Ordering::SeqCst);
        if self.gated {
            let slot = &self.gate[(gen & 1) as usize];
            while slot.load(Ordering::SeqCst) != 0 {
                spin_yield();
            }
        }
        while self.rc[old].load(Ordering::SeqCst) != 0 {
            spin_yield();
        }
        self.reclaimed[old].store(true, Ordering::SeqCst);
    }
}

fn sim_body(gated: bool) {
    let cell = Arc::new(SimCell::new(gated, 3));
    let reader = {
        let cell = Arc::clone(&cell);
        spawn(move || {
            cell.read();
            cell.read();
        })
    };
    cell.publish(1);
    cell.publish(2);
    reader.join().unwrap();
}

#[test]
fn regression_pr3_preswap_reader_ticket_race() {
    // 1. The ungated protocol must exhibit the race within the named
    //    seed window (fixed probe order → the found seed is stable).
    let mut named = None;
    for i in 0..400 {
        let seed = PR3_SEED_BASE + i;
        if let Err(f) = model::try_seed(seed, 10_000, &|| sim_body(false)) {
            assert!(f.msg.contains("use-after-reclaim"), "unexpected failure: {}", f.msg);
            named = Some((seed, f.trace));
            break;
        }
    }
    let (seed, trace) =
        named.expect("ungated twin must exhibit the PR 3 race within the seed window");

    // 2. Deterministic replay: the named seed fails identically, and
    //    the recorded choice trace reproduces it without the seed.
    let f = model::try_seed(seed, 10_000, &|| sim_body(false))
        .expect_err("named seed must replay deterministically");
    assert!(f.msg.contains("use-after-reclaim"));
    assert_eq!(f.trace, trace, "replayed schedule diverged from the recorded one");
    let f = model::replay_trace(&trace, 10_000, &|| sim_body(false))
        .expect_err("recorded trace must reproduce the failure");
    assert!(f.msg.contains("use-after-reclaim"));

    // 3. The gated (PR 3-fixed) protocol survives the named seed and
    //    the entire window.
    for i in 0..400 {
        if let Err(f) = model::try_seed(PR3_SEED_BASE + i, 10_000, &|| sim_body(true)) {
            panic!("gated protocol failed under seed {}: {f}", PR3_SEED_BASE + i);
        }
    }
}

// ---------------------------------------------------------------------
// Regression: PR 4 fail→scale→fail marooned-record bug (full router)
// ---------------------------------------------------------------------

/// Named seed window for the PR 4 regression sweep.
const PR4_SEED_BASE: u64 = 0xB1A0_0004;

/// Keys written before any failure.  Every read — concurrent with the
/// fail→scale→fail sequence or after it — must answer either the
/// correct value or a distinguishable `UNAVAILABLE`; `NIL` (the PR 4
/// symptom: silent loss of the maroon record) and wrong values are
/// schedule bugs.
fn check_read(key: &str, expect: &[u8], resp: Response) {
    match resp {
        Response::Val(v) => {
            assert_eq!(&v[..], expect, "misrouted read: key {key} answered a wrong value")
        }
        Response::Err(m) => {
            assert!(m.contains("UNAVAILABLE"), "key {key}: unexpected error {m:?}")
        }
        Response::Nil => panic!(
            "key {key} answered NIL: marooned record lost across fail→scale→fail (PR 4 bug)"
        ),
        other => panic!("key {key}: unexpected response {other:?}"),
    }
}

fn fail_scale_fail_body() {
    let router = Router::new(local_cluster("dx", 3).unwrap());
    let keys: Vec<String> = (0..8).map(|i| format!("k{i}")).collect();
    for (i, k) in keys.iter().enumerate() {
        assert_eq!(
            router.handle(Request::Put { key: k.clone(), value: val(&[i as u8]) }),
            Response::Ok
        );
    }
    // Concurrent reader races the whole admin sequence.
    let reader = {
        let router = Arc::clone(&router);
        let keys = keys.clone();
        spawn(move || {
            for (i, k) in keys.iter().enumerate() {
                check_read(k, &[i as u8], router.handle(Request::Get { key: k.clone() }));
            }
        })
    };
    router.fail_shard(0).expect("dx tolerates arbitrary failure");
    router.scale_up().expect("dx grows at its frontier while degraded");
    router.fail_shard(1).expect("dx tolerates a second failure");
    reader.join().unwrap();
    // Post-sequence sweep: the maroon records of *both* failures must
    // have survived the interleaved scale.
    for (i, k) in keys.iter().enumerate() {
        check_read(k, &[i as u8], router.handle(Request::Get { key: k.clone() }));
    }
}

#[test]
fn regression_pr4_fail_scale_fail_keeps_maroon_records() {
    // Full-router bodies are big (hundreds of decision points), so the
    // sweep is a fixed named-seed window rather than explore()'s
    // default volume; MODEL_SEED/MODEL_TRACE replay still applies via
    // try_seed determinism.
    for i in 0..150 {
        let seed = PR4_SEED_BASE + i;
        if let Err(f) = model::try_seed(seed, 200_000, &fail_scale_fail_body) {
            panic!("fail→scale→fail violated the maroon contract under seed {seed}: {f}");
        }
    }
}

// ---------------------------------------------------------------------
// PR 8: replicated failover under concurrent readers (full router)
// ---------------------------------------------------------------------

/// Named seed window for the PR 8 replication sweep.
const PR8_SEED_BASE: u64 = 0xB1A0_0008;

/// Coverage statement: replication adds **no new lock-free protocol**.
/// The `ReplicaMap` is immutable state carried by the same
/// `PlacementSnapshot` published through the same `SnapshotCell` gate
/// modeled above, and every new counter is `Relaxed` telemetry with no
/// memory published through it.  What *is* new — and what this body
/// checks — is the visibility interleaving across the write fan-out:
/// with `factor = 2`, a reader racing a `FAIL` publish must see every
/// pre-failure key answer its exact value on both sides of the epoch
/// swap (healthy primary before, surviving replica after); `NIL` and
/// `UNAVAILABLE` are both schedule bugs at one failure below the factor.
fn replicated_fail_body() {
    use binhash::algorithms::by_name;
    use binhash::shard::{Shard, ShardClient};
    let router = Router::with_replication(
        local_cluster("memento", 4).unwrap(),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        2,
        false,
    );
    // Three keys owned by the bucket we fail, three owned elsewhere —
    // a deterministic scan, so every schedule checks the same keyset.
    let healthy = by_name("memento", 4).unwrap();
    let mut on_failed = Vec::new();
    let mut elsewhere = Vec::new();
    let mut i = 0u64;
    while on_failed.len() < 3 || elsewhere.len() < 3 {
        let k = format!("rk{i}");
        if healthy.bucket(key_digest(&k)) == 1 {
            if on_failed.len() < 3 {
                on_failed.push(k);
            }
        } else if elsewhere.len() < 3 {
            elsewhere.push(k);
        }
        i += 1;
    }
    let keys: Vec<String> = on_failed.into_iter().chain(elsewhere).collect();
    for (i, k) in keys.iter().enumerate() {
        assert_eq!(
            router.handle(Request::Put { key: k.clone(), value: val(&[i as u8]) }),
            Response::Ok
        );
    }
    // Concurrent reader races the FAIL publish.
    let reader = {
        let router = Arc::clone(&router);
        let keys = keys.clone();
        spawn(move || {
            for (i, k) in keys.iter().enumerate() {
                match router.handle(Request::Get { key: k.clone() }) {
                    Response::Val(v) => assert_eq!(
                        &v[..],
                        &[i as u8],
                        "key {k} answered a wrong value across the failover publish"
                    ),
                    other => panic!(
                        "key {k}: factor-2 read lost to a single failure: {other:?}"
                    ),
                }
            }
        })
    };
    router.fail_shard(1).expect("memento tolerates arbitrary failure");
    reader.join().unwrap();
    // Post-sequence sweep: the replica identity serves every key.
    for (i, k) in keys.iter().enumerate() {
        match router.handle(Request::Get { key: k.clone() }) {
            Response::Val(v) => assert_eq!(&v[..], &[i as u8]),
            other => panic!("key {k} degraded read failed: {other:?}"),
        }
    }
}

#[test]
fn replicated_failover_serves_every_key_under_all_schedules() {
    // Full-router bodies are big, so sweep a fixed named-seed window
    // (same protocol as the PR 4 regression above).
    for i in 0..100 {
        let seed = PR8_SEED_BASE + i;
        if let Err(f) = model::try_seed(seed, 200_000, &replicated_fail_body) {
            panic!("replicated failover lost a key under seed {seed}: {f}");
        }
    }
}

// ---------------------------------------------------------------------
// HandoffQueue: the acceptor → event-loop wake-suppression protocol
// ---------------------------------------------------------------------

/// No-lost-handoff for `sync::handoff::HandoffQueue` (the event server's
/// acceptor → loop socket channel): producers enqueue and signal a
/// modeled eventfd only when `push` says so; the consumer sleeps until
/// the eventfd counter moves, takes the counter (read-and-reset, like a
/// real eventfd), and drains.  A lost wake — an item enqueued with no
/// wake in flight and no drain to cover it — strands the consumer in
/// its sleep loop on a non-empty queue, which the explorer reports as a
/// step-budget starvation failure.
#[test]
fn handoff_queue_never_loses_a_wake() {
    use binhash::sync::handoff::HandoffQueue;
    model::explore("handoff-wake-suppression", 4_000, || {
        let q = Arc::new(HandoffQueue::new());
        let eventfd = Arc::new(AtomicU64::new(0));

        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = Arc::clone(&q);
                let eventfd = Arc::clone(&eventfd);
                spawn(move || {
                    for i in 0..2u64 {
                        if q.push(p * 10 + i) {
                            // ord: SeqCst — models the eventfd signal
                            // write; pairs with the consumer's swap.
                            eventfd.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();

        let consumer = {
            let q = Arc::clone(&q);
            let eventfd = Arc::clone(&eventfd);
            spawn(move || {
                let mut got = Vec::new();
                while got.len() < 4 {
                    // epoll_wait on the eventfd: a lost wake starves
                    // this loop with items still queued.
                    // ord: SeqCst — models the readiness poll.
                    while eventfd.load(Ordering::SeqCst) == 0 {
                        spin_yield();
                    }
                    // eventfd read: returns and resets the whole counter.
                    // ord: SeqCst — models the atomic eventfd read.
                    eventfd.swap(0, Ordering::SeqCst);
                    q.drain(&mut got);
                }
                got
            })
        };

        for p in producers {
            p.join().unwrap();
        }
        let mut got = consumer.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 10, 11], "handoff dropped or duplicated an item");
        assert!(q.is_empty());
    });
}

/// Bounded exhaustive pass over the smallest interesting shape (one
/// producer, two pushes, one consumer): *every* interleaving of the
/// swap/store/lock protocol delivers both items and leaves the queue
/// empty.
#[test]
fn handoff_queue_exhaustive_single_producer() {
    use binhash::sync::handoff::HandoffQueue;
    let runs = model::explore_exhaustive("handoff-exhaustive", 20_000, || {
        let q = Arc::new(HandoffQueue::new());
        let eventfd = Arc::new(AtomicU64::new(0));

        let producer = {
            let q = Arc::clone(&q);
            let eventfd = Arc::clone(&eventfd);
            spawn(move || {
                for i in 1..=2u64 {
                    if q.push(i) {
                        // ord: SeqCst — models the eventfd signal write.
                        eventfd.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        };

        let mut got = Vec::new();
        while got.len() < 2 {
            // ord: SeqCst — models the readiness poll.
            while eventfd.load(Ordering::SeqCst) == 0 {
                spin_yield();
            }
            // ord: SeqCst — models the atomic eventfd read-and-reset.
            eventfd.swap(0, Ordering::SeqCst);
            q.drain(&mut got);
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1, 2], "handoff reordered, dropped, or duplicated");
    });
    assert!(runs > 0, "exhaustive explorer enumerated no schedules");
}
