//! PJRT runtime integration: the AOT-compiled JAX/Pallas artifacts must be
//! loadable, executable, and bit-identical to the Rust implementation.
//!
//! Requires `make artifacts` (skips with a notice otherwise — e.g. in a
//! checkout without the Python toolchain).

use binhash::algorithms::binomial;
use binhash::runtime::PlacementRuntime;
use binhash::workload::UniformDigests;

fn runtime() -> Option<PlacementRuntime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PlacementRuntime::load(dir).expect("artifacts load"))
}

#[test]
fn lookup_batch_bit_parity() {
    let Some(rt) = runtime() else { return };
    let digests = UniformDigests::new(0x17_1).take_vec(10_000); // ragged batch
    for n in [1u32, 2, 9, 11, 64, 1000, 100_000] {
        let xla = rt.lookup_batch(&digests, n).unwrap();
        for (i, &d) in digests.iter().enumerate() {
            assert_eq!(
                xla[i],
                binomial::lookup(d, n, rt.omega),
                "n={n} digest={d}"
            );
        }
    }
}

#[test]
fn lookup_batch_chunking_sizes() {
    let Some(rt) = runtime() else { return };
    // Exercise: exact artifact size, smaller, larger (multi-chunk).
    for len in [1usize, 100, 4096, 4097, 9000] {
        let digests = UniformDigests::new(len as u64).take_vec(len);
        let xla = rt.lookup_batch(&digests, 23).unwrap();
        assert_eq!(xla.len(), len);
        for (i, &d) in digests.iter().enumerate() {
            assert_eq!(xla[i], binomial::lookup(d, 23, rt.omega));
        }
    }
}

#[test]
fn migration_plan_parity_and_monotonicity() {
    let Some(rt) = runtime() else { return };
    let digests = UniformDigests::new(0x17_2).take_vec(8_192);
    let out = rt.migration_plan(&digests, 16, 17).unwrap();
    let mut count = 0u64;
    for (i, &d) in digests.iter().enumerate() {
        let old = binomial::lookup(d, 16, rt.omega);
        let new = binomial::lookup(d, 17, rt.omega);
        assert_eq!(out.old[i], old);
        assert_eq!(out.new[i], new);
        assert_eq!(out.moved[i] != 0, old != new);
        if old != new {
            assert_eq!(new, 16, "monotonicity on the bulk path");
            count += 1;
        }
    }
    assert_eq!(out.moved_count, count);
}

#[test]
fn histogram_matches_direct_counts() {
    let Some(rt) = runtime() else { return };
    let digests = UniformDigests::new(0x17_3).take_vec(30_000); // ragged
    let n = 100u32;
    let counts = rt.histogram(&digests, n).unwrap();
    assert_eq!(counts.len(), n as usize);
    let mut want = vec![0u64; n as usize];
    for &d in &digests {
        want[binomial::lookup(d, n, rt.omega) as usize] += 1;
    }
    assert_eq!(counts, want);
    assert_eq!(counts.iter().sum::<u64>(), 30_000);
}
