//! Pins the zero-allocation claim of the router's steady-state data path:
//! once the keyset is warm, local GET / PUT-overwrite / DEL through
//! `Router::handle` must not touch the heap at all — the snapshot is one
//! atomic load, the key is borrowed, the value is a shared `Arc<[u8]>`
//! (GET bumps a refcount, PUT moves the caller's buffer in, the map slot
//! is reused), and the shard stripe reuses the router's digest.
//!
//! Mechanism: a counting `#[global_allocator]` that increments a counter
//! for every `alloc`/`alloc_zeroed`/`realloc` issued *by this thread
//! while armed* (thread-local arming keeps harness/background threads out
//! of the count; deallocations are free — dropping warm state is fine).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};

use binhash::proto::{Request, Response, Value};
use binhash::router::{local_cluster, BatchScratch, Router};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
}

fn note() {
    // `try_with` so allocations during TLS teardown can't panic.
    let _ = ARMED.try_with(|armed| {
        if armed.get() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn arm(on: bool) {
    ARMED.with(|armed| armed.set(on));
}

fn value_of(i: usize, tag: u8) -> Value {
    vec![i as u8, (i >> 8) as u8, tag].into()
}

#[test]
fn steady_state_data_path_allocates_nothing() {
    const KEYS: usize = 256;
    let router = Router::new(local_cluster("binomial", 4).unwrap());

    // Warm-up: first insertion of each key allocates its map entry.
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("za{i}"), value: value_of(i, 0) }),
            Response::Ok
        );
    }

    // Zero-length values ride the same contract: an empty `Arc<[u8]>`
    // (what `PUT k 0` parses into) is stored, shared and overwritten
    // without touching the heap once the `Arc` itself exists.
    const EMPTY_KEYS: usize = 32;
    let empty: Value = Vec::new().into();
    for i in 0..EMPTY_KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("ze{i}"), value: empty.clone() }),
            Response::Ok
        );
    }

    // Pre-build every measured request outside the counting window (the
    // owned `Request` carries a pre-allocated key `String` and a
    // pre-allocated `Arc` value; `handle` only moves/borrows them).
    let gets: Vec<Request> =
        (0..KEYS).map(|i| Request::Get { key: format!("za{i}") }).collect();
    let overwrites: Vec<Request> = (0..KEYS)
        .map(|i| Request::Put { key: format!("za{i}"), value: value_of(i, 1) })
        .collect();
    let dels: Vec<Request> =
        (0..KEYS / 4).map(|i| Request::Del { key: format!("za{i}") }).collect();
    let miss_gets: Vec<Request> =
        (0..KEYS / 4).map(|i| Request::Get { key: format!("za{i}") }).collect();
    let empty_gets: Vec<Request> =
        (0..EMPTY_KEYS).map(|i| Request::Get { key: format!("ze{i}") }).collect();
    let empty_overwrites: Vec<Request> = (0..EMPTY_KEYS)
        .map(|i| Request::Put { key: format!("ze{i}"), value: empty.clone() })
        .collect();

    ALLOCS.store(0, Ordering::Relaxed);
    arm(true);
    let mut unexpected = 0u32;
    for req in gets {
        if !matches!(black_box(router.handle(req)), Response::Val(_)) {
            unexpected += 1;
        }
    }
    for req in overwrites {
        if !matches!(black_box(router.handle(req)), Response::Ok) {
            unexpected += 1;
        }
    }
    for req in dels {
        if !matches!(black_box(router.handle(req)), Response::Ok) {
            unexpected += 1;
        }
    }
    for req in miss_gets {
        if !matches!(black_box(router.handle(req)), Response::Nil) {
            unexpected += 1;
        }
    }
    for req in empty_gets {
        match black_box(router.handle(req)) {
            Response::Val(v) if v.is_empty() => {}
            _ => unexpected += 1,
        }
    }
    for req in empty_overwrites {
        if !matches!(black_box(router.handle(req)), Response::Ok) {
            unexpected += 1;
        }
    }
    arm(false);
    let allocs = ALLOCS.load(Ordering::Relaxed);

    assert_eq!(unexpected, 0, "a steady-state op answered unexpectedly");
    assert_eq!(
        allocs, 0,
        "steady-state local GET/PUT/DEL must be allocation-free, saw {allocs} allocations"
    );

    // Correctness after the measured window: overwrites landed, deletes
    // stuck, untouched keys intact.
    for i in 0..KEYS / 4 {
        assert_eq!(router.handle(Request::Get { key: format!("za{i}") }), Response::Nil);
    }
    for i in KEYS / 4..KEYS {
        assert_eq!(
            router.handle(Request::Get { key: format!("za{i}") }),
            Response::Val(value_of(i, 1)),
            "overwrite of za{i} lost"
        );
    }

    // ---- Batch phase: steady-state MGET / MPUT-overwrite / MDEL through
    // `Router::handle_batch` with caller-reused scratch must be
    // allocation-free too (the per-connection contract: scratch batch
    // buffers are reused, a batched GET bumps refcounts, a batched PUT
    // moves pre-allocated Arcs, placement grouping sorts in place).
    // This armed window also covers the batched placement column: each
    // `handle_batch` call places the whole batch up front via
    // `bucket_batch` into `BatchScratch::buckets` (clear + resize on
    // the warm Vec — capacity is retained, so no heap traffic), which
    // pins the lane-parallel binomial kernel itself as alloc-free.
    let live: Vec<String> = (KEYS / 4..KEYS).map(|i| format!("za{i}")).collect();
    let batch_values: Vec<Value> =
        (0..live.len()).map(|i| value_of(i, 3)).collect();
    let mget = Request::MGet { keys: live.clone() };
    let mput = Request::MPut { keys: live.clone(), values: batch_values.clone() };
    let mdel = Request::MDel { keys: live[..32].to_vec() };
    let mut scratch = BatchScratch::new();
    let mut out: Vec<Response> = Vec::new();

    // Warm-up batch sizes every scratch buffer outside the window.
    {
        let (op, batch) = mget.as_view().into_batch().unwrap();
        router.handle_batch(op, &batch, &mut scratch, &mut out);
    }

    ALLOCS.store(0, Ordering::Relaxed);
    arm(true);
    let mut unexpected = 0u32;
    for _ in 0..4 {
        let (op, batch) = mget.as_view().into_batch().unwrap();
        router.handle_batch(op, &batch, &mut scratch, &mut out);
        for sub in black_box(&out).iter() {
            if !matches!(sub, Response::Val(_)) {
                unexpected += 1;
            }
        }
        let (op, batch) = mput.as_view().into_batch().unwrap();
        router.handle_batch(op, &batch, &mut scratch, &mut out);
        for sub in black_box(&out).iter() {
            if !matches!(sub, Response::Ok) {
                unexpected += 1;
            }
        }
    }
    {
        let (op, batch) = mdel.as_view().into_batch().unwrap();
        router.handle_batch(op, &batch, &mut scratch, &mut out);
        for sub in black_box(&out).iter() {
            if !matches!(sub, Response::Ok) {
                unexpected += 1;
            }
        }
        // Batched misses ride the same budget.
        let (op, batch) = mdel.as_view().into_batch().unwrap();
        router.handle_batch(op, &batch, &mut scratch, &mut out);
        for sub in black_box(&out).iter() {
            if !matches!(sub, Response::Nil) {
                unexpected += 1;
            }
        }
    }
    arm(false);
    let allocs = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(unexpected, 0, "a steady-state batch sub-response was unexpected");
    assert_eq!(
        allocs, 0,
        "steady-state batched MGET/MPUT/MDEL must be allocation-free, saw {allocs} allocations"
    );

    // Post-window correctness: batch overwrites landed, batch deletes
    // stuck, the rest intact.
    for (j, key) in live.iter().enumerate() {
        let want = if j < 32 {
            Response::Nil
        } else {
            Response::Val(batch_values[j].clone())
        };
        assert_eq!(router.handle(Request::Get { key: key.clone() }), want, "key {key}");
    }
}

#[test]
fn hot_cache_hit_path_allocates_nothing() {
    // The cache's design constraint: a hit is a stripe lock, a linear
    // probe, and an `Arc` refcount bump — turning the hot-key cache on
    // must not cost the steady-state GET path its zero-allocation
    // budget.  (The *miss* path's fill owns a copy of the key `String`;
    // that allocation is priced outside the measured window.)
    use binhash::shard::{Shard, ShardClient};
    const KEYS: usize = 256;
    // Roomy capacity: 4096/8 = 512 per stripe, so no stripe can evict
    // under 256 keys and the measured window is hits only.
    const CACHE_KEYS: usize = 4096;
    let router = Router::with_placement(
        local_cluster("binomial", 4).unwrap(),
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        None,
        1,
        false,
        CACHE_KEYS,
    );
    for i in 0..KEYS {
        assert_eq!(
            router.handle(Request::Put { key: format!("hc{i}"), value: value_of(i, 0) }),
            Response::Ok
        );
    }
    // Priming pass: every GET misses, reads the shard, and fills.
    for i in 0..KEYS {
        assert!(matches!(
            router.handle(Request::Get { key: format!("hc{i}") }),
            Response::Val(_)
        ));
    }
    let gets: Vec<Request> =
        (0..KEYS).map(|i| Request::Get { key: format!("hc{i}") }).collect();
    let hits_before = router.metrics.hot_hits.load(Ordering::Relaxed); // ord: Relaxed — test-side telemetry read

    ALLOCS.store(0, Ordering::Relaxed);
    arm(true);
    let mut unexpected = 0u32;
    for req in gets {
        if !matches!(black_box(router.handle(req)), Response::Val(_)) {
            unexpected += 1;
        }
    }
    arm(false);
    let allocs = ALLOCS.load(Ordering::Relaxed);

    assert_eq!(unexpected, 0, "a warm cached GET answered unexpectedly");
    assert_eq!(
        router.metrics.hot_hits.load(Ordering::Relaxed) - hits_before, // ord: Relaxed — test-side telemetry read
        KEYS as u64,
        "the measured window must be all cache hits"
    );
    assert_eq!(
        allocs, 0,
        "the hot-cache hit path must be allocation-free, saw {allocs} allocations"
    );
}
