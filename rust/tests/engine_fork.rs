//! Fork/scale property sweep over the whole engine suite.
//!
//! The epoch-snapshot scaling path no longer reconstructs engines from
//! their names: every topology change forks the live engine
//! ([`ConsistentHasher::fork`]) and applies `add_bucket`/`remove_bucket`
//! to the fork.  These tests pin the two contracts that path relies on,
//! for every engine in `ALL_ALGORITHMS` (and the modulo anti-baseline):
//!
//! * a fork maps identically to its parent at the moment of the fork, and
//!   mutating either side never moves keys on the other — including the
//!   stateful engines' hidden state (anchor's removal metadata, dx's
//!   node-state array, memento's failure table);
//! * a full router scale-up/scale-down cycle preserves every key, for
//!   engines with and without the minimal-disruption guarantee.

use binhash::algorithms::weighted::Weighted;
use binhash::algorithms::{self, ConsistentHasher, FaultTolerant, ALL_ALGORITHMS, ANTI_BASELINE};
use binhash::hashing::SplitMix64Rng;
use binhash::proto::{Request, Response};
use binhash::router::{local_cluster, Router};

fn digests(seed: u64, k: usize) -> Vec<u64> {
    let mut rng = SplitMix64Rng::new(seed);
    (0..k).map(|_| rng.next_u64()).collect()
}

fn mapping(h: &dyn ConsistentHasher, digests: &[u64]) -> Vec<u32> {
    digests.iter().map(|&d| h.bucket(d)).collect()
}

/// Every engine name the fork contract must hold for (the 12 registered
/// algorithms plus the modulo anti-baseline).
fn all_engines() -> impl Iterator<Item = &'static str> {
    ALL_ALGORITHMS.iter().copied().chain(std::iter::once(ANTI_BASELINE))
}

#[test]
fn fork_is_identical_then_independent() {
    let ds = digests(0xF0_01, 2_000);
    for name in all_engines() {
        let mut parent = algorithms::by_name(name, 9).unwrap();
        let before = mapping(&*parent, &ds);

        // Identical at the fork point.
        let mut fork = parent.fork();
        assert_eq!(mapping(&*fork, &ds), before, "{name}: fork diverges from parent");

        // Fork mutations never leak into the parent...
        fork.add_bucket();
        fork.add_bucket();
        fork.remove_bucket();
        assert_eq!(fork.len(), 10, "{name}");
        assert_eq!(mapping(&*parent, &ds), before, "{name}: fork mutation moved parent keys");

        // ...and parent mutations never leak into the fork.
        let fork_view = mapping(&*fork, &ds);
        parent.remove_bucket();
        assert_eq!(mapping(&*fork, &ds), fork_view, "{name}: parent mutation moved fork keys");

        // A fork of a fork is just as independent.
        let mut grandchild = fork.fork();
        grandchild.remove_bucket();
        assert_eq!(mapping(&*fork, &ds), fork_view, "{name}: grandchild mutation leaked");
    }
}

#[test]
fn fork_carries_stateful_engine_state() {
    // The whitelist the fork API replaced existed because anchor, dx and
    // memento cannot be rebuilt from `(name, n)` once their state has
    // diverged from a fresh construction.  Put each into such a state via
    // arbitrary removals, fork, and require the fork to agree with the
    // degraded instance everywhere — then heal the parent and require the
    // fork to stay degraded (deep copy, not a shared view).
    use binhash::algorithms::{anchor::AnchorHash, dx::DxHash, memento::MementoHash};
    let ds = digests(0xF0_02, 2_000);

    // AnchorHash: removal metadata (A/K/W/L arrays + removal stack).
    let mut a = AnchorHash::with_capacity(12, 32);
    a.remove_arbitrary(3);
    a.remove_arbitrary(7);
    let degraded = mapping(&a, &ds);
    let fork = a.fork();
    assert_eq!(mapping(&*fork, &ds), degraded, "anchor: fork lost removal state");
    a.restore(7);
    a.restore(3);
    assert_eq!(mapping(&*fork, &ds), degraded, "anchor: healing the parent changed the fork");

    // DxHash: node-state bitmap with a hole.
    let mut d = DxHash::new(12);
    d.remove_arbitrary(5);
    let degraded = mapping(&d, &ds);
    let fork = d.fork();
    assert_eq!(mapping(&*fork, &ds), degraded, "dx: fork lost node-state");
    d.restore(5);
    assert_eq!(mapping(&*fork, &ds), degraded, "dx: healing the parent changed the fork");

    // MementoHash: replacement (failure) table.
    let mut m = MementoHash::new(12);
    m.remove_arbitrary(2);
    m.remove_arbitrary(9);
    let degraded = mapping(&m, &ds);
    let fork = m.fork();
    assert_eq!(mapping(&*fork, &ds), degraded, "memento: fork lost the failure table");
    m.restore(2);
    m.restore(9);
    assert_eq!(mapping(&*fork, &ds), degraded, "memento: healing the parent changed the fork");
    for &dg in &ds {
        let b = fork.bucket(dg);
        assert_ne!(b, 2, "memento fork routed onto a failed bucket");
        assert_ne!(b, 9, "memento fork routed onto a failed bucket");
    }
}

#[test]
fn weighted_uniform_is_placement_identical_to_the_bare_engine() {
    // The placement stack's base case: wrapping any engine in `Weighted`
    // at weight 1 everywhere is a no-op for placement, so configs without
    // a `[placement] weights` table lose nothing by gaining the adapter.
    let ds = digests(0xF0_03, 5_000);
    for name in all_engines() {
        for n in [1u32, 2, 5, 9, 16, 33] {
            let bare = algorithms::by_name(name, n).unwrap();
            let wrapped = Weighted::uniform(name, n).unwrap();
            assert_eq!(wrapped.len(), n, "{name}");
            for &d in &ds {
                assert_eq!(
                    wrapped.bucket(d),
                    bare.bucket(d),
                    "{name}: n={n} digest={d:#x} diverges under the uniform wrapper"
                );
            }
        }
    }
}

#[test]
fn weighted_fork_is_identical_then_independent_for_every_engine() {
    // Same contract the scaling path relies on for bare engines, through
    // the adapter: the fork must deep-copy the owner map, the weight
    // table, and the inner engine's state.
    let ds = digests(0xF0_04, 2_000);
    for name in all_engines() {
        let mut parent: Box<dyn ConsistentHasher> =
            Box::new(Weighted::new(name, &[2, 1, 3, 1, 1, 1], 1).unwrap());
        let before = mapping(&*parent, &ds);

        let mut fork = parent.fork();
        assert_eq!(fork.name(), "weighted", "{name}");
        assert_eq!(mapping(&*fork, &ds), before, "{name}: fork diverges from parent");

        // Fork mutations (scale and reweight) never leak into the parent...
        fork.add_bucket();
        fork.as_weighted_mut().unwrap().set_weight(0, 4).unwrap();
        assert_eq!(fork.len(), 7, "{name}");
        assert_eq!(mapping(&*parent, &ds), before, "{name}: fork mutation moved parent keys");
        assert_eq!(parent.as_weighted().unwrap().weights(), &[2, 1, 3, 1, 1, 1], "{name}");

        // ...and parent mutations never leak into the fork.
        let fork_view = mapping(&*fork, &ds);
        parent.remove_bucket();
        assert_eq!(mapping(&*fork, &ds), fork_view, "{name}: parent mutation moved fork keys");
        assert_eq!(fork.as_weighted().unwrap().weights(), &[4, 1, 3, 1, 1, 1, 1], "{name}");
    }
}

#[test]
fn scale_cycle_preserves_keys_for_every_engine() {
    const KEYS: usize = 300;
    for name in all_engines() {
        let router = Router::new(local_cluster(name, 4).unwrap());
        for i in 0..KEYS {
            assert_eq!(
                router.handle(Request::Put { key: format!("k{i}"), value: vec![i as u8, 7].into() }),
                Response::Ok,
                "{name}: put failed"
            );
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5), "{name}");
        for i in 0..KEYS {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8, 7].into()),
                "{name}: key k{i} lost after scale-up"
            );
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4), "{name}");
        for i in 0..KEYS {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8, 7].into()),
                "{name}: key k{i} lost after scale-down"
            );
        }
        assert_eq!(
            router.handle(Request::Count),
            Response::Num(KEYS as u64),
            "{name}: key count drifted across the scale cycle"
        );
        assert!(!router.snapshot().is_migrating(), "{name}: cycle did not settle");
        assert_eq!(router.topology().1, 4, "{name}");
        assert_eq!(router.topology().2, name, "{name}: STATS engine drifted");
    }
}
