//! xxHash64 — the key→digest hash for byte-string keys.
//!
//! Straight implementation of the reference specification
//! (<https://github.com/Cyan4973/xxHash/blob/dev/doc/xxhash_spec.md>),
//! validated against the published test vectors.  Used on the router's
//! request path to turn an object key into the u64 digest that the
//! consistent-hashing algorithms consume.

const P1: u64 = 0x9E37_79B1_85EB_CA87;
const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const P3: u64 = 0x1656_67B1_9E37_79F9;
const P4: u64 = 0x85EB_CA77_C2B2_AE63;
const P5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline(always)]
fn round(acc: u64, lane: u64) -> u64 {
    acc.wrapping_add(lane.wrapping_mul(P2))
        .rotate_left(31)
        .wrapping_mul(P1)
}

#[inline(always)]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(P1).wrapping_add(P4)
}

#[inline(always)]
fn read_u64(data: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(data[i..i + 8].try_into().unwrap())
}

#[inline(always)]
fn read_u32(data: &[u8], i: usize) -> u32 {
    u32::from_le_bytes(data[i..i + 4].try_into().unwrap())
}

/// xxHash64 of `data` with the given `seed`.
pub fn xxhash64(data: &[u8], seed: u64) -> u64 {
    let len = data.len();
    let mut h: u64;
    let mut i = 0usize;

    if len >= 32 {
        let mut v1 = seed.wrapping_add(P1).wrapping_add(P2);
        let mut v2 = seed.wrapping_add(P2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(P1);
        while i + 32 <= len {
            v1 = round(v1, read_u64(data, i));
            v2 = round(v2, read_u64(data, i + 8));
            v3 = round(v3, read_u64(data, i + 16));
            v4 = round(v4, read_u64(data, i + 24));
            i += 32;
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(P5);
    }

    h = h.wrapping_add(len as u64);

    while i + 8 <= len {
        h ^= round(0, read_u64(data, i));
        h = h.rotate_left(27).wrapping_mul(P1).wrapping_add(P4);
        i += 8;
    }
    if i + 4 <= len {
        h ^= (read_u32(data, i) as u64).wrapping_mul(P1);
        h = h.rotate_left(23).wrapping_mul(P2).wrapping_add(P3);
        i += 4;
    }
    while i < len {
        h ^= (data[i] as u64).wrapping_mul(P5);
        h = h.rotate_left(11).wrapping_mul(P1);
        i += 1;
    }

    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^= h >> 32;
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    // Published xxHash64 test vectors (xxhash_spec.md + reference impl).
    #[test]
    fn spec_vectors() {
        assert_eq!(xxhash64(b"", 0), 0xEF46_DB37_51D8_E999);
        assert_eq!(xxhash64(b"a", 0), 0xD24E_C4F1_A98C_6E5B);
        assert_eq!(xxhash64(b"abc", 0), 0x44BC_2CF5_AD77_0999);
    }

    #[test]
    fn seed_changes_digest() {
        assert_ne!(xxhash64(b"key", 0), xxhash64(b"key", 1));
    }

    #[test]
    fn all_length_paths() {
        // Exercise the 32-byte stripe loop, 8/4/1-byte tails.
        let data: Vec<u8> = (0..=255u8).collect();
        let mut seen = std::collections::HashSet::new();
        for len in 0..=data.len() {
            assert!(seen.insert(xxhash64(&data[..len], 0)), "collision at len={len}");
        }
    }

    #[test]
    fn deterministic() {
        let k = b"object/12345/chunk-7";
        assert_eq!(xxhash64(k, 42), xxhash64(k, 42));
    }

    #[test]
    fn avalanche_rough() {
        // Flipping one input bit flips ~half the output bits on average.
        let base = xxhash64(b"avalanche-test-key", 0);
        let mut total = 0u32;
        let mut data = *b"avalanche-test-key";
        for byte in 0..data.len() {
            data[byte] ^= 1;
            total += (xxhash64(&data, 0) ^ base).count_ones();
            data[byte] ^= 1;
        }
        let mean = total as f64 / data.len() as f64;
        assert!((20.0..44.0).contains(&mean), "mean flipped bits = {mean}");
    }
}
