//! Hashing substrate shared by every consistent-hashing algorithm.
//!
//! Two primitives carry the whole repository:
//!
//! * [`splitmix64`] / [`next_hash`] / [`hash2`] — the mixer family that the
//!   BinomialHash implementation (and the JAX/Pallas artifacts) use.  These
//!   are **bitwise-identical** to `python/compile/kernels/scalar_ref.py`;
//!   the contract is pinned by `tests/golden/binomial_golden.json`.
//! * [`xxhash64`] — the key→digest hash for byte-string keys (requests,
//!   object names).  Uniform, fast, and with published test vectors.
//!
//! Plus a tiny deterministic PRNG ([`SplitMix64Rng`]) used by workload
//! generators and randomized tests, so no external `rand` crate leaks into
//! the request path.

pub mod xxh;

pub use xxh::xxhash64;

/// 64-bit golden ratio — splitmix64's increment constant.
pub const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// splitmix64 finalizer (Steele et al.): a bijective avalanche mixer on u64.
///
/// This is the universal mixer of the repo: the rehash stream and the
/// level-relocation hash are both built from it (see [`next_hash`] and
/// [`hash2`]).
#[inline(always)]
pub const fn splitmix64(mut z: u64) -> u64 {
    z ^= z >> 30;
    z = z.wrapping_mul(MIX1);
    z ^= z >> 27;
    z = z.wrapping_mul(MIX2);
    z ^= z >> 31;
    z
}

/// The paper's rehash stream `hash^{i+1}(key)` (Alg. 1 line 13):
/// `h_{i+1} = splitmix64(h_i + PHI64)`.
#[inline(always)]
pub const fn next_hash(h: u64) -> u64 {
    splitmix64(h.wrapping_add(PHI64))
}

/// The seeded hash of Alg. 2 line 7: `r ← hash(h, f)`.
#[inline(always)]
pub const fn hash2(h: u64, f: u64) -> u64 {
    splitmix64(h ^ f.wrapping_mul(PHI64))
}

/// Smallest power of two `>= n` (capacity `E` of the enclosing tree).
///
/// `n` must be `>= 1`; `n = 1` maps to `1`.
#[inline(always)]
pub const fn next_pow2(n: u64) -> u64 {
    if n <= 1 {
        1
    } else {
        1u64 << (64 - (n - 1).leading_zeros())
    }
}

/// A tiny deterministic PRNG (splitmix64 stream) for workloads and tests.
///
/// Not cryptographic; chosen for reproducibility across the Rust and Python
/// sides and to keep the hot path free of external dependencies.
#[derive(Debug, Clone)]
pub struct SplitMix64Rng {
    state: u64,
}

impl SplitMix64Rng {
    /// Create a generator from a seed.
    pub const fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(PHI64);
        splitmix64(self.state)
    }

    /// Uniform value in `[0, bound)` via Lemire's multiply-shift reduction.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// xxhash64-backed [`std::hash::BuildHasher`] for the shard stripe maps,
/// replacing the default SipHash-1-3 on the hot path.
///
/// Tradeoff, stated honestly: xxhash64 is not a keyed PRF, so this is
/// weaker against adversarial collision-flooding than SipHash.  Two
/// mitigations keep the exposure small: the seed is drawn per process at
/// startup (clock + ASLR entropy, so collisions cannot be precomputed
/// offline against a known constant), and keys are length- (≤512) and
/// charset-validated at the wire before ever reaching a map.  Streaming
/// `write` calls chain the seed, so multi-part hashing (`Hash for
/// String` writes the bytes then a length terminator) stays well mixed.
#[derive(Debug, Clone, Copy)]
pub struct XxBuildHasher {
    seed: u64,
}

/// Per-process stripe-map seed: sampled once, shared by every map so a
/// shard's stripes stay mutually consistent within the process.
fn process_seed() -> u64 {
    use std::sync::OnceLock;
    static SEED: OnceLock<u64> = OnceLock::new();
    *SEED.get_or_init(|| {
        let clock = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let aslr = &SEED as *const _ as u64;
        splitmix64(clock ^ aslr.rotate_left(32) ^ PHI64)
    })
}

impl Default for XxBuildHasher {
    fn default() -> Self {
        Self { seed: process_seed() }
    }
}

/// Hasher state for [`XxBuildHasher`].
#[derive(Debug, Clone)]
pub struct XxHasher64 {
    state: u64,
}

impl std::hash::Hasher for XxHasher64 {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        self.state = xxhash64(bytes, self.state);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }
}

impl std::hash::BuildHasher for XxBuildHasher {
    type Hasher = XxHasher64;

    #[inline]
    fn build_hasher(&self) -> XxHasher64 {
        XxHasher64 { state: self.seed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_known_values() {
        // Reference values computed from the Python scalar spec
        // (python/compile/kernels/scalar_ref.py) — the parity contract.
        assert_eq!(splitmix64(0), 0);
        assert_eq!(splitmix64(1), 0x5692_161d_100b_05e5);
        assert_eq!(splitmix64(PHI64), 0xe220_a839_7b1d_cdaf);
        assert_eq!(next_hash(0xDEAD_BEEF), 0x4adf_b90f_68c9_eb9b);
        assert_eq!(hash2(0xDEAD_BEEF, 0xFF), 0xce45_1072_3418_6931);
    }

    #[test]
    fn next_hash_stream_progresses() {
        let h0 = 0xDEADBEEFu64;
        let h1 = next_hash(h0);
        let h2 = next_hash(h1);
        assert_ne!(h0, h1);
        assert_ne!(h1, h2);
        // Deterministic.
        assert_eq!(h1, next_hash(0xDEADBEEFu64));
    }

    #[test]
    fn next_pow2_exact() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(4), 4);
        assert_eq!(next_pow2(5), 8);
        assert_eq!(next_pow2(1023), 1024);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
        assert_eq!(next_pow2(1 << 62), 1 << 62);
    }

    #[test]
    fn rng_below_bound() {
        let mut rng = SplitMix64Rng::new(42);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut rng = SplitMix64Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn xx_build_hasher_is_deterministic_and_mixes() {
        use std::hash::{BuildHasher, Hash, Hasher};
        let bh = XxBuildHasher::default();
        let hash_of = |s: &str| {
            let mut h = bh.build_hasher();
            s.hash(&mut h);
            h.finish()
        };
        assert_eq!(hash_of("key-1"), hash_of("key-1"));
        assert_ne!(hash_of("key-1"), hash_of("key-2"));
        // Two instances share the per-process seed (stripe maps must
        // agree with each other within a process).
        let other = XxBuildHasher::default();
        let mut h = other.build_hasher();
        "key-1".hash(&mut h);
        assert_eq!(hash_of("key-1"), h.finish());
        // A HashMap keyed with it behaves.
        let mut m = std::collections::HashMap::with_hasher(XxBuildHasher::default());
        for i in 0..1_000 {
            m.insert(format!("k{i}"), i);
        }
        assert_eq!(m.len(), 1_000);
        assert_eq!(m.get("k512"), Some(&512));
    }

    #[test]
    fn rng_reproducible() {
        let mut a = SplitMix64Rng::new(123);
        let mut b = SplitMix64Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
