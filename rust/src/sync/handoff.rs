//! Wake-suppressed handoff queue: the acceptor → event-loop fd channel.
//!
//! The event server's acceptor thread pushes accepted sockets to one
//! queue per event loop; the loop drains its queue when its `eventfd`
//! wakes it.  A naive design signals the eventfd on *every* push — one
//! syscall per accepted connection even when the loop is already awake
//! and about to drain.  [`HandoffQueue`] suppresses redundant wakes with
//! a single flag while keeping the one property the server depends on:
//!
//! > **No lost handoff:** whenever the queue is non-empty, either a wake
//! > is in flight or the consumer is already past its flag-clear and
//! > will take the queue lock (and therefore see the item).
//!
//! Protocol (all flag operations `SeqCst`, so the argument below is a
//! single-total-order argument, checkable by the model scheduler):
//!
//! * **Producer** — enqueue under the lock, then `swap(true)` the flag.
//!   Signal the consumer only if the swap returned `false`.
//! * **Consumer** — on wake: `store(false)` the flag *first*, then take
//!   the lock and drain.  (Clearing before draining is what makes the
//!   suppressed-wake case safe — see below.)
//!
//! Why no handoff is lost when the producer suppresses its wake: the
//! producer's swap returned `true`, so in the SC total order the swap
//! landed between some earlier `swap(true)` (whose wake is in flight or
//! being processed) and the consumer's next `store(false)`.  The
//! producer's enqueue precedes its swap (program order), the swap
//! precedes that `store(false)` (total order), and the store precedes
//! the consumer's drain lock (program order) — so the drain's lock
//! acquisition happens-after the enqueue's lock release and the drain
//! sees the item.  If instead the consumer's `store(false)` came first,
//! the swap returns `false` and the producer sends a fresh wake.
//! Exercised across schedules by `rust/tests/model.rs`
//! (`handoff_queue_*`), which fails on starvation if a wake is ever
//! lost.

use std::collections::VecDeque;

use crate::sync::{AtomicBool, Mutex, Ordering};

/// Multi-producer, single-consumer queue with wake-suppression — the
/// consumer is notified out of band (an `eventfd` in the event server,
/// a spin-wait in the model tests), and [`push`](Self::push) reports
/// whether that notification must actually be sent.
#[derive(Debug, Default)]
pub struct HandoffQueue<T> {
    items: Mutex<VecDeque<T>>,
    /// `true` while a wake is in flight (or being processed) that the
    /// consumer has not yet acknowledged with its pre-drain clear.
    wake_pending: AtomicBool,
}

impl<T> HandoffQueue<T> {
    /// New empty queue.
    pub fn new() -> Self {
        Self { items: Mutex::new(VecDeque::new()), wake_pending: AtomicBool::new(false) }
    }

    /// Enqueue `item`.  Returns `true` when the caller must wake the
    /// consumer (no wake already in flight); `false` when an
    /// outstanding wake is guaranteed to cover this item.
    pub fn push(&self, item: T) -> bool {
        self.items.lock().unwrap().push_back(item);
        // ord: SeqCst — the no-lost-handoff proof is a single-total-order
        // argument over this swap and the consumer's pre-drain store
        // (see module docs); model-checked in rust/tests/model.rs.
        !self.wake_pending.swap(true, Ordering::SeqCst)
    }

    /// Consumer side: acknowledge the wake, then move every queued item
    /// into `into` (appended; `into` is not cleared).  Must be called on
    /// *every* wake, before the consumer goes back to sleep.
    pub fn drain(&self, into: &mut Vec<T>) {
        // ord: SeqCst — must precede the lock acquisition below in the
        // total order; a producer that observes `true` from its swap is
        // thereby ordered before this store, so its item is in the queue
        // by the time we drain (see module docs).
        self.wake_pending.store(false, Ordering::SeqCst);
        let mut q = self.items.lock().unwrap();
        into.extend(q.drain(..));
    }

    /// Queued item count (diagnostics/tests; racy by nature).
    pub fn len(&self) -> usize {
        self.items.lock().unwrap().len()
    }

    /// `true` when no items are queued (diagnostics/tests; racy).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_drain_roundtrip() {
        let q = HandoffQueue::new();
        assert!(q.push(1), "first push must request a wake");
        assert!(!q.push(2), "second push rides the outstanding wake");
        let mut got = Vec::new();
        q.drain(&mut got);
        assert_eq!(got, vec![1, 2]);
        assert!(q.is_empty());
        assert!(q.push(3), "after a drain the next push wakes again");
    }

    #[test]
    fn drain_appends_without_clearing() {
        let q = HandoffQueue::new();
        q.push("a");
        let mut got = vec!["seed"];
        q.drain(&mut got);
        assert_eq!(got, vec!["seed", "a"]);
    }
}
