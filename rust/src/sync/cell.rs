//! [`SnapshotCell`]: lock-free atomic `Arc<T>` publication with a
//! generation-validated reader gate.
//!
//! Extracted from the router's hand-rolled snapshot swap so the
//! protocol exists exactly once, is unit-tested in isolation, and is
//! model-checked under `--features model` (`rust/tests/model.rs` drives
//! it through thousands of adversarial schedules; the PR 3 pre-swap
//! reader ticket race is pinned there as a regression).
//!
//! ## Protocol
//!
//! The cell owns one strong count of the current `Arc<T>`, stored as a
//! raw pointer.  [`SnapshotCell::load`] is one atomic pointer load plus
//! a refcount bump, guarded by the gate; [`SnapshotCell::store`] swaps
//! the pointer, advances the generation, and drains the *superseded*
//! parity slot to zero before releasing the superseded value's stored
//! count.  That drain closes the classic load-then-bump race: a reader
//! holding the superseded raw pointer without having bumped its count
//! yet is still registered in the superseded slot, so the publisher
//! waits for it.  Readers arriving during the drain validate against
//! the new generation and land in the *other* slot, so publication
//! cannot be starved.
//!
//! All gate operations are `SeqCst`: the covered-reader argument is a
//! single-total-order argument (a validated reader's slot increment is
//! globally ordered before the publisher's generation bump, hence
//! before the drain of that slot) — see the memory-ordering table in
//! the [`crate::router`] module docs.
//!
//! Writers must be externally serialized (the router's admin mutex): at
//! most one drain may be in flight so the two parity slots strictly
//! alternate.

use super::{model_yield, Arc, AtomicPtr, AtomicU64, Backoff, Ordering};
use std::marker::PhantomData;

/// Lock-free publication cell: readers get `Arc<T>` clones wait-free
/// (modulo a bounded retry when a store races in); a store never blocks
/// readers and reclaims the superseded value only after its pre-swap
/// readers drained.
#[derive(Debug)]
pub struct SnapshotCell<T> {
    /// Current value as a raw `Arc` pointer owning one strong count.
    /// Never mutated through — only loaded (readers) and swapped
    /// (writers).
    ptr: AtomicPtr<T>,
    /// Publication generation; bumped by `store` after each swap.
    /// Readers validate it between registering in a gate slot and
    /// touching the pointer, so a reader that raced a store retries
    /// instead of bumping a possibly-reclaimed value.
    generation: AtomicU64,
    /// Readers currently inside the load-and-bump window, slotted by
    /// generation parity.  `store` bumps `generation` then drains the
    /// *superseded* parity slot to zero.
    gate: [AtomicU64; 2],
    /// The cell logically owns an `Arc<T>` through the raw pointer;
    /// this gives it exactly `Arc<T>`'s auto traits (`Send`/`Sync` iff
    /// `T: Send + Sync`) and correct drop-check behaviour.
    _own: PhantomData<Arc<T>>,
}

impl<T> SnapshotCell<T> {
    /// New cell holding `value`.
    pub fn new(value: T) -> Self {
        Self {
            ptr: AtomicPtr::new(Arc::into_raw(Arc::new(value)).cast_mut()),
            generation: AtomicU64::new(0),
            gate: [AtomicU64::new(0), AtomicU64::new(0)],
            _own: PhantomData,
        }
    }

    /// Publication generation (number of `store`s so far).
    pub fn generation(&self) -> u64 {
        // ord: SeqCst — telemetry read of the gate's generation; keeps
        // the cell's every-op-SC invariant (cheap, cold path).
        self.generation.load(Ordering::SeqCst)
    }

    /// Current value: one atomic pointer load plus a refcount bump — no
    /// lock, no allocation, never blocks on a concurrent `store`.
    pub fn load(&self) -> Arc<T> {
        // Generation-validated gate (SeqCst throughout): register in
        // the current generation's slot, then re-check the generation.
        // If a store raced in between, this slot may be (or already
        // have been) drained — deregister and retry against the new
        // generation.  A validated reader is provably covered: its slot
        // increment is globally ordered before the publisher's
        // generation bump (the validation load still saw the old
        // generation), hence before the publisher's drain of that slot.
        loop {
            // ord: SeqCst — the validation argument needs the single
            // total order: this load must be orderable against the
            // publisher's swap/bump/drain sequence.
            let gen = self.generation.load(Ordering::SeqCst);
            let slot = &self.gate[(gen & 1) as usize];
            // ord: SeqCst — the registration must be globally ordered
            // before the re-validation load below; with Relaxed the
            // publisher's drain could miss this reader.
            slot.fetch_add(1, Ordering::SeqCst);
            // ord: SeqCst — pairs with the publisher's generation bump.
            if self.generation.load(Ordering::SeqCst) == gen {
                // ord: SeqCst — must not be reordered before the
                // registration/validation above.
                let ptr = self.ptr.load(Ordering::SeqCst);
                // The historical race window (PR 3): between loading
                // the raw pointer and bumping its count, a publisher
                // must not be able to reclaim it.  The gate guarantees
                // that; the model checker interleaves here to prove it.
                model_yield();
                // SAFETY: `ptr` came from `Arc::into_raw` and its
                // strong count cannot reach zero here: the cell itself
                // owns one count, and `store` releases it only after
                // draining this generation's slot — which this reader
                // occupies.
                let value = unsafe {
                    Arc::increment_strong_count(ptr);
                    Arc::from_raw(ptr.cast_const())
                };
                // ord: SeqCst — deregistration; the publisher's drain
                // loop must observe it.
                slot.fetch_sub(1, Ordering::SeqCst);
                return value;
            }
            // ord: SeqCst — symmetric with the registration above.
            slot.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Publish `value`: swap the pointer, advance the generation, drain
    /// the superseded generation's reader slot, then release the
    /// superseded value's stored count (in-flight readers keep it alive
    /// via their own counts until they drop).  Returns the superseded
    /// value.
    ///
    /// Callers must be serialized externally (at most one drain in
    /// flight; the router's admin mutex provides this).
    pub fn store(&self, value: T) -> Arc<T> {
        let new_ptr = Arc::into_raw(Arc::new(value)).cast_mut();
        // ord: SeqCst — the swap must be globally ordered before the
        // generation bump: a reader that validates against the *old*
        // generation after this swap would load the new pointer, which
        // is safe; a reader that validated before it is covered by the
        // drain below.
        let old_ptr = self.ptr.swap(new_ptr, Ordering::SeqCst);
        // ord: SeqCst — pairs with readers' validation loads; after
        // this bump, new readers land in the other parity slot.
        let gen = self.generation.fetch_add(1, Ordering::SeqCst);
        // Drain readers validated against the superseded generation: a
        // finite set (new readers land in the other slot; a reader that
        // raced us blips this slot once, fails validation, and leaves),
        // each inside a nanoseconds-long load-and-bump window.
        let slot = &self.gate[(gen & 1) as usize];
        let mut backoff = Backoff::new();
        // ord: SeqCst — the drain must observe every covered reader's
        // registration (see the covered-reader argument above).
        while slot.load(Ordering::SeqCst) != 0 {
            backoff.snooze();
        }
        // Reclamation point: the model checker interleaves here to
        // prove no covered reader is still pre-bump.
        model_yield();
        // SAFETY: `old_ptr` came from `Arc::into_raw` in `new` or a
        // previous `store`; we reclaim the cell's single stored count
        // exactly once (the swap above made this call its unique
        // owner).  Every reader that loaded `old_ptr` has already
        // bumped its own strong count (it was validated, so the drain
        // waited for it), so this cannot free a value still in use.
        unsafe { Arc::from_raw(old_ptr.cast_const()) }
    }
}

impl<T> Drop for SnapshotCell<T> {
    fn drop(&mut self) {
        // ord: Relaxed — `&mut self` proves no concurrent reader or
        // writer exists; this is a plain load of the last pointer.
        let ptr = self.ptr.load(Ordering::Relaxed);
        // SAFETY: reclaiming the cell's single stored count; `&mut
        // self` guarantees no reader is inside the load-and-bump
        // window.
        unsafe { drop(Arc::from_raw(ptr.cast_const())) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as StdAtomicU64;

    /// Payload whose integrity a torn read would break.
    struct Versioned {
        version: u64,
        shadow: u64,
        drops: Arc<StdAtomicU64>,
    }

    impl Versioned {
        fn new(version: u64, drops: &Arc<StdAtomicU64>) -> Self {
            Self { version, shadow: version.wrapping_mul(7).wrapping_add(13), drops: Arc::clone(drops) }
        }
    }

    impl Drop for Versioned {
        fn drop(&mut self) {
            self.drops.fetch_add(1, Ordering::SeqCst); // ord: test-only
        }
    }

    #[test]
    fn load_store_roundtrip_and_generation() {
        let drops = Arc::new(StdAtomicU64::new(0));
        let cell = SnapshotCell::new(Versioned::new(0, &drops));
        assert_eq!(cell.generation(), 0);
        assert_eq!(cell.load().version, 0);
        let old = cell.store(Versioned::new(1, &drops));
        assert_eq!(old.version, 0);
        assert_eq!(cell.generation(), 1);
        assert_eq!(cell.load().version, 1);
        drop(old);
        assert_eq!(drops.load(Ordering::SeqCst), 1); // ord: test-only
    }

    #[test]
    fn drop_reclaims_exactly_once() {
        let drops = Arc::new(StdAtomicU64::new(0));
        let outstanding = {
            let cell = SnapshotCell::new(Versioned::new(0, &drops));
            let held = cell.load();
            drop(cell.store(Versioned::new(1, &drops)));
            // v0 survives the store because `held` still references it.
            assert_eq!(drops.load(Ordering::SeqCst), 0); // ord: test-only
            held
        };
        // Cell dropped → v1 reclaimed; v0 still alive through `outstanding`.
        assert_eq!(drops.load(Ordering::SeqCst), 1); // ord: test-only
        assert_eq!(outstanding.version, 0);
        drop(outstanding);
        assert_eq!(drops.load(Ordering::SeqCst), 2); // ord: test-only
    }

    #[test]
    fn concurrent_readers_never_see_torn_or_stale_regressing_values() {
        // Bounded stress (the real adversarial coverage is the model
        // suite): readers assert shadow integrity and per-thread
        // monotone versions while a writer publishes continuously.
        let (readers, stores, loads) = if cfg!(miri) { (2, 10, 25) } else { (4, 200, 2_000) };
        let drops = Arc::new(StdAtomicU64::new(0));
        let cell = Arc::new(SnapshotCell::new(Versioned::new(0, &drops)));
        let mut handles = Vec::new();
        for _ in 0..readers {
            let cell = Arc::clone(&cell);
            handles.push(std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..loads {
                    let v = cell.load();
                    assert_eq!(v.shadow, v.version.wrapping_mul(7).wrapping_add(13));
                    assert!(v.version >= last, "version regressed: {} < {last}", v.version);
                    last = v.version;
                }
            }));
        }
        for i in 1..=stores {
            drop(cell.store(Versioned::new(i, &drops)));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cell.load().version, stores);
        drop(cell);
        // Every published version was reclaimed exactly once: stores
        // superseded (`stores`) plus the final value in the cell.
        assert_eq!(drops.load(Ordering::SeqCst), stores + 1); // ord: test-only
    }
}
