//! Deterministic-schedule concurrency model checker ("loom-lite").
//!
//! Only compiled under `--features model`.  Real OS threads execute the
//! test body, but a cooperative [`Scheduler`] lets exactly one thread
//! make progress at a time: every non-`Relaxed` atomic operation, lock
//! acquisition/release, spawn, join, and explicit yield is a *decision
//! point* where the scheduler picks which runnable thread executes
//! next.  A schedule is therefore a finite sequence of choices, and an
//! execution is fully determined by that sequence — no wall clock, no
//! OS-scheduler dependence.
//!
//! Two explorers drive schedules over a body:
//!
//! * [`explore`] — seeded random schedules (PCT-flavored: uniform
//!   choice among runnable threads, with "polite" spin-waiters
//!   deprioritized so waits can't starve their victims).  Each seed
//!   deterministically yields one schedule.
//! * [`explore_exhaustive`] — bounded DFS over *every* choice
//!   sequence of a small body, using the classic stateless-search
//!   prefix-stack: replay a forced prefix, default to choice 0 after
//!   it, and push every unexplored sibling.
//!
//! Failures (assertion panics in any model thread, deadlocks, step
//! budget exhaustion) abort the whole run and surface the seed or the
//! exact choice trace plus a ready-to-paste replay command.  See the
//! [`crate::sync`] module docs for the env-var replay protocol
//! (`MODEL_SEED`, `MODEL_TRACE`, `MODEL_SCHEDULES`, `MODEL_MAX_STEPS`).

use std::cell::RefCell;
use std::collections::HashSet;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::sync::{PoisonError, TryLockError, TryLockResult};

/// Default per-schedule step budget (decision points before the run is
/// declared livelocked).  Override with `MODEL_MAX_STEPS`.
pub const DEFAULT_MAX_STEPS: u64 = 20_000;

// ---------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------

/// Sentinel panic payload used to unwind threads of an aborted run.
/// Never escapes [`run_once`]: the runner maps it back to the primary
/// failure recorded in the scheduler.
struct ModelAbort;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Status {
    Ready,
    /// Waiting to acquire the model mutex with this id.
    BlockedMutex(usize),
    /// Waiting for the model thread with this id to finish.
    BlockedJoin(usize),
    Finished,
}

struct ThreadInfo {
    status: Status,
    /// A polite thread is spin-waiting on someone else's progress; the
    /// scheduler prefers impolite (productive) threads when any exist.
    polite: bool,
}

struct State {
    threads: Vec<ThreadInfo>,
    /// Logical owner of the execution token.
    current: usize,
    /// Decision points taken so far.
    steps: u64,
    max_steps: u64,
    /// Chosen candidate index at each decision point.
    trace: Vec<u32>,
    /// Candidate count at each decision point (for sibling expansion).
    branches: Vec<u32>,
    /// Forced prefix of choices (replay / DFS prefix).
    replay: Vec<u32>,
    /// xorshift64* state; `None` = DFS mode (default choice 0).
    rng: Option<u64>,
    /// First failure wins; everything after unwinds via [`ModelAbort`].
    abort: Option<String>,
    /// OS handles of spawned model threads, joined by [`run_once`].
    os_handles: Vec<std::thread::JoinHandle<()>>,
}

struct Scheduler {
    state: StdMutex<State>,
    cv: Condvar,
}

thread_local! {
    /// (scheduler, my thread id) while executing inside a model run.
    static CURRENT: RefCell<Option<(Arc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

fn current() -> Option<(Arc<Scheduler>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

fn xorshift(x: &mut u64) -> u64 {
    *x ^= *x >> 12;
    *x ^= *x << 25;
    *x ^= *x >> 27;
    x.wrapping_mul(0x2545_F491_4F6C_DD1D)
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

impl Scheduler {
    fn new(replay: Vec<u32>, seed: Option<u64>, max_steps: u64) -> Arc<Self> {
        Arc::new(Self {
            state: StdMutex::new(State {
                threads: vec![ThreadInfo { status: Status::Ready, polite: false }],
                current: 0,
                steps: 0,
                max_steps,
                trace: Vec::new(),
                branches: Vec::new(),
                replay,
                rng: seed.map(|s| splitmix(s) | 1),
                abort: None,
                os_handles: Vec::new(),
            }),
            cv: Condvar::new(),
        })
    }

    fn panic_abort() -> ! {
        std::panic::panic_any(ModelAbort)
    }

    /// Record the first failure and wake everyone so they can unwind.
    fn set_abort(&self, msg: String) {
        let mut st = self.state.lock().unwrap();
        if st.abort.is_none() {
            st.abort = Some(msg);
        }
        self.cv.notify_all();
    }

    /// The decision point: set my new status, pick who runs next, and
    /// (if that isn't me, or I just blocked) wait for my turn.
    fn switch(&self, me: usize, new_status: Status, polite: bool) {
        let mut st = self.state.lock().unwrap();
        if st.abort.is_some() {
            drop(st);
            Self::panic_abort();
        }
        st.threads[me].status = new_status;
        st.threads[me].polite = polite;

        st.steps += 1;
        if st.steps > st.max_steps {
            let steps = st.steps;
            st.abort = Some(format!(
                "step budget exceeded after {steps} decision points \
                 (possible livelock; raise MODEL_MAX_STEPS if the body is \
                 legitimately this long)"
            ));
            self.cv.notify_all();
            drop(st);
            Self::panic_abort();
        }

        let ready: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Ready)
            .map(|(i, _)| i)
            .collect();
        if ready.is_empty() {
            let detail: Vec<String> = st
                .threads
                .iter()
                .enumerate()
                .map(|(i, t)| format!("t{i}:{:?}", t.status))
                .collect();
            st.abort = Some(format!("deadlock: no runnable threads [{}]", detail.join(" ")));
            self.cv.notify_all();
            drop(st);
            Self::panic_abort();
        }
        // Prefer impolite (productive) threads; a spin-waiter only runs
        // when nothing productive is runnable.  This keeps waits finite
        // under the DFS default-0 policy and starvation-free in random
        // mode.
        let impolite: Vec<usize> =
            ready.iter().copied().filter(|&i| !st.threads[i].polite).collect();
        let candidates = if impolite.is_empty() { ready } else { impolite };

        let step_idx = st.trace.len();
        let n = candidates.len() as u32;
        let choice = if step_idx < st.replay.len() {
            st.replay[step_idx].min(n - 1)
        } else if let Some(ref mut rng) = st.rng {
            (xorshift(rng) % n as u64) as u32
        } else {
            0
        };
        st.trace.push(choice);
        st.branches.push(n);
        st.current = candidates[choice as usize];
        self.cv.notify_all();

        while !(st.current == me && st.threads[me].status == Status::Ready) {
            if st.abort.is_some() {
                drop(st);
                Self::panic_abort();
            }
            st = self.cv.wait(st).unwrap();
        }
        if st.abort.is_some() {
            drop(st);
            Self::panic_abort();
        }
    }

    /// A freshly spawned model thread parks here until first scheduled.
    fn wait_first(&self, me: usize) {
        let mut st = self.state.lock().unwrap();
        while !(st.current == me && st.threads[me].status == Status::Ready) {
            if st.abort.is_some() {
                drop(st);
                Self::panic_abort();
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Wake every thread blocked on mutex `mid` (they re-contend when
    /// scheduled).  The releaser keeps the execution token.
    fn mutex_released(&self, mid: usize) {
        let mut st = self.state.lock().unwrap();
        for t in st.threads.iter_mut() {
            if t.status == Status::BlockedMutex(mid) {
                t.status = Status::Ready;
                t.polite = false;
            }
        }
        self.cv.notify_all();
    }

    /// Mark `me` finished, wake joiners, and hand the token onward.
    /// Does not wait (the OS thread exits after this).
    fn finish(&self, me: usize) {
        let mut st = self.state.lock().unwrap();
        st.threads[me].status = Status::Finished;
        for t in st.threads.iter_mut() {
            if t.status == Status::BlockedJoin(me) {
                t.status = Status::Ready;
                t.polite = false;
            }
        }
        if st.abort.is_some() {
            self.cv.notify_all();
            return;
        }
        let ready: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Ready)
            .map(|(i, _)| i)
            .collect();
        if let Some(&next) = ready.first() {
            // Handing off after a finish is not a recorded decision
            // point: with the finisher gone there is no interleaving
            // freedom to explore at this instant that the next regular
            // decision point doesn't already cover.
            st.current = next;
        } else if st.threads.iter().any(|t| {
            matches!(t.status, Status::BlockedMutex(_) | Status::BlockedJoin(_))
        }) {
            let detail: Vec<String> = st
                .threads
                .iter()
                .enumerate()
                .map(|(i, t)| format!("t{i}:{:?}", t.status))
                .collect();
            st.abort =
                Some(format!("deadlock after t{me} finished [{}]", detail.join(" ")));
        }
        self.cv.notify_all();
    }
}

/// Yield at a synchronization point.  No-op outside a model run, so the
/// entire normal test suite also runs under `--features model`.
pub fn yield_point() {
    if let Some((sched, me)) = current() {
        sched.switch(me, Status::Ready, false);
    }
}

/// Polite yield: the current thread is spin-waiting on someone else and
/// asks to be deprioritized.  Falls back to an OS yield outside a run.
pub fn polite_yield() {
    if let Some((sched, me)) = current() {
        sched.switch(me, Status::Ready, true);
    } else {
        // lint_sync: allow — model-internal fallback outside a run.
        #[allow(clippy::disallowed_methods)]
        std::thread::yield_now();
    }
}

// ---------------------------------------------------------------------
// spawn / join
// ---------------------------------------------------------------------

type Slot<T> = Arc<StdMutex<Option<std::thread::Result<T>>>>;

/// Handle to a model thread.  `join` blocks *logically* (the scheduler
/// keeps exploring other threads) rather than on the OS.
pub struct JoinHandle<T> {
    id: usize,
    slot: Slot<T>,
    /// Set only when spawned outside a model run (plain passthrough).
    os: Option<std::thread::JoinHandle<()>>,
}

impl<T> JoinHandle<T> {
    /// Wait for the thread to finish and return its result, exactly
    /// like `std::thread::JoinHandle::join`.
    pub fn join(self) -> std::thread::Result<T> {
        if let Some((sched, me)) = current() {
            loop {
                if let Some(r) = self.slot.lock().unwrap().take() {
                    return r;
                }
                sched.switch(me, Status::BlockedJoin(self.id), false);
            }
        }
        if let Some(os) = self.os {
            let _ = os.join();
        }
        self.slot
            .lock()
            .unwrap()
            .take()
            .expect("model thread finished without storing a result")
    }
}

/// Spawn a model thread.  Inside a run the new thread is registered
/// with the scheduler and only executes when scheduled; outside a run
/// this degrades to `std::thread::spawn`.
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let slot: Slot<T> = Arc::new(StdMutex::new(None));
    if let Some((sched, me)) = current() {
        let id = {
            let mut st = sched.state.lock().unwrap();
            st.threads.push(ThreadInfo { status: Status::Ready, polite: false });
            st.threads.len() - 1
        };
        let slot2 = Arc::clone(&slot);
        let sched2 = Arc::clone(&sched);
        let os = std::thread::spawn(move || {
            CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched2), id)));
            let result = catch_unwind(AssertUnwindSafe(|| {
                sched2.wait_first(id);
                f()
            }));
            match result {
                Ok(v) => *slot2.lock().unwrap() = Some(Ok(v)),
                Err(payload) => {
                    if payload.downcast_ref::<ModelAbort>().is_none() {
                        sched2.set_abort(panic_message(&payload));
                    }
                    *slot2.lock().unwrap() = Some(Err(payload));
                }
            }
            CURRENT.with(|c| *c.borrow_mut() = None);
            sched2.finish(id);
        });
        sched.state.lock().unwrap().os_handles.push(os);
        // Spawning is a synchronization point: give the explorer the
        // chance to run the child before the parent's next step.
        sched.switch(me, Status::Ready, false);
        JoinHandle { id, slot, os: None }
    } else {
        let slot2 = Arc::clone(&slot);
        let os = std::thread::spawn(move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            *slot2.lock().unwrap() = Some(result);
        });
        JoinHandle { id: usize::MAX, slot, os: Some(os) }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "model thread panicked (non-string payload)".to_string()
    }
}

// ---------------------------------------------------------------------
// Single-run driver
// ---------------------------------------------------------------------

/// One failed schedule, with everything needed to replay it.
#[derive(Debug)]
pub struct Failure {
    /// Primary failure (first panic / deadlock / budget message).
    pub msg: String,
    /// The exact choice trace of the failing run.
    pub trace: Vec<u32>,
    /// Seed, when the run was driven by one.
    pub seed: Option<u64>,
}

impl Failure {
    fn trace_csv(&self) -> String {
        self.trace.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    }
}

impl fmt::Display for Failure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "model check failed: {}", self.msg)?;
        if let Some(seed) = self.seed {
            writeln!(f, "  replay: MODEL_SEED={seed} cargo test --features model")?;
        }
        write!(
            f,
            "  replay: MODEL_TRACE={} cargo test --features model",
            self.trace_csv()
        )
    }
}

/// Execute `body` once under a fixed schedule policy.  Returns the
/// choice trace on success.
fn run_once(
    replay: Vec<u32>,
    seed: Option<u64>,
    max_steps: u64,
    body: &dyn Fn(),
) -> Result<(Vec<u32>, Vec<u32>), Failure> {
    let sched = Scheduler::new(replay, seed, max_steps);
    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&sched), 0)));
    let result = catch_unwind(AssertUnwindSafe(body));
    CURRENT.with(|c| *c.borrow_mut() = None);
    if let Err(payload) = result {
        if payload.downcast_ref::<ModelAbort>().is_none() {
            sched.set_abort(panic_message(&payload));
        }
    }
    // Hand the token to any still-running children so they can drain
    // (or unwind, if the run aborted), then reap the OS threads.
    sched.finish(0);
    loop {
        let os = {
            let mut st = sched.state.lock().unwrap();
            std::mem::take(&mut st.os_handles)
        };
        if os.is_empty() {
            break;
        }
        for h in os {
            let _ = h.join();
        }
    }
    let st = sched.state.lock().unwrap();
    match &st.abort {
        Some(msg) => {
            Err(Failure { msg: msg.clone(), trace: st.trace.clone(), seed })
        }
        None => Ok((st.trace.clone(), st.branches.clone())),
    }
}

/// Run `body` once under the schedule derived from `seed`.  Returns the
/// trace on success; use this to *search* for a failing seed (regression
/// tests pin historical races this way).
pub fn try_seed(seed: u64, max_steps: u64, body: &dyn Fn()) -> Result<Vec<u32>, Failure> {
    run_once(Vec::new(), Some(seed), max_steps, body).map(|(t, _)| t)
}

/// Replay one exact choice trace (choices past the end default to 0).
pub fn replay_trace(trace: &[u32], max_steps: u64, body: &dyn Fn()) -> Result<Vec<u32>, Failure> {
    run_once(trace.to_vec(), None, max_steps, body).map(|(t, _)| t)
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.trim().parse().ok())
}

fn env_trace() -> Option<Vec<u32>> {
    let raw = std::env::var("MODEL_TRACE").ok()?;
    Some(
        raw.split(',')
            .filter(|s| !s.trim().is_empty())
            .filter_map(|s| s.trim().parse().ok())
            .collect(),
    )
}

fn hash_trace(trace: &[u32]) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    trace.hash(&mut h);
    h.finish()
}

/// Explore `schedules` random seeds over `body`, panicking with replay
/// instructions on the first failure.  Returns the number of *distinct*
/// schedules (unique choice traces) observed.
///
/// Env overrides: `MODEL_SEED` pins a single seed, `MODEL_TRACE`
/// replays one trace, `MODEL_SCHEDULES` overrides the count,
/// `MODEL_MAX_STEPS` overrides the step budget.
pub fn explore(name: &str, schedules: usize, body: impl Fn()) -> usize {
    let max_steps = env_u64("MODEL_MAX_STEPS").unwrap_or(DEFAULT_MAX_STEPS);
    if let Some(trace) = env_trace() {
        match replay_trace(&trace, max_steps, &body) {
            Ok(_) => return 1,
            Err(f) => panic!("[{name}] {f}"),
        }
    }
    if let Some(seed) = env_u64("MODEL_SEED") {
        match try_seed(seed, max_steps, &body) {
            Ok(_) => return 1,
            Err(f) => panic!("[{name}] {f}"),
        }
    }
    let schedules = env_u64("MODEL_SCHEDULES").map(|n| n as usize).unwrap_or(schedules);
    // Fixed base so runs are reproducible without any env; per-name salt
    // so different tests don't correlate their seed streams.
    let base = splitmix(0xB1A0_0001 ^ hash_trace(&[name.len() as u32]));
    let mut distinct = HashSet::new();
    for i in 0..schedules {
        let seed = base.wrapping_add(i as u64);
        match try_seed(seed, max_steps, &body) {
            Ok(trace) => {
                distinct.insert(hash_trace(&trace));
            }
            Err(f) => panic!("[{name}] {f}"),
        }
    }
    distinct.len()
}

/// Exhaustively enumerate every schedule of `body` (bounded by
/// `max_schedules` runs), panicking with the exact failing trace on the
/// first failure.  Returns the number of schedules executed; if the
/// bound was hit before the space was exhausted, the count equals
/// `max_schedules` and remaining prefixes were dropped.
pub fn explore_exhaustive(name: &str, max_schedules: usize, body: impl Fn()) -> usize {
    let max_steps = env_u64("MODEL_MAX_STEPS").unwrap_or(DEFAULT_MAX_STEPS);
    if let Some(trace) = env_trace() {
        match replay_trace(&trace, max_steps, &body) {
            Ok(_) => return 1,
            Err(f) => panic!("[{name}] {f}"),
        }
    }
    let mut stack: Vec<Vec<u32>> = vec![Vec::new()];
    let mut runs = 0usize;
    while let Some(prefix) = stack.pop() {
        if runs >= max_schedules {
            break;
        }
        let plen = prefix.len();
        match run_once(prefix, None, max_steps, &body) {
            Ok((trace, branches)) => {
                runs += 1;
                // Push every unexplored sibling at or past the forced
                // prefix (positions inside the prefix were expanded when
                // the prefix itself was generated).
                for i in plen..trace.len() {
                    for alt in (trace[i] + 1)..branches[i] {
                        let mut p = trace[..i].to_vec();
                        p.push(alt);
                        stack.push(p);
                    }
                }
            }
            Err(f) => panic!("[{name}] after {runs} schedules: {f}"),
        }
    }
    runs
}

// ---------------------------------------------------------------------
// Instrumented Mutex
// ---------------------------------------------------------------------

static NEXT_MUTEX_ID: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Scheduler-aware mutex.  Inside a model run, contention parks the
/// thread in the scheduler (`BlockedMutex`) instead of the OS, so the
/// explorer controls who wins the lock; outside a run it behaves as a
/// plain `std::sync::Mutex`.
#[derive(Debug)]
pub struct Mutex<T: ?Sized> {
    id: usize,
    /// Logical ownership inside a model run; the inner std mutex is
    /// then always uncontended.
    flag: std::sync::atomic::AtomicBool,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// New unlocked mutex.
    pub fn new(value: T) -> Self {
        Self {
            id: NEXT_MUTEX_ID.fetch_add(1, Ordering::Relaxed), // ord: Relaxed — unique-id counter; nothing is published through it
            flag: std::sync::atomic::AtomicBool::new(false),
            inner: StdMutex::new(value),
        }
    }

    /// Consume the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    /// Acquire, blocking (logically, inside a run) until available.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((sched, me)) = current() {
            sched.switch(me, Status::Ready, false);
            while self.flag.swap(true, Ordering::SeqCst) { // ord: SeqCst — logical ownership flag; model-only code, strongest order by policy
                sched.switch(me, Status::BlockedMutex(self.id), false);
            }
            let inner = self
                .inner
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            Ok(MutexGuard { lock: self, inner: Some(inner), in_run: true })
        } else {
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), in_run: false }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    in_run: false,
                })),
            }
        }
    }

    /// Non-blocking acquire.
    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        if let Some((sched, me)) = current() {
            sched.switch(me, Status::Ready, false);
            if self.flag.swap(true, Ordering::SeqCst) { // ord: SeqCst — symmetric with `lock`
                return Err(TryLockError::WouldBlock);
            }
            let inner = self
                .inner
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            Ok(MutexGuard { lock: self, inner: Some(inner), in_run: true })
        } else {
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), in_run: false }),
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
                Err(TryLockError::Poisoned(p)) => {
                    Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                        in_run: false,
                    })))
                }
            }
        }
    }

    /// Mutable access without locking (requires `&mut self`).
    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Self::new(T::default())
    }
}

/// Guard for [`Mutex`]; releasing wakes scheduler-blocked waiters.
#[derive(Debug)]
pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    in_run: bool,
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after drop")
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after drop")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // Drop the std guard first so the data is consistent before any
        // waiter can win the flag.
        self.inner = None;
        if self.in_run {
            self.lock.flag.store(false, Ordering::SeqCst); // ord: SeqCst — release of the logical ownership flag
            if let Some((sched, _)) = current() {
                sched.mutex_released(self.lock.id);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Instrumented atomics
// ---------------------------------------------------------------------

#[inline]
fn sync_hook(order: Ordering) {
    // Relaxed ops (metric counters) are not decision points — they have
    // no inter-thread ordering role, and instrumenting them would blow
    // up the schedule space without adding coverage.
    if !matches!(order, Ordering::Relaxed) { // ord: n/a — variant inspection, not an atomic operation
        yield_point();
    }
}

macro_rules! model_int_atomic {
    ($name:ident, $std:ty, $ty:ty) => {
        /// Instrumented atomic: every non-`Relaxed` operation is a
        /// scheduler decision point inside a model run.
        #[derive(Debug, Default)]
        pub struct $name {
            inner: $std,
        }

        impl $name {
            /// New atomic with the given initial value.
            pub const fn new(v: $ty) -> Self {
                Self { inner: <$std>::new(v) }
            }

            /// See the `std` atomic of the same name.
            pub fn load(&self, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.load(order)
            }

            /// See the `std` atomic of the same name.
            pub fn store(&self, v: $ty, order: Ordering) {
                sync_hook(order);
                self.inner.store(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn swap(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.swap(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_add(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_add(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_sub(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_sub(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_or(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_or(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_and(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_and(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_max(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_max(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn fetch_min(&self, v: $ty, order: Ordering) -> $ty {
                sync_hook(order);
                self.inner.fetch_min(v, order)
            }

            /// See the `std` atomic of the same name.
            pub fn compare_exchange(
                &self,
                cur: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                sync_hook(success);
                self.inner.compare_exchange(cur, new, success, failure)
            }

            /// See the `std` atomic of the same name.
            pub fn compare_exchange_weak(
                &self,
                cur: $ty,
                new: $ty,
                success: Ordering,
                failure: Ordering,
            ) -> Result<$ty, $ty> {
                sync_hook(success);
                self.inner.compare_exchange_weak(cur, new, success, failure)
            }

            /// See the `std` atomic of the same name.
            pub fn into_inner(self) -> $ty {
                self.inner.into_inner()
            }

            /// See the `std` atomic of the same name.
            pub fn get_mut(&mut self) -> &mut $ty {
                self.inner.get_mut()
            }
        }
    };
}

model_int_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
model_int_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
model_int_atomic!(AtomicI64, std::sync::atomic::AtomicI64, i64);

/// Instrumented `AtomicBool`; see [`AtomicU64`].
#[derive(Debug, Default)]
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
}

impl AtomicBool {
    /// New atomic with the given initial value.
    pub const fn new(v: bool) -> Self {
        Self { inner: std::sync::atomic::AtomicBool::new(v) }
    }

    /// See `std::sync::atomic::AtomicBool`.
    pub fn load(&self, order: Ordering) -> bool {
        sync_hook(order);
        self.inner.load(order)
    }

    /// See `std::sync::atomic::AtomicBool`.
    pub fn store(&self, v: bool, order: Ordering) {
        sync_hook(order);
        self.inner.store(v, order)
    }

    /// See `std::sync::atomic::AtomicBool`.
    pub fn swap(&self, v: bool, order: Ordering) -> bool {
        sync_hook(order);
        self.inner.swap(v, order)
    }

    /// See `std::sync::atomic::AtomicBool`.
    pub fn compare_exchange(
        &self,
        cur: bool,
        new: bool,
        success: Ordering,
        failure: Ordering,
    ) -> Result<bool, bool> {
        sync_hook(success);
        self.inner.compare_exchange(cur, new, success, failure)
    }
}

/// Instrumented `AtomicPtr`; see [`AtomicU64`].
#[derive(Debug)]
pub struct AtomicPtr<T> {
    inner: std::sync::atomic::AtomicPtr<T>,
}

impl<T> AtomicPtr<T> {
    /// New atomic with the given initial pointer.
    pub const fn new(p: *mut T) -> Self {
        Self { inner: std::sync::atomic::AtomicPtr::new(p) }
    }

    /// See `std::sync::atomic::AtomicPtr`.
    pub fn load(&self, order: Ordering) -> *mut T {
        sync_hook(order);
        self.inner.load(order)
    }

    /// See `std::sync::atomic::AtomicPtr`.
    pub fn store(&self, p: *mut T, order: Ordering) {
        sync_hook(order);
        self.inner.store(p, order)
    }

    /// See `std::sync::atomic::AtomicPtr`.
    pub fn swap(&self, p: *mut T, order: Ordering) -> *mut T {
        sync_hook(order);
        self.inner.swap(p, order)
    }

    /// See `std::sync::atomic::AtomicPtr`.
    pub fn compare_exchange(
        &self,
        cur: *mut T,
        new: *mut T,
        success: Ordering,
        failure: Ordering,
    ) -> Result<*mut T, *mut T> {
        sync_hook(success);
        self.inner.compare_exchange(cur, new, success, failure)
    }

    /// See `std::sync::atomic::AtomicPtr`.
    pub fn get_mut(&mut self) -> &mut *mut T {
        self.inner.get_mut()
    }
}

impl<T> Default for AtomicPtr<T> {
    fn default() -> Self {
        Self::new(std::ptr::null_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The classic lost-update race: two threads do load-then-store
    /// increments.  The explorer must find a schedule where an update
    /// is lost — and that failing seed must replay deterministically.
    fn lost_update_body() -> u64 {
        let counter = Arc::new(AtomicU64::new(0));
        let mut hs = Vec::new();
        for _ in 0..2 {
            let c = Arc::clone(&counter);
            hs.push(spawn(move || {
                let v = c.load(Ordering::SeqCst); // ord: test-only
                c.store(v + 1, Ordering::SeqCst); // ord: test-only
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        counter.load(Ordering::SeqCst) // ord: test-only
    }

    #[test]
    fn explorer_finds_lost_update() {
        let mut failing_seed = None;
        for seed in 0..256u64 {
            let r = try_seed(seed, 1000, &|| {
                assert_eq!(lost_update_body(), 2, "lost update");
            });
            if r.is_err() {
                failing_seed = Some(seed);
                break;
            }
        }
        let seed = failing_seed.expect("random exploration should hit the lost update");
        // Deterministic: the same seed fails again, twice.
        for _ in 0..2 {
            let err = try_seed(seed, 1000, &|| {
                assert_eq!(lost_update_body(), 2, "lost update");
            })
            .expect_err("failing seed must replay deterministically");
            assert!(err.msg.contains("lost update"), "got: {}", err.msg);
            // And the printed trace replays to the same failure.
            let err2 = replay_trace(&err.trace, 1000, &|| {
                assert_eq!(lost_update_body(), 2, "lost update");
            })
            .expect_err("trace replay must reproduce the failure");
            assert!(err2.msg.contains("lost update"));
        }
    }

    #[test]
    fn exhaustive_finds_lost_update_and_counts_atomic_commit() {
        // The racy body must fail somewhere in the full schedule space.
        let r = catch_unwind(AssertUnwindSafe(|| {
            explore_exhaustive("lost-update", 10_000, || {
                assert_eq!(lost_update_body(), 2, "lost update");
            })
        }));
        assert!(r.is_err(), "exhaustive search must find the lost update");

        // The fetch_add version is correct under every schedule.
        let runs = explore_exhaustive("fetch-add", 10_000, || {
            let counter = Arc::new(AtomicU64::new(0));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let c = Arc::clone(&counter);
                    spawn(move || {
                        c.fetch_add(1, Ordering::SeqCst); // ord: test-only
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(counter.load(Ordering::SeqCst), 2); // ord: test-only
        });
        assert!(runs > 1, "expected multiple schedules, got {runs}");
    }

    #[test]
    fn mutex_excludes_and_deadlock_is_detected() {
        // Mutual exclusion: lock-protected read-modify-write never
        // loses updates under any schedule.
        let runs = explore_exhaustive("mutex-rmw", 10_000, || {
            let m = Arc::new(Mutex::new(0u64));
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    spawn(move || {
                        let mut g = m.lock().unwrap();
                        *g += 1;
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*m.lock().unwrap(), 2);
        });
        assert!(runs > 1);

        // A child that never finishes while holding the lock the root
        // needs → deadlock, reported (not hung).
        let err = try_seed(0, 1000, &|| {
            let m = Arc::new(Mutex::new(()));
            let m2 = Arc::clone(&m);
            let g = m.lock().unwrap();
            let h = spawn(move || {
                let _g = m2.lock().unwrap();
            });
            // Root joins while holding the lock the child wants.
            drop(h.join());
            drop(g);
        })
        .expect_err("must detect deadlock");
        assert!(err.msg.contains("deadlock"), "got: {}", err.msg);
    }

    #[test]
    fn polite_yield_keeps_spin_waits_finite() {
        // Waiter politely spins for a flag the child sets.  Under the
        // DFS default-0 policy this terminates only because polite
        // threads are deprioritized.
        let runs = explore_exhaustive("polite-spin", 10_000, || {
            let flag = Arc::new(AtomicBool::new(false));
            let f = Arc::clone(&flag);
            let h = spawn(move || {
                f.store(true, Ordering::SeqCst); // ord: test-only
            });
            while !flag.load(Ordering::SeqCst) { // ord: test-only
                polite_yield();
            }
            h.join().unwrap();
        });
        assert!(runs >= 1);
    }

    #[test]
    fn explore_counts_distinct_schedules() {
        let distinct = explore("distinct", 200, || {
            let x = Arc::new(AtomicU64::new(0));
            let hs: Vec<_> = (0..3)
                .map(|i| {
                    let x = Arc::clone(&x);
                    spawn(move || {
                        x.fetch_add(i + 1, Ordering::SeqCst); // ord: test-only
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(x.load(Ordering::SeqCst), 6); // ord: test-only
        });
        assert!(distinct > 10, "3 racing adders must yield many schedules, got {distinct}");
    }
}
