//! Synchronization shim: one import surface, two build personalities.
//!
//! Every concurrent module in this crate (`router`, `shard`, `metrics`,
//! `rebalance`, `cluster`) imports its synchronization primitives from
//! here instead of `std::sync`.  The boundary is enforced by
//! `tools/lint_sync.py` (run in the CI lint step): a direct
//! `std::sync::atomic` / `std::sync::Mutex` / `std::sync::Arc` import
//! anywhere else in `rust/src/` fails the build.
//!
//! ## Normal builds (default)
//!
//! The shim is a set of zero-cost `pub use` re-exports of the exact
//! `std` types the code always used — `AtomicU64` here *is*
//! `std::sync::atomic::AtomicU64`, `Mutex` *is* `std::sync::Mutex`.
//! There is no wrapper struct, no extra branch, no codegen difference:
//! `zero_alloc.rs` and the `router_hotpath` bench measure the same
//! machine code as before the shim existed.
//!
//! ## Model builds (`--features model`)
//!
//! With the `model` cargo feature the same names resolve to the
//! instrumented primitives in [`model`]: atomics and mutexes that, when
//! executed inside a [`model::run`] closure, hand control to a
//! deterministic cooperative scheduler at every non-`Relaxed` atomic
//! operation, every lock acquisition/release, and every explicit
//! [`model_yield`] point.  The scheduler runs real OS threads but lets
//! only one make progress at a time, so a *schedule* — the sequence of
//! "which thread runs next" choices — fully determines the execution.
//!
//! Two explorers drive schedules over a test body:
//!
//! * [`model::explore`] — seeded PCT-style random schedules.  Each seed
//!   deterministically produces one schedule; thousands of seeds explore
//!   thousands of interleavings.
//! * [`model::explore_exhaustive`] — bounded depth-first enumeration of
//!   *every* schedule of a small test body.
//!
//! ### Replaying a failing seed
//!
//! A model-test failure prints the seed (and, for exhaustive search, the
//! exact choice trace) that produced it.  To replay locally:
//!
//! ```text
//! MODEL_SEED=4242 cargo test --features model --test model -- gate_
//! # or, for an explicit choice trace:
//! MODEL_TRACE=0,1,1,0,2 cargo test --features model --test model -- gate_
//! ```
//!
//! `MODEL_SEED` pins [`model::explore`] to a single seed;
//! `MODEL_TRACE` replays one exact schedule.  `MODEL_SCHEDULES` and
//! `MODEL_MAX_STEPS` override the schedule count and per-run step
//! budget.  The scheduler is deterministic by construction (no wall
//! clock, no OS-scheduler dependence), so a replay reproduces the
//! failure every time, on any machine.
//!
//! ### What the model checker does and does not see
//!
//! The scheduler serializes all instrumented operations, so every
//! explored execution is *sequentially consistent*.  It therefore finds
//! logic races (lost updates, torn publication protocols, ordering bugs
//! between distinct atomics, use-after-reclaim in refcount protocols)
//! but cannot observe weak-memory reorderings that a `Relaxed`/`Acquire`
//! mismatch would permit on real hardware.  The CI matrix covers that
//! axis separately: ThreadSanitizer (real weak-memory race detection)
//! and Miri (UB detection, including some weak-memory modelling) run
//! over the same code because normal builds use the untouched `std`
//! primitives.
//!
//! ## Spin loops and `Backoff`
//!
//! The shim deliberately does *not* re-export `std::thread::sleep`,
//! `std::thread::yield_now`, or `std::hint::spin_loop` — those are
//! disallowed crate-wide via `clippy.toml` precisely because a raw spin
//! loop is invisible to the model scheduler (and would livelock the
//! exhaustive explorer, which always tries "keep running the current
//! thread" first).  Product code that waits for another thread uses
//! [`Backoff`], whose `snooze()` is a progressive spin→yield→sleep
//! ladder in normal builds and a *polite* scheduler yield in model
//! builds (the scheduler deprioritizes a polite thread so its victim
//! gets scheduled, keeping exploration finite).

#[cfg(feature = "model")]
pub mod model;

pub mod cell;
pub mod handoff;

// ---------------------------------------------------------------------
// Normal builds: zero-cost re-exports of std.
// ---------------------------------------------------------------------

#[cfg(not(feature = "model"))]
pub use std::sync::atomic::{
    AtomicBool, AtomicI64, AtomicPtr, AtomicU64, AtomicUsize, Ordering,
};

#[cfg(not(feature = "model"))]
pub use std::sync::{Arc, LockResult, Mutex, MutexGuard, TryLockResult, Weak};

// ---------------------------------------------------------------------
// Model builds: instrumented substitutes.  `Arc`/`Weak` stay std's —
// refcount protocols are exercised through the atomics and explicit
// model_yield points, and the scheduler serializes all of them.
// ---------------------------------------------------------------------

#[cfg(feature = "model")]
pub use model::{AtomicBool, AtomicI64, AtomicPtr, AtomicU64, AtomicUsize, Mutex, MutexGuard};

#[cfg(feature = "model")]
pub use std::sync::atomic::Ordering;

#[cfg(feature = "model")]
pub use std::sync::{Arc, LockResult, TryLockResult, Weak};

/// Hint to the model scheduler that this is an interesting interleaving
/// point (e.g. between a raw-pointer load and the refcount increment
/// that makes it safe).  Free in normal builds.
#[inline(always)]
pub fn model_yield() {
    #[cfg(feature = "model")]
    model::yield_point();
}

/// Polite yield for product-code spin loops (see [`Backoff`]).  In
/// normal builds this is a plain OS-thread yield; in model builds it
/// deprioritizes the current thread so the thread being waited on runs.
#[inline]
pub fn spin_yield() {
    #[cfg(feature = "model")]
    model::polite_yield();
    #[cfg(not(feature = "model"))]
    // lint_sync: allow — the shim is the one place allowed to touch the
    // raw primitive; everyone else goes through Backoff/spin_yield.
    #[allow(clippy::disallowed_methods)]
    std::thread::yield_now();
}

/// Progressive backoff for bounded waits on another thread's progress.
///
/// Normal builds: spin (`spin_loop`) for the first few rounds, then
/// OS-yield, then exponentially growing sleeps capped at 3.2 ms — the
/// same ladder the router's quiesce loop always used.  Model builds:
/// every `snooze()` is a polite scheduler yield, so waits cost one
/// schedule step instead of wall-clock time.
#[derive(Debug)]
pub struct Backoff {
    step: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Self::new()
    }
}

impl Backoff {
    const SPIN_LIMIT: u32 = 6;
    const YIELD_LIMIT: u32 = 10;

    /// Fresh backoff (starts at the cheap end of the ladder).
    pub const fn new() -> Self {
        Self { step: 0 }
    }

    /// Wait a little, escalating on each call.
    pub fn snooze(&mut self) {
        #[cfg(feature = "model")]
        {
            model::polite_yield();
        }
        #[cfg(not(feature = "model"))]
        {
            if self.step < Self::SPIN_LIMIT {
                for _ in 0..(1u32 << self.step) {
                    // lint_sync: allow — Backoff is the sanctioned home
                    // of the raw spin/yield/sleep primitives.
                    #[allow(clippy::disallowed_methods)]
                    std::hint::spin_loop();
                }
            } else if self.step < Self::YIELD_LIMIT {
                #[allow(clippy::disallowed_methods)]
                std::thread::yield_now();
            } else {
                // Exponential sleep: 50µs << n, capped at 3.2ms.
                let exp = (self.step - Self::YIELD_LIMIT).min(6);
                #[allow(clippy::disallowed_methods)]
                std::thread::sleep(std::time::Duration::from_micros(50u64 << exp));
            }
        }
        self.step = self.step.saturating_add(1);
    }

    /// Number of snoozes taken so far (for tests / diagnostics).
    pub fn steps(&self) -> u32 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_escalates_and_counts() {
        let mut b = Backoff::new();
        assert_eq!(b.steps(), 0);
        for _ in 0..8 {
            b.snooze();
        }
        assert_eq!(b.steps(), 8);
    }

    #[test]
    fn shim_atomics_are_usable() {
        let x = AtomicU64::new(1);
        x.fetch_add(2, Ordering::SeqCst); // ord: test-only, strongest is fine
        assert_eq!(x.load(Ordering::SeqCst), 3); // ord: test-only
        let m = Mutex::new(5u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 6);
    }

    #[test]
    fn model_yield_is_safe_outside_model_runs() {
        // Outside a model::run closure (or in normal builds) these are
        // no-ops; the whole normal test suite runs under
        // `--features model` because of this.
        model_yield();
        spin_yield();
    }
}
