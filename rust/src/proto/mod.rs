//! Wire protocol shared by the router front-end and the shard servers.
//!
//! Text-framed commands with binary value payloads (memcached-style):
//!
//! ```text
//! GET <key>\n                 -> VAL <len>\n<bytes>  |  NIL\n
//! PUT <key> <len>\n<bytes>    -> OK\n
//! PUTNX <key> <len>\n<bytes>  -> OK\n | NIL\n        (shard only)
//! DEL <key>\n                 -> OK\n | NIL\n
//! DELTOMB <key>\n             -> OK\n | NIL\n        (shard only)
//! SCAN\n                      -> KEYS <count>\n(<key>\n)*
//! SCANSTRIPE <i>\n            -> KEYS <count>\n(<key>\n)*  (shard only)
//! PURGETOMBS\n                -> NUM <count>\n       (shard only)
//! WIPE\n                      -> NUM <count>\n       (shard only)
//! DIGEST\n                    -> NUMS <n>( <x>)*\n   (shard only)
//! COUNT\n                     -> NUM <count>\n
//! STATS\n                     -> INFO <line>\n
//! SCALEUP\n                   -> NUM <new-n>\n        (router only)
//! SCALEDOWN\n                 -> NUM <new-n>\n        (router only)
//! FAIL <id>\n                 -> NUM <working-n>\n    (router only)
//! RESTORE <id>\n              -> NUM <working-n>\n    (router only)
//! ```
//!
//! ## Batched commands: one round-trip per keybatch
//!
//! Placement is O(1) nanoseconds; a round-trip is O(10µs–1ms).  The batch
//! frames let one round-trip carry up to [`MAX_BATCH`] keys, so the wire
//! cost amortizes across the batch (heavy readers and the rebalancer's
//! stripe streaming both use them):
//!
//! ```text
//! MGET <n> <k1> ... <kn>\n    -> MULTI <n>\n(<sub-response>)*
//! MDEL <n> <k1> ... <kn>\n    -> MULTI <n>\n(<sub-response>)*
//! MDELTOMB <n> <k1> ... <kn>\n                        (shard only)
//! MPUT <n> <k1> <l1> ... <kn> <ln>\n<bytes1>...<bytesn>
//! MPUTNX <n> ...              (same framing as MPUT)   (shard only)
//! ```
//!
//! `MPUT`/`MPUTNX` announce every key and payload length on the header
//! line, then stream the payloads back to back.  Every batch answers
//! `MULTI <n>` followed by exactly `n` positional sub-responses — the
//! i-th sub-response answers the i-th key, whatever the server did
//! internally to group the keys (see `router` for the fan-out ordering
//! guarantees).  Sub-responses are the singleton forms (`VAL`/`NIL` for
//! MGET, `OK`/`NIL`/`ERR` for the rest); `MULTI` never nests.
//!
//! Batch counts are hostile-input-hardened: a count above [`MAX_BATCH`],
//! a count/token-list mismatch, or an unparseable per-key length answers
//! a *recoverable* `ERR` (the header line was consumed; the connection
//! stays framed — though an `MPUT` client that already streamed payloads
//! after a bad header has desynced itself, exactly like a singleton `PUT`
//! with a bad length token), and no pre-allocation is sized from a
//! client-supplied count beyond the cap.  A put batch's payloads must
//! total at most [`MAX_VALUE_LEN`] — beyond that (or any truncated
//! payload) the stream is untrustworthy and the connection drops, as for
//! a singleton `PUT`.
//!
//! Keys are ASCII tokens without whitespace (the router rejects others);
//! values are arbitrary bytes.  Errors: `ERR <msg>\n`.
//!
//! ## Borrowed parsing: the zero-allocation server path
//!
//! The server loops parse with [`read_request_ref`] into a
//! [`RequestRef`] that *borrows* the command line from a per-connection
//! reusable [`RecvBuf`] — no per-request line `String` and no key
//! `to_string()`.  Batch frames parse the same way: the key list becomes
//! a span table (byte offsets into the line) reused across requests, and
//! a [`BatchRef`] view hands out `&str` keys by index — zero per-key
//! allocation however large the batch.  Value payloads are read once into
//! a freshly allocated [`Value`] (`Arc<[u8]>`) that then flows through
//! router, shard map and migration without ever being copied again; a GET
//! answers with a refcount bump of the stored `Arc`.  The owned
//! [`Request`] enum survives for admin paths, tests and client helpers
//! ([`RequestRef::into_owned`] / [`Request::as_view`] convert).
//!
//! Parse failures come in two severities, which is what keeps a typo from
//! killing a connection:
//!
//! * **recoverable** (unknown command, missing/invalid key, bad integer)
//!   — the line was consumed and the stream is still framed;
//!   [`read_request_ref`] yields [`Wire::Bad`] and the server answers
//!   `ERR <msg>` and keeps serving.  (A `PUT` whose *length* token was
//!   unparseable is reported this way too; if the client really sent a
//!   payload it has desynced itself — its next "commands" will error.)
//! * **framing / IO** (stream error, truncated payload, value above
//!   [`MAX_VALUE_LEN`]) — the byte stream is no longer trustworthy; the
//!   functions return `Err` and the server drops the connection.
//!
//! Responses are serialized into a per-connection output buffer with
//! [`encode_response`]; servers flush once per drained read burst, so a
//! pipelined client pays one syscall per burst, not one per response.
//!
//! `PUTNX` stores only if the key is absent (`NIL` = already present) and
//! `SCANSTRIPE` lists one lock stripe; both exist for the incremental
//! rebalancer.  `DELTOMB` removes a key *and* leaves a tombstone that
//! bars a later `PUTNX` from resurrecting it; `PURGETOMBS` clears the
//! tombstones once a migration settles.
//!
//! `FAIL <id>` / `RESTORE <id>` are the router's failover admin pair:
//! FAIL publishes a degraded epoch that routes around the dead shard
//! (O(1), no key movement — the dead shard's data is marooned and reads
//! of it answer `ERR UNAVAILABLE: …`), RESTORE rejoins it *empty* (the
//! router issues `WIPE` first: writes and deletes issued while it was
//! down never reached it, so its contents are stale) and migrates the
//! keys written to survivors in the interim back onto it.
//!
//! ## Two server personalities over one parser
//!
//! Everything above is I/O-model agnostic; the servers bind it two ways
//! (both std-only — the build is fully offline, no external crates):
//!
//! * **Blocking thread-per-connection** — [`serve_framed`] drives the
//!   parser straight off a socket `BufReader`.  Simple, and the fallback
//!   everywhere epoll is unavailable.
//! * **Readiness event loop** (`crate::net`) — nonblocking sockets on
//!   raw epoll.  A per-connection state machine buffers exactly one
//!   frame's bytes (the header line plus the payload extent
//!   [`frame_payload_extent`] computes from it) and then runs the *same*
//!   [`read_request_ref`] over the in-memory slice, so a command split
//!   across arbitrary read boundaries resumes mid-frame with byte-for-
//!   byte identical behavior to the blocking path.  See `crate::net` for
//!   the state-machine diagram, interest transitions and backpressure
//!   rule.

use std::io::{BufRead, BufReader, Read, Write};
use std::mem::MaybeUninit;

use anyhow::{anyhow, bail, Result};

use crate::sync::Arc;

/// A value payload: refcounted shared bytes.  GET answers clone the `Arc`
/// (refcount bump), never the bytes; PUT moves the parsed buffer into the
/// shard map without a re-copy.
pub type Value = Arc<[u8]>;

/// Hard cap on a single value payload (framing guard).
pub const MAX_VALUE_LEN: usize = 64 << 20;

/// Hard cap on the number of keys one batch frame may carry.  Doubles as
/// the pre-allocation bound for client-supplied counts (`MULTI`, `KEYS`):
/// a hostile count fails at the truncated stream, never by reserving
/// memory up front.
pub const MAX_BATCH: usize = 4096;

/// The operation a batch applies to every key it carries.  `Get`, `Put`
/// and `Del` are client-facing (`MGET`/`MPUT`/`MDEL`); `PutNx` and
/// `DelTomb` are the shard-internal migration pair (`MPUTNX`/`MDELTOMB`),
/// with exactly the singleton ops' semantics per key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchOp {
    /// Batched `GET`.
    Get,
    /// Batched `PUT`.
    Put,
    /// Batched `PUTNX` (shard-internal; the rebalancer's copy step).
    PutNx,
    /// Batched `DEL`.
    Del,
    /// Batched `DELTOMB` (shard-internal; mid-migration deletes).
    DelTomb,
}

impl BatchOp {
    /// `true` for the put-type ops, whose frames carry a payload per key.
    pub fn has_values(self) -> bool {
        matches!(self, BatchOp::Put | BatchOp::PutNx)
    }

    /// The wire command this op frames as.
    pub fn wire_name(self) -> &'static str {
        match self {
            BatchOp::Get => "MGET",
            BatchOp::Put => "MPUT",
            BatchOp::PutNx => "MPUTNX",
            BatchOp::Del => "MDEL",
            BatchOp::DelTomb => "MDELTOMB",
        }
    }
}

/// A batch of keys (plus, for put-type ops, parallel values) addressed by
/// dense index — the shard fan-out's view of wherever the batch came
/// from: a parsed wire frame ([`BatchRef`]), an owned request, or the
/// rebalancer's move list.  Implementations must answer `key`/`value` for
/// every `i < len()` in O(1) without allocating (`value` is a refcount
/// bump of a shared buffer, never a byte copy).
pub trait BatchSource {
    /// Number of keys in the batch.
    fn len(&self) -> usize;
    /// `true` when the batch carries no keys.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Key `i`.
    fn key(&self, i: usize) -> &str;
    /// Value for key `i` (put-type batches only).
    ///
    /// # Panics
    /// May panic for get/del-type batches, which carry no values.
    fn value(&self, i: usize) -> Value;
}

/// A parsed batch borrowing its keys from a connection's [`RecvBuf`] (or
/// from an owned [`Request`]'s vectors via [`Request::as_view`]) — the
/// allocation-free view batch requests parse into.  Keys are resolved by
/// index against a reused span table; values (put-type batches) are the
/// `Arc` buffers the parser read, shared out by refcount bump.
#[derive(Debug, Clone)]
pub struct BatchRef<'a> {
    repr: BatchRepr<'a>,
}

#[derive(Debug, Clone)]
enum BatchRepr<'a> {
    /// Keys are byte spans into the connection's reused line buffer.
    Wire { line: &'a str, spans: &'a [(u32, u32)], values: &'a [Value] },
    /// Keys and values borrowed from an owned [`Request`]'s vectors.
    Owned { keys: &'a [String], values: &'a [Value] },
}

impl<'a> BatchRef<'a> {
    /// View over parallel owned vectors (`values` empty for get/del-type
    /// batches) — the bridge from owned requests and tests into the
    /// batch path.
    pub fn from_owned(keys: &'a [String], values: &'a [Value]) -> Self {
        debug_assert!(values.is_empty() || values.len() == keys.len());
        Self { repr: BatchRepr::Owned { keys, values } }
    }

    /// The parallel value slice (empty for get/del-type batches).
    pub fn values(&self) -> &'a [Value] {
        match self.repr {
            BatchRepr::Wire { values, .. } | BatchRepr::Owned { values, .. } => values,
        }
    }

    /// Key `i` with the view's full lifetime (the trait method narrows to
    /// the borrow of `self`).
    pub fn key_at(&self, i: usize) -> &'a str {
        match self.repr {
            BatchRepr::Wire { line, spans, .. } => {
                let (s, e) = spans[i];
                &line[s as usize..e as usize]
            }
            BatchRepr::Owned { keys, .. } => &keys[i],
        }
    }

    fn keys_owned(&self) -> Vec<String> {
        (0..self.len()).map(|i| self.key_at(i).to_string()).collect()
    }
}

impl BatchSource for BatchRef<'_> {
    fn len(&self) -> usize {
        match self.repr {
            BatchRepr::Wire { spans, .. } => spans.len(),
            BatchRepr::Owned { keys, .. } => keys.len(),
        }
    }

    fn key(&self, i: usize) -> &str {
        self.key_at(i)
    }

    fn value(&self, i: usize) -> Value {
        self.values()[i].clone()
    }
}

// Wire- and owned-backed views of the same keys/values are equal: tests
// and `into_owned` roundtrips compare across representations.
impl PartialEq for BatchRef<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len()
            && (0..self.len()).all(|i| self.key_at(i) == other.key_at(i))
            && self.values() == other.values()
    }
}

impl Eq for BatchRef<'_> {}

/// A parsed request (owned form — admin paths, tests, client helpers).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Fetch a value.
    Get { key: String },
    /// Store a value.
    Put { key: String, value: Value },
    /// Store a value only if the key is absent (shard-internal; the
    /// rebalancer's copy step, so a migration never overwrites a newer
    /// client write that already reached the destination shard).
    PutNx { key: String, value: Value },
    /// Delete a key.
    Del { key: String },
    /// Delete a key and leave a tombstone barring a later `PUTNX` from
    /// resurrecting it (shard-internal; the router's mid-migration
    /// delete, so a DEL racing the migration copy of the same key cannot
    /// bring it back).
    DelTomb { key: String },
    /// List all keys (shard-internal; used by the rebalancer).
    Scan,
    /// List the keys of one lock stripe (shard-internal; the incremental
    /// rebalancer streams stripes instead of materializing a full scan).
    ScanStripe {
        /// Stripe index in `[0, shard::STRIPES)`.
        stripe: u32,
    },
    /// Clear all migration tombstones (shard-internal; issued by the
    /// router once a migration settles).
    PurgeTombs,
    /// Number of keys stored.
    Count,
    /// One-line stats.
    Stats,
    /// Add a shard (router admin).
    ScaleUp,
    /// Remove the last shard (router admin).
    ScaleDown,
    /// Fail a shard over: publish a degraded epoch that routes around it
    /// (router admin).
    Fail {
        /// Bucket id of the failed shard.
        shard: u32,
    },
    /// Restore a failed shard: wipe it, rejoin it, migrate its keyspace
    /// back (router admin).
    Restore {
        /// Bucket id of the shard to restore.
        shard: u32,
    },
    /// Drop every stored key and tombstone (shard-internal; issued by the
    /// router before a failed shard rejoins, because the shard missed
    /// every write and delete while it was down).
    Wipe,
    /// Per-stripe content digests (shard-internal; drives the restore
    /// anti-entropy sweep, which skips stripes whose digests already
    /// match between source and destination).
    Digest,
    /// Fetch many values in one round-trip (`MGET`).
    MGet {
        /// Object keys, answered positionally.
        keys: Vec<String>,
    },
    /// Store many values in one round-trip (`MPUT`).
    MPut {
        /// Object keys.
        keys: Vec<String>,
        /// Parallel payloads (`values.len() == keys.len()`).
        values: Vec<Value>,
    },
    /// Batched `PUTNX` (shard-internal; the rebalancer's copy step).
    MPutNx {
        /// Object keys.
        keys: Vec<String>,
        /// Parallel payloads.
        values: Vec<Value>,
    },
    /// Delete many keys in one round-trip (`MDEL`).
    MDel {
        /// Object keys, answered positionally.
        keys: Vec<String>,
    },
    /// Batched `DELTOMB` (shard-internal; mid-migration deletes).
    MDelTomb {
        /// Object keys, answered positionally.
        keys: Vec<String>,
    },
}

/// A parsed request borrowing its key from a connection's [`RecvBuf`] —
/// the server data path's allocation-free view.  Value payloads are
/// carried as [`Value`] (the one buffer the parser allocated) so they can
/// be moved straight into storage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestRef<'a> {
    /// Fetch a value.
    Get {
        /// Object key.
        key: &'a str,
    },
    /// Store a value.
    Put {
        /// Object key.
        key: &'a str,
        /// Parsed payload, moved into the shard map without a re-copy.
        value: Value,
    },
    /// Store only if absent (shard-internal; migration copy step).
    PutNx {
        /// Object key.
        key: &'a str,
        /// Parsed payload.
        value: Value,
    },
    /// Delete a key.
    Del {
        /// Object key.
        key: &'a str,
    },
    /// Delete and tombstone (shard-internal; mid-migration delete).
    DelTomb {
        /// Object key.
        key: &'a str,
    },
    /// List all keys (shard-internal).
    Scan,
    /// List one lock stripe's keys (shard-internal).
    ScanStripe {
        /// Stripe index in `[0, shard::STRIPES)`.
        stripe: u32,
    },
    /// Clear migration tombstones (shard-internal).
    PurgeTombs,
    /// Number of keys stored.
    Count,
    /// One-line stats.
    Stats,
    /// Add a shard (router admin).
    ScaleUp,
    /// Remove the last shard (router admin).
    ScaleDown,
    /// Fail a shard over (router admin).
    Fail {
        /// Bucket id of the failed shard.
        shard: u32,
    },
    /// Restore a failed shard (router admin).
    Restore {
        /// Bucket id of the shard to restore.
        shard: u32,
    },
    /// Drop every stored key and tombstone (shard-internal).
    Wipe,
    /// Per-stripe content digests (shard-internal).
    Digest,
    /// Fetch many values in one round-trip (`MGET`).
    MGet {
        /// The keybatch, answered positionally.
        batch: BatchRef<'a>,
    },
    /// Store many values in one round-trip (`MPUT`).
    MPut {
        /// The keybatch with parallel payloads.
        batch: BatchRef<'a>,
    },
    /// Batched `PUTNX` (shard-internal; migration copy step).
    MPutNx {
        /// The keybatch with parallel payloads.
        batch: BatchRef<'a>,
    },
    /// Delete many keys in one round-trip (`MDEL`).
    MDel {
        /// The keybatch, answered positionally.
        batch: BatchRef<'a>,
    },
    /// Batched `DELTOMB` (shard-internal; mid-migration deletes).
    MDelTomb {
        /// The keybatch, answered positionally.
        batch: BatchRef<'a>,
    },
}

impl Request {
    /// Borrowed view of this request (key borrowed, value refcount-bumped)
    /// — the bridge from the owned API into the allocation-free path.
    pub fn as_view(&self) -> RequestRef<'_> {
        match self {
            Request::Get { key } => RequestRef::Get { key },
            Request::Put { key, value } => RequestRef::Put { key, value: value.clone() },
            Request::PutNx { key, value } => RequestRef::PutNx { key, value: value.clone() },
            Request::Del { key } => RequestRef::Del { key },
            Request::DelTomb { key } => RequestRef::DelTomb { key },
            Request::Scan => RequestRef::Scan,
            Request::ScanStripe { stripe } => RequestRef::ScanStripe { stripe: *stripe },
            Request::PurgeTombs => RequestRef::PurgeTombs,
            Request::Count => RequestRef::Count,
            Request::Stats => RequestRef::Stats,
            Request::ScaleUp => RequestRef::ScaleUp,
            Request::ScaleDown => RequestRef::ScaleDown,
            Request::Fail { shard } => RequestRef::Fail { shard: *shard },
            Request::Restore { shard } => RequestRef::Restore { shard: *shard },
            Request::Wipe => RequestRef::Wipe,
            Request::Digest => RequestRef::Digest,
            Request::MGet { keys } => {
                RequestRef::MGet { batch: BatchRef::from_owned(keys, &[]) }
            }
            Request::MPut { keys, values } => {
                RequestRef::MPut { batch: BatchRef::from_owned(keys, values) }
            }
            Request::MPutNx { keys, values } => {
                RequestRef::MPutNx { batch: BatchRef::from_owned(keys, values) }
            }
            Request::MDel { keys } => {
                RequestRef::MDel { batch: BatchRef::from_owned(keys, &[]) }
            }
            Request::MDelTomb { keys } => {
                RequestRef::MDelTomb { batch: BatchRef::from_owned(keys, &[]) }
            }
        }
    }
}

impl RequestRef<'_> {
    /// Convert to the owned form (allocates the key — admin/test paths).
    pub fn into_owned(self) -> Request {
        match self {
            RequestRef::Get { key } => Request::Get { key: key.to_string() },
            RequestRef::Put { key, value } => Request::Put { key: key.to_string(), value },
            RequestRef::PutNx { key, value } => {
                Request::PutNx { key: key.to_string(), value }
            }
            RequestRef::Del { key } => Request::Del { key: key.to_string() },
            RequestRef::DelTomb { key } => Request::DelTomb { key: key.to_string() },
            RequestRef::Scan => Request::Scan,
            RequestRef::ScanStripe { stripe } => Request::ScanStripe { stripe },
            RequestRef::PurgeTombs => Request::PurgeTombs,
            RequestRef::Count => Request::Count,
            RequestRef::Stats => Request::Stats,
            RequestRef::ScaleUp => Request::ScaleUp,
            RequestRef::ScaleDown => Request::ScaleDown,
            RequestRef::Fail { shard } => Request::Fail { shard },
            RequestRef::Restore { shard } => Request::Restore { shard },
            RequestRef::Wipe => Request::Wipe,
            RequestRef::Digest => Request::Digest,
            RequestRef::MGet { batch } => Request::MGet { keys: batch.keys_owned() },
            RequestRef::MPut { batch } => {
                Request::MPut { keys: batch.keys_owned(), values: batch.values().to_vec() }
            }
            RequestRef::MPutNx { batch } => {
                Request::MPutNx { keys: batch.keys_owned(), values: batch.values().to_vec() }
            }
            RequestRef::MDel { batch } => Request::MDel { keys: batch.keys_owned() },
            RequestRef::MDelTomb { batch } => Request::MDelTomb { keys: batch.keys_owned() },
        }
    }
}

impl<'a> RequestRef<'a> {
    /// Split a batch request into its `(op, keybatch)` pair; non-batch
    /// requests come back unchanged in `Err` — the servers' dispatch
    /// point between the batch and singleton paths.
    pub fn into_batch(self) -> Result<(BatchOp, BatchRef<'a>), Self> {
        match self {
            RequestRef::MGet { batch } => Ok((BatchOp::Get, batch)),
            RequestRef::MPut { batch } => Ok((BatchOp::Put, batch)),
            RequestRef::MPutNx { batch } => Ok((BatchOp::PutNx, batch)),
            RequestRef::MDel { batch } => Ok((BatchOp::Del, batch)),
            RequestRef::MDelTomb { batch } => Ok((BatchOp::DelTomb, batch)),
            other => Err(other),
        }
    }
}

/// A response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Success without payload.
    Ok,
    /// A value payload (shared buffer — cloning a `Response::Val` bumps a
    /// refcount, it never copies the bytes).
    Val(Value),
    /// Key absent.
    Nil,
    /// Key listing.
    Keys(Vec<String>),
    /// Numeric result.
    Num(u64),
    /// Fixed-size numeric vector (one line; answers `DIGEST` with the
    /// per-stripe content digests).
    Nums(Vec<u64>),
    /// Informational line.
    Info(String),
    /// Error with message.
    Err(String),
    /// Positional sub-responses answering a batch request: the i-th entry
    /// answers the i-th key of the `MGET`/`MPUT`/`MDEL` frame.  Never
    /// nests.
    Multi(Vec<Response>),
}

/// Per-connection reusable parse scratch: the command line, the batch
/// span table and the batch value list all live here and [`RequestRef`] /
/// [`BatchRef`] borrow from them, so a connection allocates its buffers
/// once, not once per request (and not once per batched key).
#[derive(Debug, Default)]
pub struct RecvBuf {
    line: String,
    /// Byte spans of a batch frame's keys within `line`.
    spans: Vec<(u32, u32)>,
    /// Announced payload lengths of an `MPUT`/`MPUTNX` header, parsed
    /// before any payload byte is read.
    lens: Vec<u32>,
    /// Parsed payloads of the current batch (each a freshly allocated
    /// `Arc` that flows to storage without a re-copy; the vector itself
    /// is reused).
    values: Vec<Value>,
}

/// Steady-state capacity caps for a connection's reusable buffers
/// ([`RecvBuf::recycle`] and the servers' in/out buffers shrink back to
/// these).  One oversized batch may grow a buffer to the 64 MiB framing
/// budget; *keeping* it grown costs that much per connection forever —
/// fatal at 10k+ connections — so every server trims after each request.
pub const RECV_LINE_CAP: usize = 16 << 10;
/// Cap on the batch span/length tables kept across requests (entries).
pub const RECV_SPAN_CAP: usize = 1024;
/// Cap on the batch value `Arc` table kept across requests (entries).
pub const RECV_VALUE_CAP: usize = 64;

impl RecvBuf {
    /// New empty scratch buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Release the previous request's payload refs and shrink any buffer
    /// an oversized batch grew beyond its steady-state cap.  Servers call
    /// this once per handled request: per-connection memory is then
    /// bounded by the caps, not by the largest batch the connection ever
    /// saw.  No-op (four capacity compares) in steady state.
    pub fn recycle(&mut self) {
        // Dropping the Arcs promptly also releases the payload bytes of
        // the last batch (the stored copies live on in the shard map).
        self.values.clear();
        if self.line.capacity() > RECV_LINE_CAP {
            self.line.clear();
            self.line.shrink_to(RECV_LINE_CAP);
        }
        if self.spans.capacity() > RECV_SPAN_CAP {
            self.spans.clear();
            self.spans.shrink_to(RECV_SPAN_CAP);
        }
        if self.lens.capacity() > RECV_SPAN_CAP {
            self.lens.clear();
            self.lens.shrink_to(RECV_SPAN_CAP);
        }
        if self.values.capacity() > RECV_VALUE_CAP {
            self.values.shrink_to(RECV_VALUE_CAP);
        }
    }

    /// Current buffer capacities `(line, spans, lens, values)` — lets
    /// tests pin the [`recycle`](Self::recycle) bound without exposing
    /// the fields.
    pub fn capacities(&self) -> (usize, usize, usize, usize) {
        (self.line.capacity(), self.spans.capacity(), self.lens.capacity(), self.values.capacity())
    }
}

/// Outcome of parsing one request line.
#[derive(Debug)]
pub enum Wire<'a> {
    /// A well-formed request.
    Req(RequestRef<'a>),
    /// A recoverable protocol error: the stream is still framed — answer
    /// `ERR <msg>` and keep the connection.
    Bad(String),
}

/// `true` when `key` is a legal wire token.
pub fn valid_key(key: &str) -> bool {
    !key.is_empty() && key.len() <= 512 && key.bytes().all(|b| b.is_ascii_graphic())
}

fn key_tok(tok: Option<&str>) -> Result<&str, String> {
    match tok {
        None => Err("missing key".to_string()),
        Some(key) if !valid_key(key) => Err(format!("invalid key {key:?}")),
        Some(key) => Ok(key),
    }
}

/// Parse and bound a batch count token.  Everything that can go wrong
/// here is recoverable: the whole frame (for get/del-type batches) or at
/// least the header line (put-type) was consumed with the line.
fn batch_count(cmd: &str, tok: Option<&str>) -> Result<usize, String> {
    let n: usize = tok
        .ok_or_else(|| format!("{cmd} missing count"))?
        .parse()
        .map_err(|e| format!("bad {cmd} count: {e}"))?;
    if n > MAX_BATCH {
        return Err(format!("{cmd} count {n} exceeds the batch cap {MAX_BATCH}"));
    }
    Ok(n)
}

/// Byte span of `tok` within `line`.  `tok` must be a subslice of `line`
/// (it comes from `line.split(' ')`), so the pointer difference is its
/// offset — plain integer arithmetic on addresses, no unsafe.
fn span_of(line: &str, tok: &str) -> (u32, u32) {
    let off = tok.as_ptr() as usize - line.as_ptr() as usize;
    debug_assert!(off + tok.len() <= line.len(), "token not borrowed from line");
    (off as u32, (off + tok.len()) as u32)
}

/// Read a value payload into a freshly allocated [`Value`] — the single
/// buffer that then travels to the shard map without being copied again.
///
/// Cost note: the buffer is zero-initialized (one memset pass the old
/// `vec![0; len]` got lazily from calloc) before `read_exact` fills it —
/// the price of building the `Arc` in place on stable Rust.  What it
/// buys: no second allocation and no `Vec`→`Arc` byte copy when the
/// value is stored, shared, or migrated.
fn read_value<R: Read>(r: &mut R, len: usize) -> Result<Value> {
    let mut uninit: Arc<[MaybeUninit<u8>]> = Arc::new_uninit_slice(len);
    let slice = Arc::get_mut(&mut uninit).expect("freshly allocated Arc is unique");
    for b in slice.iter_mut() {
        b.write(0);
    }
    // SAFETY: every byte was just initialized above.
    let mut value: Arc<[u8]> = unsafe { uninit.assume_init() };
    let slice = Arc::get_mut(&mut value).expect("still unique");
    r.read_exact(slice)?;
    Ok(value)
}

/// Read one request into `buf`, borrowing the key from it.  Returns
/// `Ok(None)` on clean EOF, [`Wire::Bad`] for recoverable parse failures
/// (answer `ERR`, keep the connection), and `Err` only for framing/IO
/// errors (drop the connection).
///
/// Generic over [`BufRead`] so the blocking servers pass their socket
/// `BufReader` and the event loop passes `&mut &[u8]` over an in-memory
/// frame it has already buffered to completion (see
/// [`frame_payload_extent`] for how it knows the frame is complete) —
/// both run the exact same parse.
pub fn read_request_ref<'a, R: BufRead>(
    r: &mut R,
    buf: &'a mut RecvBuf,
) -> Result<Option<Wire<'a>>> {
    // Split the scratch into disjoint field borrows: the returned view
    // borrows `line`/`spans`/`values` simultaneously.
    let RecvBuf { line, spans, lens, values } = buf;
    line.clear();
    spans.clear();
    lens.clear();
    values.clear();
    if r.read_line(line)? == 0 {
        return Ok(None);
    }
    let line: &'a str = line;
    let line = line.trim_end();
    let mut parts = line.split(' ');
    let cmd = parts.next().unwrap_or("");
    macro_rules! try_bad {
        ($e:expr) => {
            match $e {
                Ok(x) => x,
                Err(m) => return Ok(Some(Wire::Bad(m))),
            }
        };
    }
    let req = match cmd {
        "GET" => RequestRef::Get { key: try_bad!(key_tok(parts.next())) },
        "DEL" => RequestRef::Del { key: try_bad!(key_tok(parts.next())) },
        "DELTOMB" => RequestRef::DelTomb { key: try_bad!(key_tok(parts.next())) },
        "PURGETOMBS" => RequestRef::PurgeTombs,
        "PUT" | "PUTNX" => {
            let key = try_bad!(key_tok(parts.next()));
            let len: usize = try_bad!(parts
                .next()
                .ok_or_else(|| format!("{cmd} missing length"))
                .and_then(|t| t
                    .parse()
                    .map_err(|e| format!("bad {cmd} length {t:?}: {e}"))));
            if len > MAX_VALUE_LEN {
                // The payload follows on the wire; there is no way to stay
                // framed without buffering it — drop the connection.
                bail!("value too large: {len}");
            }
            let value = read_value(r, len)?;
            if cmd == "PUT" {
                RequestRef::Put { key, value }
            } else {
                RequestRef::PutNx { key, value }
            }
        }
        "SCAN" => RequestRef::Scan,
        "SCANSTRIPE" => {
            let stripe: u32 = try_bad!(parts
                .next()
                .ok_or_else(|| "SCANSTRIPE missing index".to_string())
                .and_then(|t| t
                    .parse()
                    .map_err(|e| format!("bad SCANSTRIPE index {t:?}: {e}"))));
            RequestRef::ScanStripe { stripe }
        }
        "COUNT" => RequestRef::Count,
        "STATS" => RequestRef::Stats,
        "SCALEUP" => RequestRef::ScaleUp,
        "SCALEDOWN" => RequestRef::ScaleDown,
        "FAIL" | "RESTORE" => {
            let shard: u32 = try_bad!(parts
                .next()
                .ok_or_else(|| format!("{cmd} missing shard id"))
                .and_then(|t| t
                    .parse()
                    .map_err(|e| format!("bad {cmd} shard id {t:?}: {e}"))));
            if cmd == "FAIL" {
                RequestRef::Fail { shard }
            } else {
                RequestRef::Restore { shard }
            }
        }
        "WIPE" => RequestRef::Wipe,
        "DIGEST" => RequestRef::Digest,
        "MGET" | "MDEL" | "MDELTOMB" => {
            // Key-list batch: `<CMD> <n> <k1> ... <kn>`.  Everything that
            // can go wrong is recoverable — the whole frame is this line.
            let n = try_bad!(batch_count(cmd, parts.next()));
            for _ in 0..n {
                let key = try_bad!(key_tok(parts.next()));
                spans.push(span_of(line, key));
            }
            if parts.next().is_some() {
                return Ok(Some(Wire::Bad(format!(
                    "{cmd} count {n} shorter than its key list"
                ))));
            }
            let batch = BatchRef { repr: BatchRepr::Wire { line, spans, values } };
            match cmd {
                "MGET" => RequestRef::MGet { batch },
                "MDEL" => RequestRef::MDel { batch },
                _ => RequestRef::MDelTomb { batch },
            }
        }
        "MPUT" | "MPUTNX" => {
            // Put batch: `<CMD> <n> <k1> <l1> ... <kn> <ln>` then the `n`
            // payloads back to back.  Header mistakes are recoverable
            // (nothing past the line was consumed; a client that already
            // streamed payloads has desynced itself, as with a singleton
            // PUT whose length token was bad); payload truncation and
            // oversize are framing errors.
            let n = try_bad!(batch_count(cmd, parts.next()));
            let mut total = 0usize;
            for _ in 0..n {
                let key = try_bad!(key_tok(parts.next()));
                let len: usize = try_bad!(parts
                    .next()
                    .ok_or_else(|| format!("{cmd} missing a length"))
                    .and_then(|t| t
                        .parse()
                        .map_err(|e| format!("bad {cmd} length {t:?}: {e}"))));
                if len > MAX_VALUE_LEN {
                    bail!("value too large: {len}");
                }
                total += len;
                if total > MAX_VALUE_LEN {
                    bail!("batch payload too large: > {MAX_VALUE_LEN}");
                }
                spans.push(span_of(line, key));
                lens.push(len as u32);
            }
            if parts.next().is_some() {
                return Ok(Some(Wire::Bad(format!(
                    "{cmd} count {n} shorter than its key list"
                ))));
            }
            for &len in lens.iter() {
                values.push(read_value(r, len as usize)?);
            }
            let batch = BatchRef { repr: BatchRepr::Wire { line, spans, values } };
            if cmd == "MPUT" {
                RequestRef::MPut { batch }
            } else {
                RequestRef::MPutNx { batch }
            }
        }
        other => return Ok(Some(Wire::Bad(format!("unknown command {other:?}")))),
    };
    Ok(Some(Wire::Req(req)))
}

/// How far past its header line a frame extends on the wire — computed
/// from the header alone, *before* the payload arrives.  This is the
/// event loop's frame detector: a readiness server must know how many
/// bytes make the frame complete so it can buffer exactly that much and
/// then hand [`read_request_ref`] an in-memory slice, resuming cleanly
/// when a read ends mid-command.
///
/// The contract (differentially tested against the parser in
/// `frame_extent_agrees_with_parser`): for any header line,
///
/// * [`FrameExtent::Payload`]`(p)` — the parser, given the line plus
///   exactly `p` payload bytes, consumes all of them and yields a
///   request;
/// * [`FrameExtent::LineOnly`] — the parser consumes the line and *no*
///   payload bytes (either the command carries none, or the header is
///   recoverably bad and the parser answers [`Wire::Bad`] before its
///   payload-read phase — mirroring the blocking path, where a client
///   that streamed payloads after a bad header has desynced itself);
/// * [`FrameExtent::Oversized`] — the header announces a payload beyond
///   the [`MAX_VALUE_LEN`] budget; the parser would `bail!` and the
///   connection must drop without buffering the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameExtent {
    /// The frame is the header line alone.
    LineOnly,
    /// The frame is the header line plus this many payload bytes.
    Payload(usize),
    /// The announced payload exceeds the framing budget — drop the
    /// connection (never buffer toward an oversized frame).
    Oversized,
}

/// Compute a frame's [`FrameExtent`] from its header line (trailing
/// newline optional).  Mirrors [`read_request_ref`]'s token walk
/// *exactly* — same token order, same first-failure-wins decisions — so
/// the event loop's framing and the parser's consumption can never
/// disagree (see `FrameExtent`'s contract and its differential test).
pub fn frame_payload_extent(line: &str) -> FrameExtent {
    let line = line.trim_end();
    let mut parts = line.split(' ');
    let cmd = parts.next().unwrap_or("");
    match cmd {
        "PUT" | "PUTNX" => {
            // Parser order: key token first (bad key => Bad, no payload
            // read), then the length token (unparseable => Bad, no
            // payload; oversized => bail).
            if key_tok(parts.next()).is_err() {
                return FrameExtent::LineOnly;
            }
            match parts.next().and_then(|t| t.parse::<usize>().ok()) {
                Some(len) if len > MAX_VALUE_LEN => FrameExtent::Oversized,
                Some(len) => FrameExtent::Payload(len),
                None => FrameExtent::LineOnly,
            }
        }
        "MPUT" | "MPUTNX" => {
            // Parser order: count, then (key, len) pairs left to right
            // (each failure decided at its pair), then the trailing-token
            // check — only after all of that does it read payloads.
            let n = match batch_count(cmd, parts.next()) {
                Ok(n) => n,
                Err(_) => return FrameExtent::LineOnly,
            };
            let mut total = 0usize;
            for _ in 0..n {
                if key_tok(parts.next()).is_err() {
                    return FrameExtent::LineOnly;
                }
                let len = match parts.next().and_then(|t| t.parse::<usize>().ok()) {
                    Some(len) => len,
                    None => return FrameExtent::LineOnly,
                };
                if len > MAX_VALUE_LEN {
                    return FrameExtent::Oversized;
                }
                total += len;
                if total > MAX_VALUE_LEN {
                    return FrameExtent::Oversized;
                }
            }
            if parts.next().is_some() {
                return FrameExtent::LineOnly;
            }
            FrameExtent::Payload(total)
        }
        _ => FrameExtent::LineOnly,
    }
}

/// Read one request in owned form. Returns `None` on clean EOF and `Err`
/// on *any* parse failure (legacy strict behavior — clients and tests;
/// servers use [`read_request_ref`] and stay alive on recoverable ones).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    let mut buf = RecvBuf::new();
    match read_request_ref(r, &mut buf)? {
        None => Ok(None),
        Some(Wire::Req(req)) => Ok(Some(req.into_owned())),
        Some(Wire::Bad(msg)) => Err(anyhow!(msg)),
    }
}

/// Write one request (borrowed form — the servers' forwarding path).
pub fn write_request_ref<W: Write>(w: &mut W, req: &RequestRef<'_>) -> Result<()> {
    match req {
        RequestRef::Get { key } => writeln!(w, "GET {key}")?,
        RequestRef::Del { key } => writeln!(w, "DEL {key}")?,
        RequestRef::DelTomb { key } => writeln!(w, "DELTOMB {key}")?,
        RequestRef::PurgeTombs => w.write_all(b"PURGETOMBS\n")?,
        RequestRef::Put { key, value } => {
            writeln!(w, "PUT {key} {}", value.len())?;
            w.write_all(value)?;
        }
        RequestRef::PutNx { key, value } => {
            writeln!(w, "PUTNX {key} {}", value.len())?;
            w.write_all(value)?;
        }
        RequestRef::Scan => w.write_all(b"SCAN\n")?,
        RequestRef::ScanStripe { stripe } => writeln!(w, "SCANSTRIPE {stripe}")?,
        RequestRef::Count => w.write_all(b"COUNT\n")?,
        RequestRef::Stats => w.write_all(b"STATS\n")?,
        RequestRef::ScaleUp => w.write_all(b"SCALEUP\n")?,
        RequestRef::ScaleDown => w.write_all(b"SCALEDOWN\n")?,
        RequestRef::Fail { shard } => writeln!(w, "FAIL {shard}")?,
        RequestRef::Restore { shard } => writeln!(w, "RESTORE {shard}")?,
        RequestRef::Wipe => w.write_all(b"WIPE\n")?,
        RequestRef::Digest => w.write_all(b"DIGEST\n")?,
        RequestRef::MGet { batch } => write_batch_frame(w, BatchOp::Get, 0..batch.len(), batch)?,
        RequestRef::MPut { batch } => write_batch_frame(w, BatchOp::Put, 0..batch.len(), batch)?,
        RequestRef::MPutNx { batch } => {
            write_batch_frame(w, BatchOp::PutNx, 0..batch.len(), batch)?
        }
        RequestRef::MDel { batch } => write_batch_frame(w, BatchOp::Del, 0..batch.len(), batch)?,
        RequestRef::MDelTomb { batch } => {
            write_batch_frame(w, BatchOp::DelTomb, 0..batch.len(), batch)?
        }
    }
    w.flush()?;
    Ok(())
}

/// Serialize one batch frame for the keys selected by `indices` (dense
/// indices into `src`), without flushing.  The put-type frames take two
/// passes over the selection (header line, then payloads), hence `Clone`.
fn write_batch_frame<W: Write, S: BatchSource + ?Sized>(
    w: &mut W,
    op: BatchOp,
    indices: impl Iterator<Item = usize> + Clone,
    src: &S,
) -> Result<()> {
    write!(w, "{} {}", op.wire_name(), indices.clone().count())?;
    if op.has_values() {
        for i in indices.clone() {
            write!(w, " {} {}", src.key(i), src.value(i).len())?;
        }
        w.write_all(b"\n")?;
        for i in indices {
            w.write_all(&src.value(i))?;
        }
    } else {
        for i in indices {
            write!(w, " {}", src.key(i))?;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Write one batch request for the subset of `src` selected by `sel` and
/// flush — the remote shard fan-out's serializer (one round-trip carries
/// one shard's share of the batch).
pub fn write_batch_request<W: Write, S: BatchSource + ?Sized>(
    w: &mut W,
    op: BatchOp,
    sel: &[u32],
    src: &S,
) -> Result<()> {
    write_batch_frame(w, op, sel.iter().map(|&i| i as usize), src)?;
    w.flush()?;
    Ok(())
}

/// Write one request (owned form).
pub fn write_request<W: Write>(w: &mut W, req: &Request) -> Result<()> {
    write_request_ref(w, &req.as_view())
}

/// Read one response.
pub fn read_response<R: Read>(r: &mut BufReader<R>) -> Result<Response> {
    read_response_at(r, 0)
}

/// `depth` guards against a hostile server nesting `MULTI` inside
/// `MULTI` (the protocol never does) to recurse the client off its
/// stack.
fn read_response_at<R: Read>(r: &mut BufReader<R>, depth: u32) -> Result<Response> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("connection closed mid-response");
    }
    let line_t = line.trim_end();
    let (tag, rest) = line_t.split_once(' ').unwrap_or((line_t, ""));
    Ok(match tag {
        "OK" => Response::Ok,
        "NIL" => Response::Nil,
        "VAL" => {
            let len: usize = rest.parse()?;
            if len > MAX_VALUE_LEN {
                bail!("value too large: {len}");
            }
            Response::Val(read_value(r, len)?)
        }
        "KEYS" => {
            let count: usize = rest.parse()?;
            // Cap the pre-allocation: a hostile/oversized count must fail
            // at the truncated stream, not by reserving memory up front.
            let mut keys = Vec::with_capacity(count.min(MAX_BATCH));
            for _ in 0..count {
                let mut k = String::new();
                if r.read_line(&mut k)? == 0 {
                    bail!("truncated key list");
                }
                keys.push(k.trim_end().to_string());
            }
            Response::Keys(keys)
        }
        "MULTI" => {
            if depth > 0 {
                bail!("nested MULTI response");
            }
            let count: usize = rest.parse()?;
            // Same pre-allocation cap as KEYS: a hostile count fails at
            // the truncated stream, not by reserving memory.
            let mut subs = Vec::with_capacity(count.min(MAX_BATCH));
            for _ in 0..count {
                subs.push(read_response_at(r, depth + 1)?);
            }
            Response::Multi(subs)
        }
        "NUM" => Response::Num(rest.parse()?),
        "NUMS" => {
            let mut toks = rest.split_ascii_whitespace();
            let count: usize = match toks.next() {
                Some(t) => t.parse()?,
                None => bail!("NUMS missing count"),
            };
            // Same pre-allocation cap as KEYS: a hostile count fails at
            // the truncated line, not by reserving memory.
            let mut nums = Vec::with_capacity(count.min(MAX_BATCH));
            for _ in 0..count {
                match toks.next() {
                    Some(t) => nums.push(t.parse::<u64>()?),
                    None => bail!("NUMS truncated: expected {count} values"),
                }
            }
            if toks.next().is_some() {
                bail!("NUMS frame has trailing tokens");
            }
            Response::Nums(nums)
        }
        "INFO" => Response::Info(rest.to_string()),
        "ERR" => Response::Err(rest.to_string()),
        other => bail!("bad response tag {other:?}"),
    })
}

/// Serialize one response into an output buffer *without* flushing — the
/// servers coalesce a pipelined burst's responses and flush once.
pub fn encode_response(out: &mut Vec<u8>, resp: &Response) -> Result<()> {
    match resp {
        Response::Ok => out.extend_from_slice(b"OK\n"),
        Response::Nil => out.extend_from_slice(b"NIL\n"),
        Response::Val(value) => {
            writeln!(out, "VAL {}", value.len())?;
            out.extend_from_slice(value);
        }
        Response::Keys(keys) => {
            writeln!(out, "KEYS {}", keys.len())?;
            for k in keys {
                out.extend_from_slice(k.as_bytes());
                out.push(b'\n');
            }
        }
        Response::Num(x) => writeln!(out, "NUM {x}")?,
        Response::Nums(xs) => {
            write!(out, "NUMS {}", xs.len())?;
            for x in xs {
                write!(out, " {x}")?;
            }
            out.push(b'\n');
        }
        Response::Info(s) => writeln!(out, "INFO {s}")?,
        Response::Err(m) => writeln!(out, "ERR {m}")?,
        Response::Multi(subs) => {
            writeln!(out, "MULTI {}", subs.len())?;
            for s in subs {
                encode_response(out, s)?;
            }
        }
    }
    Ok(())
}

/// Encode a batch's positional sub-responses (`MULTI <n>` + each
/// sub-response) straight from a caller-reused buffer — the server path's
/// alternative to materializing a [`Response::Multi`] vector per batch.
pub fn encode_multi_response(out: &mut Vec<u8>, subs: &[Response]) -> Result<()> {
    writeln!(out, "MULTI {}", subs.len())?;
    for s in subs {
        encode_response(out, s)?;
    }
    Ok(())
}

/// Write one response and flush (single-response convenience path).
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    let mut buf = Vec::with_capacity(64);
    encode_response(&mut buf, resp)?;
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Flush the coalesced response buffer once it reaches this size even if
/// the read burst hasn't drained, bounding per-connection memory.
const FLUSH_HIGH_WATER: usize = 32 << 10;

/// Serve one framed connection until EOF: the shared read→handle→encode
/// loop of the router and shard servers (`handle` is the only
/// difference).  Parses borrowed requests from a reusable [`RecvBuf`],
/// answers `ERR` (and keeps the connection) on recoverable parse
/// failures, returns `Err` on framing/IO errors, and coalesces pipelined
/// responses — a flush is deferred only while the read buffer provably
/// holds another complete command line (a partial line means the next
/// `read_line` hits the socket; never withhold a response across a read
/// that could block).  A `PUT` whose header arrived but whose announced
/// payload stalls can still block post-flush — framing obliges the
/// client to send the payload without waiting on earlier responses.
///
/// The handler *encodes* its response into the connection's output
/// buffer ([`encode_response`] / [`encode_multi_response`]) instead of
/// returning a `Response` — that is what lets a server answer a batch
/// from per-connection scratch without materializing a
/// [`Response::Multi`] vector per frame.
pub fn serve_framed<R: Read, W: Write>(
    rd: &mut BufReader<R>,
    wr: &mut W,
    mut handle: impl FnMut(RequestRef<'_>, &mut Vec<u8>) -> Result<()>,
) -> Result<()> {
    let mut scratch = RecvBuf::new();
    let mut out = Vec::with_capacity(4 << 10);
    loop {
        match read_request_ref(rd, &mut scratch)? {
            None => break,
            Some(Wire::Req(req)) => handle(req, &mut out)?,
            Some(Wire::Bad(msg)) => encode_response(&mut out, &Response::Err(msg))?,
        }
        // Bound per-connection memory: drop the request's payload refs
        // and shrink scratch an oversized batch grew (no-op otherwise).
        scratch.recycle();
        let next_is_buffered = rd.buffer().contains(&b'\n');
        if !next_is_buffered || out.len() >= FLUSH_HIGH_WATER {
            wr.write_all(&out)?;
            wr.flush()?;
            out.clear();
            // Same bound for the response side: a single huge VAL may
            // blow past the high-water mark; don't keep that capacity.
            if out.capacity() > 2 * FLUSH_HIGH_WATER {
                out.shrink_to(FLUSH_HIGH_WATER);
            }
        }
    }
    if !out.is_empty() {
        wr.write_all(&out)?;
        wr.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) -> Request {
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let mut r = BufReader::new(&buf[..]);
        read_request(&mut r).unwrap().unwrap()
    }

    fn roundtrip_resp(resp: Response) -> Response {
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        let mut r = BufReader::new(&buf[..]);
        read_response(&mut r).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Get { key: "k1".into() },
            Request::Put { key: "k2".into(), value: b"hello\nworld\x00\xff".to_vec().into() },
            Request::PutNx { key: "k4".into(), value: b"\x01\x02".to_vec().into() },
            Request::Del { key: "k3".into() },
            Request::DelTomb { key: "k5".into() },
            Request::Scan,
            Request::ScanStripe { stripe: 7 },
            Request::PurgeTombs,
            Request::Count,
            Request::Stats,
            Request::ScaleUp,
            Request::ScaleDown,
            Request::Fail { shard: 3 },
            Request::Restore { shard: 3 },
            Request::Wipe,
            Request::Digest,
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn zero_length_values_roundtrip() {
        // The empty-payload edge: `PUT k 0` builds an empty `Arc<[u8]>`
        // through `new_uninit_slice(0)` + `read_exact(&mut [])`; it must
        // survive request and response framing bit-exactly.
        let empty: Value = Vec::new().into();
        for req in [
            Request::Put { key: "e".into(), value: empty.clone() },
            Request::PutNx { key: "e".into(), value: empty.clone() },
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
        assert_eq!(roundtrip_resp(Response::Val(empty.clone())), Response::Val(empty));
    }

    #[test]
    fn bad_failover_arguments_are_recoverable() {
        // Missing / non-numeric / overflowing shard ids must answer ERR
        // and keep the stream framed, like every other recoverable typo.
        let input = b"FAIL\nRESTORE notanumber\nFAIL 99999999999999999999\nFAIL 2\n";
        let mut r = BufReader::new(&input[..]);
        let mut buf = RecvBuf::new();
        for _ in 0..3 {
            match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
                Wire::Bad(msg) => assert!(!msg.is_empty()),
                Wire::Req(req) => panic!("expected Bad, got {req:?}"),
            }
        }
        match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::Fail { shard }) => assert_eq!(shard, 2),
            other => panic!("expected FAIL 2, got {other:?}"),
        }
    }

    #[test]
    fn owned_and_borrowed_views_roundtrip() {
        let req = Request::Put { key: "k".into(), value: b"v".to_vec().into() };
        assert_eq!(req.as_view().into_owned(), req);
        let req = Request::ScanStripe { stripe: 3 };
        assert_eq!(req.as_view().into_owned(), req);
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Ok,
            Response::Nil,
            Response::Val(vec![0u8, 1, 2, 255, b'\n'].into()),
            Response::Keys(vec!["a".into(), "b/c".into()]),
            Response::Keys(Vec::new()),
            Response::Num(42),
            Response::Nums(vec![0, 1, u64::MAX, 0x517]),
            Response::Nums(Vec::new()),
            Response::Info("epoch=3 n=8".into()),
            Response::Err("nope".into()),
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn eof_returns_none() {
        let mut r = BufReader::new(&b""[..]);
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn bad_command_errors() {
        let mut r = BufReader::new(&b"BOGUS x\n"[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn oversized_put_rejected() {
        let mut r = BufReader::new(&b"PUT k 999999999999\n"[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn recoverable_failures_keep_the_stream_framed() {
        // Four recoverable mistakes, then a healthy request: the borrowed
        // parser must report each as Wire::Bad and stay in sync.
        let input = b"BOGUS x\nGET\nSCANSTRIPE nope\nPUT k notanint\nGET ok\n";
        let mut r = BufReader::new(&input[..]);
        let mut buf = RecvBuf::new();
        for _ in 0..4 {
            match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
                Wire::Bad(msg) => assert!(!msg.is_empty()),
                Wire::Req(req) => panic!("expected Bad, got {req:?}"),
            }
        }
        match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::Get { key }) => assert_eq!(key, "ok"),
            other => panic!("expected GET ok, got {other:?}"),
        }
        assert!(read_request_ref(&mut r, &mut buf).unwrap().is_none());
    }

    #[test]
    fn invalid_key_is_recoverable() {
        let long = format!("DEL {}\nCOUNT\n", "x".repeat(600));
        let mut r = BufReader::new(long.as_bytes());
        let mut buf = RecvBuf::new();
        assert!(matches!(
            read_request_ref(&mut r, &mut buf).unwrap().unwrap(),
            Wire::Bad(_)
        ));
        assert!(matches!(
            read_request_ref(&mut r, &mut buf).unwrap().unwrap(),
            Wire::Req(RequestRef::Count)
        ));
    }

    #[test]
    fn truncated_put_payload_is_a_framing_error() {
        // Header promises 10 bytes, stream ends after 3: the connection
        // cannot be trusted any further.
        let mut r = BufReader::new(&b"PUT k 10\nabc"[..]);
        let mut buf = RecvBuf::new();
        assert!(read_request_ref(&mut r, &mut buf).is_err());
    }

    #[test]
    fn empty_put_payload_parses() {
        let mut r = BufReader::new(&b"PUT k 0\n"[..]);
        let mut buf = RecvBuf::new();
        match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::Put { key, value }) => {
                assert_eq!(key, "k");
                assert!(value.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn oversized_keys_count_errors_without_huge_alloc() {
        // A hostile KEYS count must fail at the truncated stream, not by
        // pre-allocating count * sizeof(String).
        let mut r = BufReader::new(&b"KEYS 18446744073709551615\n"[..]);
        assert!(read_response(&mut r).is_err());
    }

    #[test]
    fn truncated_val_response_errors() {
        let mut r = BufReader::new(&b"VAL 10\nabc"[..]);
        assert!(read_response(&mut r).is_err());
    }

    #[test]
    fn pipelined_requests() {
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::Get { key: "a".into() }).unwrap();
        write_request(&mut buf, &Request::Count).unwrap();
        let mut r = BufReader::new(&buf[..]);
        assert_eq!(read_request(&mut r).unwrap().unwrap(), Request::Get { key: "a".into() });
        assert_eq!(read_request(&mut r).unwrap().unwrap(), Request::Count);
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn encode_response_coalesces_without_flush() {
        let mut out = Vec::new();
        encode_response(&mut out, &Response::Ok).unwrap();
        encode_response(&mut out, &Response::Val(b"xy".to_vec().into())).unwrap();
        encode_response(&mut out, &Response::Nil).unwrap();
        assert_eq!(&out[..], b"OK\nVAL 2\nxyNIL\n");
        let mut r = BufReader::new(&out[..]);
        assert_eq!(read_response(&mut r).unwrap(), Response::Ok);
        assert_eq!(read_response(&mut r).unwrap(), Response::Val(b"xy".to_vec().into()));
        assert_eq!(read_response(&mut r).unwrap(), Response::Nil);
    }

    #[test]
    fn key_validation() {
        assert!(valid_key("tenant-1/bucket-2/obj"));
        assert!(!valid_key(""));
        assert!(!valid_key("has space"));
        assert!(!valid_key("has\nnewline"));
        assert!(!valid_key(&"x".repeat(600)));
    }

    #[test]
    fn batch_requests_roundtrip() {
        let values: Vec<Value> =
            vec![b"v0".to_vec().into(), Vec::new().into(), b"\x00\xff\n".to_vec().into()];
        let keys: Vec<String> = vec!["a".into(), "b/c".into(), "d-3".into()];
        for req in [
            Request::MGet { keys: keys.clone() },
            Request::MDel { keys: keys.clone() },
            Request::MDelTomb { keys: keys.clone() },
            Request::MPut { keys: keys.clone(), values: values.clone() },
            Request::MPutNx { keys, values },
            Request::MGet { keys: Vec::new() },
            Request::MPut { keys: Vec::new(), values: Vec::new() },
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn batch_views_agree_across_representations() {
        let req = Request::MPut {
            keys: vec!["k1".into(), "k2".into()],
            values: vec![b"x".to_vec().into(), b"yz".to_vec().into()],
        };
        // Owned -> wire -> borrowed-wire view must equal the owned view.
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let mut r = BufReader::new(&buf[..]);
        let mut scratch = RecvBuf::new();
        match read_request_ref(&mut r, &mut scratch).unwrap().unwrap() {
            Wire::Req(RequestRef::MPut { batch }) => {
                assert_eq!(RequestRef::MPut { batch }, req.as_view());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_parse_is_allocation_light_and_borrowed() {
        // Keys of a parsed MGET borrow from the connection scratch.
        let mut r = BufReader::new(&b"MGET 3 k1 k22 k333\n"[..]);
        let mut buf = RecvBuf::new();
        match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::MGet { batch }) => {
                assert_eq!(batch.len(), 3);
                assert_eq!(batch.key_at(0), "k1");
                assert_eq!(batch.key_at(1), "k22");
                assert_eq!(batch.key_at(2), "k333");
                assert!(batch.values().is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hostile_batch_counts_are_recoverable() {
        // Oversized, non-numeric, mismatched and trailing-token counts
        // all answer ERR and keep the stream framed; no pre-allocation is
        // sized from the hostile count.
        let input = format!(
            "MGET 18446744073709551615 k\nMGET {} k\nMGET nope k\nMGET 3 k1 k2\n\
             MGET 1 k1 k2\nMPUT 2 k1 1\nMDEL 1\nMGET 2 k1 k2\n",
            MAX_BATCH + 1
        );
        let mut r = BufReader::new(input.as_bytes());
        let mut buf = RecvBuf::new();
        for _ in 0..7 {
            match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
                Wire::Bad(msg) => assert!(!msg.is_empty()),
                Wire::Req(req) => panic!("expected Bad, got {req:?}"),
            }
        }
        match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::MGet { batch }) => assert_eq!(batch.len(), 2),
            other => panic!("expected MGET, got {other:?}"),
        }
    }

    #[test]
    fn truncated_mput_payload_is_a_framing_error() {
        // Header promises 4 + 6 bytes, stream ends early: drop the
        // connection (as for a truncated singleton PUT).
        let mut r = BufReader::new(&b"MPUT 2 k1 4 k2 6\nabcdde"[..]);
        let mut buf = RecvBuf::new();
        assert!(read_request_ref(&mut r, &mut buf).is_err());
    }

    #[test]
    fn oversized_mput_lengths_are_framing_errors() {
        // A single oversized length and an over-budget total both drop
        // the connection before any payload allocation.
        let mut r = BufReader::new(&b"MPUT 1 k 999999999999\n"[..]);
        let mut buf = RecvBuf::new();
        assert!(read_request_ref(&mut r, &mut buf).is_err());
        let line = format!("MPUT 2 k1 {} k2 {}\n", MAX_VALUE_LEN, MAX_VALUE_LEN);
        let mut r = BufReader::new(line.as_bytes());
        assert!(read_request_ref(&mut r, &mut buf).is_err());
    }

    #[test]
    fn bad_mput_length_token_is_recoverable() {
        let mut r = BufReader::new(&b"MPUT 1 k notanint\nCOUNT\n"[..]);
        let mut buf = RecvBuf::new();
        assert!(matches!(
            read_request_ref(&mut r, &mut buf).unwrap().unwrap(),
            Wire::Bad(_)
        ));
        assert!(matches!(
            read_request_ref(&mut r, &mut buf).unwrap().unwrap(),
            Wire::Req(RequestRef::Count)
        ));
    }

    #[test]
    fn multi_responses_roundtrip() {
        for resp in [
            Response::Multi(vec![
                Response::Val(b"a".to_vec().into()),
                Response::Nil,
                Response::Ok,
                Response::Err("UNAVAILABLE: marooned".into()),
            ]),
            Response::Multi(Vec::new()),
            Response::Multi(vec![Response::Val(Vec::new().into())]),
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn hostile_multi_count_errors_without_huge_alloc() {
        let mut r = BufReader::new(&b"MULTI 18446744073709551615\nOK\n"[..]);
        assert!(read_response(&mut r).is_err());
    }

    #[test]
    fn nested_multi_is_rejected() {
        // The protocol never nests MULTI; a server that does is hostile
        // (unbounded recursion) and the client must drop it.
        let mut r = BufReader::new(&b"MULTI 1\nMULTI 1\nOK\n"[..]);
        assert!(read_response(&mut r).is_err());
    }

    #[test]
    fn encode_multi_matches_response_multi() {
        let subs = vec![Response::Ok, Response::Nil, Response::Val(b"q".to_vec().into())];
        let mut a = Vec::new();
        encode_multi_response(&mut a, &subs).unwrap();
        let mut b = Vec::new();
        encode_response(&mut b, &Response::Multi(subs)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn frame_extent_known_cases() {
        use FrameExtent::*;
        for (line, want) in [
            ("GET k\n", LineOnly),
            ("COUNT\n", LineOnly),
            ("BOGUS x y\n", LineOnly),
            ("PUT k 5\n", Payload(5)),
            ("PUTNX k 0\n", Payload(0)),
            ("PUT k notanint\n", LineOnly),
            ("PUT\n", LineOnly),
            ("PUT k 999999999999\n", Oversized),
            ("MGET 2 k1 k2\n", LineOnly),
            ("MPUT 0\n", Payload(0)),
            ("MPUT 2 k1 3 k2 4\n", Payload(7)),
            ("MPUT 2 k1 3 k2\n", LineOnly),
            ("MPUT 2 k1 3 k2 4 extra\n", LineOnly),
            ("MPUT nope k 3\n", LineOnly),
            ("MPUT 1 k 999999999999\n", Oversized),
            ("MPUT 2 k1 50000000 k2 50000000\n", Oversized),
        ] {
            assert_eq!(frame_payload_extent(line), want, "line {line:?}");
        }
        // Exactly at the budget is still a legal (if huge) frame.
        let line = format!("PUT k {MAX_VALUE_LEN}\n");
        assert_eq!(frame_payload_extent(&line), Payload(MAX_VALUE_LEN));
    }

    /// The [`FrameExtent`] contract, checked differentially: for every
    /// corpus line (valid frames plus single-byte mutations), the parser
    /// given `line + extent` payload bytes + `COUNT\n` must consume
    /// exactly the frame — the follow-up parse must see COUNT.
    #[test]
    fn frame_extent_agrees_with_parser() {
        let mut corpus: Vec<Vec<u8>> = [
            "GET k\n",
            "PUT k 5\n",
            "PUT k notanint\n",
            "PUT toolong 99999999999999999999\n",
            "MGET 2 k1 k2\n",
            "MPUT 2 k1 3 k2 4\n",
            "MPUT 2 k1 3 k2 4 extra\n",
            "MPUT 1 k 12\n",
            "MDEL 1 k\n",
            "COUNT\n",
        ]
        .iter()
        .map(|s| s.as_bytes().to_vec())
        .collect();
        // Single-byte mutations of every corpus line (keeping the
        // terminator) — bad keys, bad counts, bad lengths, bad commands.
        let mut rng = crate::hashing::SplitMix64Rng::new(0xF7A3E);
        let seeds = corpus.clone();
        for line in &seeds {
            for pos in 0..line.len().saturating_sub(1) {
                let mut m = line.clone();
                m[pos] = match rng.next_u64() % 4 {
                    0 => b' ',
                    1 => b'0',
                    2 => b'?',
                    _ => (rng.next_u64() % 26) as u8 + b'a',
                };
                corpus.push(m);
            }
        }
        for line_bytes in &corpus {
            let line = std::str::from_utf8(line_bytes).expect("corpus is ASCII");
            let extent = frame_payload_extent(line);
            let payload = match extent {
                FrameExtent::Payload(p) if p <= 1 << 20 => p,
                FrameExtent::Payload(_) => continue, // don't materialize huge frames
                FrameExtent::LineOnly => 0,
                FrameExtent::Oversized => {
                    // The parser must refuse the frame outright.
                    let mut stream = line_bytes.clone();
                    stream.extend_from_slice(b"COUNT\n");
                    let mut r = BufReader::new(&stream[..]);
                    let mut buf = RecvBuf::new();
                    assert!(
                        read_request_ref(&mut r, &mut buf).is_err(),
                        "line {line:?}: extent says Oversized but the parser accepted it"
                    );
                    continue;
                }
            };
            let mut stream = line_bytes.clone();
            stream.extend(std::iter::repeat(0xAB).take(payload));
            stream.extend_from_slice(b"COUNT\n");
            let mut r = BufReader::new(&stream[..]);
            let mut buf = RecvBuf::new();
            match read_request_ref(&mut r, &mut buf) {
                Ok(Some(_)) => {}
                other => panic!("line {line:?}: first parse failed: {other:?}"),
            }
            match read_request_ref(&mut r, &mut buf) {
                Ok(Some(Wire::Req(RequestRef::Count))) => {}
                other => panic!(
                    "line {line:?} (extent {extent:?}): parser consumption disagrees \
                     with the extent — next parse saw {other:?} instead of COUNT"
                ),
            }
        }
    }

    #[test]
    fn recycle_bounds_scratch_and_releases_payload_refs() {
        // A big batch grows every scratch field past its cap...
        let keys: Vec<String> = (0..2000).map(|i| format!("key-{i:04}")).collect();
        let values: Vec<Value> = (0..2000).map(|_| vec![7u8; 64].into()).collect();
        let mut frame = Vec::new();
        write_request(&mut frame, &Request::MPut { keys, values }).unwrap();
        let mut r = BufReader::new(&frame[..]);
        let mut buf = RecvBuf::new();
        let weak = match read_request_ref(&mut r, &mut buf).unwrap().unwrap() {
            Wire::Req(RequestRef::MPut { batch }) => {
                assert_eq!(batch.len(), 2000);
                Arc::downgrade(&batch.values()[0])
            }
            other => panic!("{other:?}"),
        };
        let (l, s, le, v) = buf.capacities();
        assert!(l > RECV_LINE_CAP && s > RECV_SPAN_CAP && le > RECV_SPAN_CAP);
        assert!(v > RECV_VALUE_CAP);
        // ...and recycle trims it all back and drops the payload Arcs.
        buf.recycle();
        assert!(weak.upgrade().is_none(), "recycle must release payload refs");
        let (l, s, le, v) = buf.capacities();
        assert!(l <= 2 * RECV_LINE_CAP, "line capacity {l} not trimmed");
        assert!(s <= 2 * RECV_SPAN_CAP, "span capacity {s} not trimmed");
        assert!(le <= 2 * RECV_SPAN_CAP, "lens capacity {le} not trimmed");
        assert!(v <= 2 * RECV_VALUE_CAP, "value capacity {v} not trimmed");
        // A recycled buffer still parses.
        let mut r = BufReader::new(&b"GET ok\n"[..]);
        assert!(matches!(
            read_request_ref(&mut r, &mut buf).unwrap().unwrap(),
            Wire::Req(RequestRef::Get { key: "ok" })
        ));
    }

    #[test]
    fn fuzzed_batch_frames_never_panic_or_desync() {
        // Seeded mutation fuzz: corrupt one byte of a valid batch frame
        // at every position, append a healthy COUNT, and drain the
        // stream.  Every read must land in one of the three legal
        // outcomes — a request, a recoverable Bad (stream stays framed
        // and keeps draining), or a framing error (connection would
        // drop) — and never panic, hang, or over-allocate.
        let mut frames: Vec<Vec<u8>> = Vec::new();
        let mut f = Vec::new();
        write_request(&mut f, &Request::MGet { keys: vec!["ka".into(), "kb".into()] })
            .unwrap();
        frames.push(f);
        let mut f = Vec::new();
        write_request(
            &mut f,
            &Request::MPut {
                keys: vec!["ka".into(), "kb".into()],
                values: vec![b"1234".to_vec().into(), b"56".to_vec().into()],
            },
        )
        .unwrap();
        frames.push(f);
        let mut f = Vec::new();
        write_request(&mut f, &Request::MDelTomb { keys: vec!["ka".into()] }).unwrap();
        frames.push(f);

        let mut rng = crate::hashing::SplitMix64Rng::new(0xBA7C);
        for frame in &frames {
            for pos in 0..frame.len() {
                let mut mutated = frame.clone();
                // Random byte, plus the interesting edges.
                let b = match rng.next_u64() % 4 {
                    0 => b' ',
                    1 => b'\n',
                    2 => 0xFF,
                    _ => (rng.next_u64() & 0x7F) as u8,
                };
                mutated[pos] = b;
                mutated.extend_from_slice(b"COUNT\n");
                let mut r = BufReader::new(&mutated[..]);
                let mut buf = RecvBuf::new();
                // Drain until EOF or framing error; no panic allowed.
                loop {
                    match read_request_ref(&mut r, &mut buf) {
                        Ok(None) => break,
                        Ok(Some(_)) => continue,
                        Err(_) => break, // framing: connection would drop
                    }
                }
            }
        }
    }
}
