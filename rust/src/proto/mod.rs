//! Wire protocol shared by the router front-end and the shard servers.
//!
//! Text-framed commands with binary value payloads (memcached-style):
//!
//! ```text
//! GET <key>\n                 -> VAL <len>\n<bytes>  |  NIL\n
//! PUT <key> <len>\n<bytes>    -> OK\n
//! PUTNX <key> <len>\n<bytes>  -> OK\n | NIL\n        (shard only)
//! DEL <key>\n                 -> OK\n | NIL\n
//! DELTOMB <key>\n             -> OK\n | NIL\n        (shard only)
//! SCAN\n                      -> KEYS <count>\n(<key>\n)*
//! SCANSTRIPE <i>\n            -> KEYS <count>\n(<key>\n)*  (shard only)
//! PURGETOMBS\n                -> NUM <count>\n       (shard only)
//! COUNT\n                     -> NUM <count>\n
//! STATS\n                     -> INFO <line>\n
//! SCALEUP\n                   -> NUM <new-n>\n        (router only)
//! SCALEDOWN\n                 -> NUM <new-n>\n        (router only)
//! ```
//!
//! Keys are ASCII tokens without whitespace (the router rejects others);
//! values are arbitrary bytes.  Errors: `ERR <msg>\n`.
//!
//! `PUTNX` stores only if the key is absent (`NIL` = already present) and
//! `SCANSTRIPE` lists one lock stripe; both exist for the incremental
//! rebalancer, which streams stripes and copies without clobbering newer
//! client writes.  `DELTOMB` is the router's mid-migration delete: it
//! removes the key *and* leaves a tombstone that bars a later `PUTNX`
//! (the migration copy) from resurrecting it; `PURGETOMBS` clears the
//! tombstones once the migration settles.  The router's `STATS` line
//! reports the placement epoch and a `state=migrating|steady` field;
//! `SCALEUP`/`SCALEDOWN` issued while a migration is already in flight
//! answer `ERR MIGRATING: <detail>`.
//!
//! Blocking I/O over `std::io` — the servers are thread-per-connection
//! (see DESIGN.md: the build is fully offline, so the stack is std-only).

use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{anyhow, bail, Result};

/// A parsed request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Fetch a value.
    Get { key: String },
    /// Store a value.
    Put { key: String, value: Vec<u8> },
    /// Store a value only if the key is absent (shard-internal; the
    /// rebalancer's copy step, so a migration never overwrites a newer
    /// client write that already reached the destination shard).
    PutNx { key: String, value: Vec<u8> },
    /// Delete a key.
    Del { key: String },
    /// Delete a key and leave a tombstone barring a later `PUTNX` from
    /// resurrecting it (shard-internal; the router's mid-migration
    /// delete, so a DEL racing the migration copy of the same key cannot
    /// bring it back).
    DelTomb { key: String },
    /// List all keys (shard-internal; used by the rebalancer).
    Scan,
    /// List the keys of one lock stripe (shard-internal; the incremental
    /// rebalancer streams stripes instead of materializing a full scan).
    ScanStripe {
        /// Stripe index in `[0, shard::STRIPES)`.
        stripe: u32,
    },
    /// Clear all migration tombstones (shard-internal; issued by the
    /// router once a migration settles).
    PurgeTombs,
    /// Number of keys stored.
    Count,
    /// One-line stats.
    Stats,
    /// Add a shard (router admin).
    ScaleUp,
    /// Remove the last shard (router admin).
    ScaleDown,
}

/// A response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Success without payload.
    Ok,
    /// A value payload.
    Val(Vec<u8>),
    /// Key absent.
    Nil,
    /// Key listing.
    Keys(Vec<String>),
    /// Numeric result.
    Num(u64),
    /// Informational line.
    Info(String),
    /// Error with message.
    Err(String),
}

/// `true` when `key` is a legal wire token.
pub fn valid_key(key: &str) -> bool {
    !key.is_empty() && key.len() <= 512 && key.bytes().all(|b| b.is_ascii_graphic())
}

/// Read one request from a buffered stream. Returns `None` on clean EOF.
pub fn read_request<R: Read>(r: &mut BufReader<R>) -> Result<Option<Request>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let line = line.trim_end();
    let mut parts = line.split(' ');
    let cmd = parts.next().unwrap_or("");
    let req = match cmd {
        "GET" => Request::Get { key: expect_key(parts.next())? },
        "DEL" => Request::Del { key: expect_key(parts.next())? },
        "DELTOMB" => Request::DelTomb { key: expect_key(parts.next())? },
        "PURGETOMBS" => Request::PurgeTombs,
        "PUT" | "PUTNX" => {
            let key = expect_key(parts.next())?;
            let len: usize =
                parts.next().ok_or_else(|| anyhow!("{cmd} missing length"))?.parse()?;
            if len > 64 << 20 {
                bail!("value too large: {len}");
            }
            let mut value = vec![0u8; len];
            r.read_exact(&mut value)?;
            if cmd == "PUT" {
                Request::Put { key, value }
            } else {
                Request::PutNx { key, value }
            }
        }
        "SCAN" => Request::Scan,
        "SCANSTRIPE" => {
            let stripe: u32 =
                parts.next().ok_or_else(|| anyhow!("SCANSTRIPE missing index"))?.parse()?;
            Request::ScanStripe { stripe }
        }
        "COUNT" => Request::Count,
        "STATS" => Request::Stats,
        "SCALEUP" => Request::ScaleUp,
        "SCALEDOWN" => Request::ScaleDown,
        other => bail!("unknown command {other:?}"),
    };
    Ok(Some(req))
}

fn expect_key(tok: Option<&str>) -> Result<String> {
    let key = tok.ok_or_else(|| anyhow!("missing key"))?;
    if !valid_key(key) {
        bail!("invalid key {key:?}");
    }
    Ok(key.to_string())
}

/// Write one request.
pub fn write_request<W: Write>(w: &mut W, req: &Request) -> Result<()> {
    match req {
        Request::Get { key } => writeln!(w, "GET {key}")?,
        Request::Del { key } => writeln!(w, "DEL {key}")?,
        Request::DelTomb { key } => writeln!(w, "DELTOMB {key}")?,
        Request::PurgeTombs => w.write_all(b"PURGETOMBS\n")?,
        Request::Put { key, value } => {
            writeln!(w, "PUT {key} {}", value.len())?;
            w.write_all(value)?;
        }
        Request::PutNx { key, value } => {
            writeln!(w, "PUTNX {key} {}", value.len())?;
            w.write_all(value)?;
        }
        Request::Scan => w.write_all(b"SCAN\n")?,
        Request::ScanStripe { stripe } => writeln!(w, "SCANSTRIPE {stripe}")?,
        Request::Count => w.write_all(b"COUNT\n")?,
        Request::Stats => w.write_all(b"STATS\n")?,
        Request::ScaleUp => w.write_all(b"SCALEUP\n")?,
        Request::ScaleDown => w.write_all(b"SCALEDOWN\n")?,
    }
    w.flush()?;
    Ok(())
}

/// Read one response.
pub fn read_response<R: Read>(r: &mut BufReader<R>) -> Result<Response> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("connection closed mid-response");
    }
    let line_t = line.trim_end();
    let (tag, rest) = line_t.split_once(' ').unwrap_or((line_t, ""));
    Ok(match tag {
        "OK" => Response::Ok,
        "NIL" => Response::Nil,
        "VAL" => {
            let len: usize = rest.parse()?;
            let mut value = vec![0u8; len];
            r.read_exact(&mut value)?;
            Response::Val(value)
        }
        "KEYS" => {
            let count: usize = rest.parse()?;
            let mut keys = Vec::with_capacity(count.min(1 << 20));
            for _ in 0..count {
                let mut k = String::new();
                if r.read_line(&mut k)? == 0 {
                    bail!("truncated key list");
                }
                keys.push(k.trim_end().to_string());
            }
            Response::Keys(keys)
        }
        "NUM" => Response::Num(rest.parse()?),
        "INFO" => Response::Info(rest.to_string()),
        "ERR" => Response::Err(rest.to_string()),
        other => bail!("bad response tag {other:?}"),
    })
}

/// Write one response.
pub fn write_response<W: Write>(w: &mut W, resp: &Response) -> Result<()> {
    match resp {
        Response::Ok => w.write_all(b"OK\n")?,
        Response::Nil => w.write_all(b"NIL\n")?,
        Response::Val(value) => {
            writeln!(w, "VAL {}", value.len())?;
            w.write_all(value)?;
        }
        Response::Keys(keys) => {
            writeln!(w, "KEYS {}", keys.len())?;
            for k in keys {
                w.write_all(k.as_bytes())?;
                w.write_all(b"\n")?;
            }
        }
        Response::Num(x) => writeln!(w, "NUM {x}")?,
        Response::Info(s) => writeln!(w, "INFO {s}")?,
        Response::Err(m) => writeln!(w, "ERR {m}")?,
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) -> Request {
        let mut buf = Vec::new();
        write_request(&mut buf, &req).unwrap();
        let mut r = BufReader::new(&buf[..]);
        read_request(&mut r).unwrap().unwrap()
    }

    fn roundtrip_resp(resp: Response) -> Response {
        let mut buf = Vec::new();
        write_response(&mut buf, &resp).unwrap();
        let mut r = BufReader::new(&buf[..]);
        read_response(&mut r).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Get { key: "k1".into() },
            Request::Put { key: "k2".into(), value: b"hello\nworld\x00\xff".to_vec() },
            Request::PutNx { key: "k4".into(), value: b"\x01\x02".to_vec() },
            Request::Del { key: "k3".into() },
            Request::DelTomb { key: "k5".into() },
            Request::Scan,
            Request::ScanStripe { stripe: 7 },
            Request::PurgeTombs,
            Request::Count,
            Request::Stats,
            Request::ScaleUp,
            Request::ScaleDown,
        ] {
            assert_eq!(roundtrip_req(req.clone()), req);
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Ok,
            Response::Nil,
            Response::Val(vec![0u8, 1, 2, 255, b'\n']),
            Response::Keys(vec!["a".into(), "b/c".into()]),
            Response::Keys(Vec::new()),
            Response::Num(42),
            Response::Info("epoch=3 n=8".into()),
            Response::Err("nope".into()),
        ] {
            assert_eq!(roundtrip_resp(resp.clone()), resp);
        }
    }

    #[test]
    fn eof_returns_none() {
        let mut r = BufReader::new(&b""[..]);
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn bad_command_errors() {
        let mut r = BufReader::new(&b"BOGUS x\n"[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn oversized_put_rejected() {
        let mut r = BufReader::new(&b"PUT k 999999999999\n"[..]);
        assert!(read_request(&mut r).is_err());
    }

    #[test]
    fn pipelined_requests() {
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::Get { key: "a".into() }).unwrap();
        write_request(&mut buf, &Request::Count).unwrap();
        let mut r = BufReader::new(&buf[..]);
        assert_eq!(read_request(&mut r).unwrap().unwrap(), Request::Get { key: "a".into() });
        assert_eq!(read_request(&mut r).unwrap().unwrap(), Request::Count);
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn key_validation() {
        assert!(valid_key("tenant-1/bucket-2/obj"));
        assert!(!valid_key(""));
        assert!(!valid_key("has space"));
        assert!(!valid_key("has\nnewline"));
        assert!(!valid_key(&"x".repeat(600)));
    }
}
