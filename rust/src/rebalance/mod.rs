//! Rebalancer: computes and applies the minimal key-movement set for a
//! topology change, incrementally.
//!
//! Consistent hashing makes the plan *local*: under monotonicity only keys
//! whose new bucket is the joining one move (scale-up), and under minimal
//! disruption only keys on the leaving bucket move (scale-down).  The
//! planner still verifies this from first principles by computing old/new
//! placement for every key — that check is the bulk workload the
//! [`PlacementRuntime`] XLA artifacts accelerate, and it catches a
//! non-consistent engine (e.g. `maglev`) by reporting its excess moves.
//!
//! The production entry point is [`migrate_streaming`]: it walks every
//! source shard one lock stripe at a time (`Shard::scan_stripe`), plans
//! each bounded batch, and applies it immediately — peak memory is one
//! stripe of keys plus one batch of moves, never the full keyset, and the
//! data path keeps serving (dual-read) while batches land.  The copy step
//! is `PUTNX` so a migration batch can never clobber a newer value a
//! client already wrote to the destination shard, nor resurrect a key a
//! mid-migration `DELTOMB` tombstoned (see [`apply`]).
//!
//! ## Batched application: O(1) round-trips per batch, not ~3 per key
//!
//! [`apply`] drives the sweep over the batched wire ops: each planned
//! batch is grouped by `(source, destination)` pair and moved with **four
//! shard calls** — `MGET` the source copies, `MPUTNX` them onto the
//! destination, `MGET` the refused keys back from the destination (to
//! tell a raced client write from a tombstoned delete), and one `MDEL`
//! retiring the source copies — instead of the former
//! GET + PUTNX + DEL per key.  Against remote shards that cuts migration
//! round-trips by roughly the batch factor ([`MigrationStats::round_trips`]
//! counts them; `migration_round_trips_stay_batched` pins the bound);
//! locally each call runs under one stripe-lock acquisition per occupied
//! stripe.  Per-key semantics are unchanged — `MPUTNX`/`MDELTOMB` refuse
//! and tombstone exactly like their singleton forms.
//!
//! The tombstone/PUTNX no-resurrection contract and the migration purge
//! ordering are model-checked under adversarial interleavings in
//! `rust/tests/model.rs` (`--features model`); any synchronization this
//! module needs flows through [`crate::sync`], never raw `std::sync`.

use anyhow::{bail, Result};

use crate::algorithms::ConsistentHasher;
use crate::proto::{BatchOp, BatchSource, Response, Value};
use crate::runtime::PlacementRuntime;
use crate::shard::ShardClient;

/// One key relocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Move {
    /// Object key.
    pub key: String,
    /// The key's digest (`shard::key_digest`), carried from planning so
    /// `apply` threads it into local shard calls instead of re-hashing.
    pub digest: u64,
    /// Source bucket.
    pub from: u32,
    /// Destination bucket.
    pub to: u32,
    /// Copy without retiring the source: the source bucket remains a
    /// legitimate holder (it is in the key's replica set under the
    /// destination topology), so the move is a *replication* copy, not a
    /// relocation.  The planner emits `false`; the router's restore path
    /// flips it per key when `replication.factor` > 1.
    pub keep_source: bool,
}

/// A computed migration plan.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    /// Keys to relocate.
    pub moves: Vec<Move>,
    /// Keys examined.
    pub scanned: usize,
}

impl MigrationPlan {
    /// Fraction of scanned keys that move.
    pub fn moved_fraction(&self) -> f64 {
        if self.scanned == 0 {
            0.0
        } else {
            self.moves.len() as f64 / self.scanned as f64
        }
    }
}

/// How placement is recomputed during planning.
pub enum PlanPath<'a> {
    /// Pure-Rust loop over the two epochs' placement engines (the old
    /// engine is the router's fork of the pre-change snapshot, so this
    /// works for every engine — stateless or stateful).
    Engines {
        /// Engine of the epoch being migrated away from.
        old: &'a dyn ConsistentHasher,
        /// Engine of the epoch being migrated into.
        new: &'a dyn ConsistentHasher,
    },
    /// AOT XLA artifact (BinomialHash engine only): bulk old/new placement
    /// on the PJRT runtime.
    Xla {
        /// Compiled artifact runtime.
        runtime: &'a PlacementRuntime,
        /// Cluster size before the change.
        n_old: u32,
        /// Cluster size after the change.
        n_new: u32,
    },
}

/// Aggregate result of an incremental migration.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MigrationStats {
    /// Keys examined across all stripes.
    pub scanned: u64,
    /// Keys copied to a new owner (and removed from the old one).
    pub moved: u64,
    /// Bounded batches planned and applied.
    pub batches: u64,
    /// Shard calls issued by the sweep: one `SCANSTRIPE` per *scanned*
    /// stripe plus at most four batched calls (`MGET`/`MPUTNX`/
    /// refused-`MGET`/`MDEL`) per (batch, source→destination) pair, plus
    /// one `DIGEST` per shard consulted by an anti-entropy sweep — each
    /// is one wire round-trip against a remote shard, so this is the
    /// number the batch factor divides (the per-key sweep paid ~3 calls
    /// *per moved key*).
    pub round_trips: u64,
    /// `(source, stripe)` scans skipped by the anti-entropy digest
    /// comparison (source and destination already agree on the stripe's
    /// content — streaming it would move nothing).
    pub stripes_skipped: u64,
}

/// Incremental migration driver: stream the `sources` shards
/// stripe-by-stripe, plan each chunk of at most `batch_size` keys with
/// `plan_batch`, and apply it immediately.
///
/// `ae_dest` turns the sweep into **anti-entropy**: when the migration
/// converges on a single destination (a failed-shard restore), the
/// driver fetches that destination's per-stripe content digests once,
/// each source's digests once, and skips every `(source, stripe)` whose
/// digests already match — equal digests mean equal content (up to a
/// 64-bit collision), so streaming the stripe would move nothing.  The
/// skip rule is what turns RESTORE's full survivor re-stream into
/// round-trips proportional to the *divergent* stripes.
///
/// `shards` must cover the union of the old and new topologies (every
/// `Move::to` destination must be indexable); only the `sources` shards
/// are scanned — every *reachable* old shard on scale-up and on a
/// failed-shard restore, just the retiring shard on scale-down when the
/// engine guarantees minimal disruption (every shard otherwise).  The
/// list may have holes: a degraded topology's failed shards are excluded
/// by the router, because a dead shard can neither be scanned nor be a
/// legal destination.  Unlike the stop-the-world path this
/// never materializes the cluster's keyset — memory is bounded by the
/// largest stripe — and every batch is visible to concurrent readers the
/// moment it lands.
pub fn migrate_streaming(
    shards: &[ShardClient],
    sources: &[u32],
    ae_dest: Option<u32>,
    batch_size: usize,
    mut plan_batch: impl FnMut(&[(String, u64)]) -> Result<MigrationPlan>,
) -> Result<MigrationStats> {
    let batch_size = batch_size.max(1);
    let mut stats = MigrationStats::default();
    let dest_digests = match ae_dest {
        Some(d) => {
            let digests = shards[d as usize].stripe_digests()?;
            stats.round_trips += 1; // the destination DIGEST call
            Some(digests)
        }
        None => None,
    };
    for shard in sources.iter().map(|&b| &shards[b as usize]) {
        let src_digests = match &dest_digests {
            Some(_) => {
                let digests = shard.stripe_digests()?;
                stats.round_trips += 1; // one DIGEST call per source
                Some(digests)
            }
            None => None,
        };
        for stripe in 0..crate::shard::STRIPES as u32 {
            if let (Some(dst), Some(src)) = (&dest_digests, &src_digests) {
                if dst[stripe as usize] == src[stripe as usize] {
                    stats.stripes_skipped += 1;
                    continue;
                }
            }
            let digested: Vec<(String, u64)> = shard
                .scan_stripe(stripe)?
                .into_iter()
                .map(|key| {
                    let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
                    (key, digest)
                })
                .collect();
            stats.round_trips += 1; // the stripe scan
            for chunk in digested.chunks(batch_size) {
                let plan = plan_batch(chunk)?;
                stats.scanned += plan.scanned as u64;
                let (moved, rts) = apply(&plan, shards)?;
                stats.moved += moved;
                stats.round_trips += rts;
                stats.batches += 1;
            }
        }
    }
    Ok(stats)
}

/// Compute the migration plan for the scanned keys.
pub fn plan(keys: &[(String, u64)], path: PlanPath<'_>) -> Result<MigrationPlan> {
    let mut plan = MigrationPlan { moves: Vec::new(), scanned: keys.len() };
    match path {
        PlanPath::Engines { old, new } => {
            // One batched placement call per engine over the whole
            // scanned stripe chunk instead of two scalar lookups per
            // key — the migration sweep and the anti-entropy restore
            // both flow through here, so they ride the lane-parallel
            // kernel for free.
            let digests: Vec<u64> = keys.iter().map(|(_, d)| *d).collect();
            let mut from = vec![0u32; keys.len()];
            let mut to = vec![0u32; keys.len()];
            old.bucket_batch(&digests, &mut from);
            new.bucket_batch(&digests, &mut to);
            for (i, (key, digest)) in keys.iter().enumerate() {
                if from[i] != to[i] {
                    plan.moves.push(Move {
                        key: key.clone(),
                        digest: *digest,
                        from: from[i],
                        to: to[i],
                        keep_source: false,
                    });
                }
            }
        }
        PlanPath::Xla { runtime, n_old, n_new } => {
            let digests: Vec<u64> = keys.iter().map(|(_, d)| *d).collect();
            let outcome = runtime.migration_plan(&digests, n_old, n_new)?;
            for (i, (key, digest)) in keys.iter().enumerate() {
                if outcome.moved[i] != 0 {
                    plan.moves.push(Move {
                        key: key.clone(),
                        digest: *digest,
                        from: outcome.old[i],
                        to: outcome.new[i],
                        keep_source: false,
                    });
                }
            }
        }
    }
    Ok(plan)
}

/// A plan's moves viewed as a [`BatchSource`]: keys come from the move
/// list, values (for the `MPUTNX` step) from the parallel buffer the
/// `MGET` step filled.  Indices are *plan-wide*, so one response array
/// serves every group of the plan.
struct MoveBatch<'a> {
    moves: &'a [Move],
    values: &'a [Value],
}

impl BatchSource for MoveBatch<'_> {
    fn len(&self) -> usize {
        self.moves.len()
    }

    fn key(&self, i: usize) -> &str {
        &self.moves[i].key
    }

    fn value(&self, i: usize) -> Value {
        self.values[i].clone()
    }
}

/// Apply a plan with the batched wire ops: group the moves by
/// `(source, destination)` pair and, per group, `MGET` the source copies,
/// `MPUTNX` them onto the destination (a value a client already wrote to
/// the destination mid-migration is newer than the copy we hold and must
/// win), `MGET` the refused keys back from the destination, and retire
/// the source copies with one `MDEL` — at most four shard calls per
/// group instead of ~3 per key.  Values are `Arc<[u8]>`, so a
/// local-to-local move transfers a refcount, not bytes; only remote hops
/// serialize the payload.  Returns `(keys migrated, shard calls issued)`.
///
/// A refused copy has two causes, told apart by re-reading the
/// destination: a *live* value means a client write raced ahead (the
/// stale source copy is retired here), while *no* value means a
/// mid-migration DEL tombstoned the key between our read and the copy —
/// the source copy is left for that DEL's own source-side delete, so the
/// client's DEL observes the key it is deleting.
pub fn apply(plan: &MigrationPlan, shards: &[ShardClient]) -> Result<(u64, u64)> {
    if plan.moves.is_empty() {
        return Ok((0, 0));
    }
    let mut moved = 0u64;
    let mut round_trips = 0u64;
    // Group by (from, to).  In practice a streamed chunk comes from one
    // source shard and most topology changes have one destination, so
    // this is usually a single group.
    let mut order: Vec<u32> = (0..plan.moves.len() as u32).collect();
    order.sort_unstable_by_key(|&i| {
        let m = &plan.moves[i as usize];
        ((m.from as u64) << 32) | m.to as u64
    });
    // Plan-wide tables, shared by every group (indices are plan-wide by
    // design, so one allocation serves however many groups the plan
    // fans out to).
    let mut scratch = GroupScratch {
        digests: plan.moves.iter().map(|m| m.digest).collect(),
        out: vec![Response::Nil; plan.moves.len()],
        values: vec![Vec::new().into(); plan.moves.len()],
        sel: Vec::new(),
        put_sel: Vec::new(),
        del_sel: Vec::new(),
        refused: Vec::new(),
    };
    let mut g = 0usize;
    while g < order.len() {
        let lead = &plan.moves[order[g] as usize];
        let (from, to) = (lead.from, lead.to);
        scratch.sel.clear();
        while g < order.len() {
            let m = &plan.moves[order[g] as usize];
            if m.from != from || m.to != to {
                break;
            }
            scratch.sel.push(order[g]);
            g += 1;
        }
        round_trips += apply_group(plan, from, to, shards, &mut scratch, &mut moved)?;
    }
    Ok((moved, round_trips))
}

/// Plan-wide scratch shared by [`apply`]'s groups: response/value/digest
/// tables indexed like the move list, plus the per-step selections.
struct GroupScratch {
    digests: Vec<u64>,
    out: Vec<Response>,
    values: Vec<Value>,
    sel: Vec<u32>,
    put_sel: Vec<u32>,
    del_sel: Vec<u32>,
    refused: Vec<u32>,
}

/// Move one `(source, destination)` group; returns the shard calls
/// issued.
fn apply_group(
    plan: &MigrationPlan,
    from: u32,
    to: u32,
    shards: &[ShardClient],
    s: &mut GroupScratch,
    moved: &mut u64,
) -> Result<u64> {
    let src_shard = &shards[from as usize];
    let dst_shard = &shards[to as usize];
    let moves = &plan.moves[..];
    let mut rts = 0u64;

    // 1. Fetch the source copies in one call.
    src_shard.call_batch(
        BatchOp::Get,
        &s.sel,
        &MoveBatch { moves, values: &[] },
        &s.digests,
        &mut s.out,
    )?;
    rts += 1;
    s.put_sel.clear();
    for &i in &s.sel {
        match std::mem::replace(&mut s.out[i as usize], Response::Nil) {
            // A key that vanished since planning (client DEL / re-PUT
            // that moved it) drops out of the group, as in the per-key
            // sweep.
            Response::Nil => {}
            Response::Val(v) => {
                s.values[i as usize] = v;
                s.put_sel.push(i);
            }
            other => bail!("unexpected GET response {other:?}"),
        }
    }
    if s.put_sel.is_empty() {
        return Ok(rts);
    }

    // 2. Copy onto the destination; PUTNX semantics per key.
    let copy = MoveBatch { moves, values: &s.values };
    dst_shard.call_batch(BatchOp::PutNx, &s.put_sel, &copy, &s.digests, &mut s.out)?;
    rts += 1;
    s.del_sel.clear();
    s.refused.clear();
    for &i in &s.put_sel {
        match s.out[i as usize] {
            Response::Ok => {
                *moved += 1;
                // A keep_source move is a replication copy: the source
                // stays a legitimate holder, so nothing is retired.
                if !moves[i as usize].keep_source {
                    s.del_sel.push(i);
                }
            }
            Response::Nil => s.refused.push(i),
            ref other => bail!("unexpected PUTNX response {other:?}"),
        }
    }

    // 3. Tell the refused copies apart in one destination read.
    if !s.refused.is_empty() {
        dst_shard.call_batch(BatchOp::Get, &s.refused, &copy, &s.digests, &mut s.out)?;
        rts += 1;
        for &i in &s.refused {
            if matches!(s.out[i as usize], Response::Val(_))
                && !moves[i as usize].keep_source
            {
                // A client write raced ahead: retire the stale source
                // copy (not counted as a migrated key).  keep_source
                // moves retain it — the destination holding a newer
                // value does not make the source any less a replica.
                s.del_sel.push(i);
            }
        }
    }

    // 4. Retire the source copies in one call.
    if !s.del_sel.is_empty() {
        s.del_sel.sort_unstable();
        src_shard.call_batch(BatchOp::Del, &s.del_sel, &copy, &s.digests, &mut s.out)?;
        rts += 1;
    }
    Ok(rts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::{self, BinomialHash};
    use crate::hashing::SplitMix64Rng;
    use crate::shard::Shard;

    fn keyset(k: usize) -> Vec<(String, u64)> {
        let mut rng = SplitMix64Rng::new(12);
        (0..k)
            .map(|i| {
                let key = format!("obj-{i}-{}", rng.next_u64());
                let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
                (key, digest)
            })
            .collect()
    }

    #[test]
    fn scale_up_moves_only_to_new_bucket() {
        let keys = keyset(20_000);
        let (old, new) = (BinomialHash::new(8), BinomialHash::new(9));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.to, 8, "monotonicity: moves only onto the new bucket");
        }
        let f = plan.moved_fraction();
        assert!((f - 1.0 / 9.0).abs() < 0.02, "moved fraction {f}");
    }

    #[test]
    fn scale_down_moves_only_from_removed_bucket() {
        let keys = keyset(20_000);
        let (old, new) = (BinomialHash::new(9), BinomialHash::new(8));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.from, 8, "minimal disruption: only the removed bucket's keys move");
        }
    }

    #[test]
    fn streaming_migration_moves_data_in_bounded_batches() {
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        // Place keys per n=2 (bucket 2 unused), then migrate to n=3.
        let keys = keyset(2_000);
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 2, 6);
            if let ShardClient::Local(s) = &shards[b as usize] {
                s.put(key, b"x".to_vec().into(), *digest);
            }
        }
        const BATCH: usize = 64;
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        let stats = migrate_streaming(&shards, &[0, 1], None, BATCH, |chunk| {
            assert!(chunk.len() <= BATCH, "batch bound violated: {}", chunk.len());
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        assert_eq!(stats.scanned, 2_000);
        assert!(stats.moved > 0);
        // 2000 keys over 2 shards x 16 stripes at batch 64: many batches.
        assert!(stats.batches >= 32, "batches={}", stats.batches);
        // Every key now lives on its n=3 bucket; totals preserved.
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 3, 6);
            assert!(shards[b as usize].get(key).unwrap().is_some(), "key {key} not on {b}");
        }
        let total: u64 = shards.iter().map(|s| s.count().unwrap()).sum();
        assert_eq!(total, 2_000);
    }

    #[test]
    fn migration_round_trips_stay_batched() {
        // The batched sweep's acceptance bound: per stripe, one scan plus
        // at most four shard calls per planned batch — i.e. O(ceil(keys /
        // batch)) round-trips — never the per-key sweep's ~3 calls per
        // moved key.
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let keys = keyset(2_000);
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 2, 6);
            if let ShardClient::Local(s) = &shards[b as usize] {
                s.put(key, b"x".to_vec().into(), *digest);
            }
        }
        const BATCH: usize = 64;
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        let stats = migrate_streaming(&shards, &[0, 1], None, BATCH, |chunk| {
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        let stripes_scanned = 2 * crate::shard::STRIPES as u64;
        assert!(
            stats.round_trips <= stripes_scanned + 4 * stats.batches,
            "round_trips={} exceeds scans({stripes_scanned}) + 4×batches({})",
            stats.round_trips,
            stats.batches
        );
        // ~1/3 of 2000 keys move; the per-key sweep would have paid ~3
        // calls for each of them on top of the scans.
        assert!(stats.moved > 400, "moved={}", stats.moved);
        assert!(
            stats.round_trips < stripes_scanned + 3 * stats.moved / 2,
            "round_trips={} is not batched (moved={})",
            stats.round_trips,
            stats.moved
        );
        // Keys all landed (same invariant as the bounded-batches test).
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 3, 6);
            assert!(shards[b as usize].get(key).unwrap().is_some(), "key {key} not on {b}");
        }
    }

    #[test]
    fn streaming_migration_respects_newer_destination_writes() {
        // A key already present on its destination (a "client write that
        // raced ahead") must survive the migration copy untouched.
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let keys = keyset(500);
        let mut raced = None;
        for (key, digest) in &keys {
            let from = binomial::lookup(*digest, 2, 6);
            let to = binomial::lookup(*digest, 3, 6);
            shards[from as usize].put(key, b"stale".to_vec().into()).unwrap();
            if raced.is_none() && from != to {
                shards[to as usize].put(key, b"fresh".to_vec().into()).unwrap();
                raced = Some((key.clone(), to));
            }
        }
        let (raced_key, raced_to) = raced.expect("keyset contains a moving key");
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        migrate_streaming(&shards, &[0, 1], None, 128, |chunk| {
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        assert_eq!(
            shards[raced_to as usize].get(&raced_key).unwrap().as_deref(),
            Some(&b"fresh"[..]),
            "migration clobbered a newer destination write"
        );
    }

    #[test]
    fn keep_source_moves_copy_without_retiring() {
        // A keep_source move is a replication copy: after apply, BOTH
        // shards hold the key.
        let shards: Vec<ShardClient> =
            (0..2).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let digest = crate::hashing::xxhash64(b"rep", 0);
        if let ShardClient::Local(s) = &shards[0] {
            s.put("rep", b"v".to_vec().into(), digest);
        }
        let plan = MigrationPlan {
            moves: vec![Move {
                key: "rep".into(),
                digest,
                from: 0,
                to: 1,
                keep_source: true,
            }],
            scanned: 1,
        };
        let (moved, _) = apply(&plan, &shards).unwrap();
        assert_eq!(moved, 1);
        assert!(shards[0].get("rep").unwrap().is_some(), "source copy retired");
        assert!(shards[1].get("rep").unwrap().is_some(), "destination copy missing");

        // And when the destination already holds a newer value, the
        // refused keep_source copy still leaves the source intact.
        if let ShardClient::Local(s) = &shards[1] {
            s.put("rep", b"newer".to_vec().into(), digest);
        }
        let (moved, _) = apply(&plan, &shards).unwrap();
        assert_eq!(moved, 0);
        assert!(shards[0].get("rep").unwrap().is_some());
        assert_eq!(
            shards[1].get("rep").unwrap().as_deref(),
            Some(&b"newer"[..]),
            "keep_source copy clobbered a newer destination value"
        );
    }

    #[test]
    fn anti_entropy_digests_skip_converged_stripes() {
        // Restore shape: one destination (2, wiped/empty), two survivor
        // sources holding a handful of keys.  The digest comparison must
        // skip every stripe the sources have empty (they match the empty
        // destination) and scan only the occupied ones — strictly fewer
        // round-trips than the full re-stream.
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let keys = keyset(24);
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 2, 6);
            if let ShardClient::Local(s) = &shards[b as usize] {
                s.put(key, b"x".to_vec().into(), *digest);
            }
        }
        let occupied: u64 = (0..2)
            .map(|b| {
                let ShardClient::Local(s) = &shards[b as usize] else { unreachable!() };
                s.stripe_digests().iter().filter(|d| **d != 0).count() as u64
            })
            .sum();
        let total = 2 * crate::shard::STRIPES as u64;
        assert!(occupied < total, "keyset too dense for the skip to show");
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        let stats = migrate_streaming(&shards, &[0, 1], Some(2), 128, |chunk| {
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        assert_eq!(stats.stripes_skipped, total - occupied);
        // Round-trip accounting: 1 dest DIGEST + 2 source DIGESTs +
        // `occupied` scans + 4×batches at most; the full re-stream costs
        // `total` scans + the same batch calls.
        let full = total + 4 * stats.batches;
        assert!(
            stats.round_trips < full,
            "anti-entropy ({}) not below full re-stream ({full})",
            stats.round_trips
        );
        // Correctness unchanged: every key reachable at its n=3 owner.
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 3, 6);
            assert!(shards[b as usize].get(key).unwrap().is_some(), "key {key} not on {b}");
        }
    }

    #[test]
    fn empty_plan_on_no_change() {
        let keys = keyset(1_000);
        let (old, new) = (BinomialHash::new(5), BinomialHash::new(5));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        assert!(plan.moves.is_empty());
        assert_eq!(plan.moved_fraction(), 0.0);
    }

    #[test]
    fn plan_from_forked_stateful_engine_matches_mutation() {
        // The router's scaling path plans with a fork of the live engine;
        // for a stateful engine the fork must carry the construction
        // state, or the plan would disagree with the data path's routing.
        let keys = keyset(5_000);
        let mut live = crate::algorithms::anchor::AnchorHash::with_capacity(6, 32);
        let old = live.fork();
        let added = live.add_bucket();
        let plan =
            plan(&keys, PlanPath::Engines { old: &*old, new: &live }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.to, added, "anchor scale-up move not onto the new bucket");
        }
        assert!(!plan.moves.is_empty());
    }
}
