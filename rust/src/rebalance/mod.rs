//! Rebalancer: computes and applies the minimal key-movement set for a
//! topology change, incrementally.
//!
//! Consistent hashing makes the plan *local*: under monotonicity only keys
//! whose new bucket is the joining one move (scale-up), and under minimal
//! disruption only keys on the leaving bucket move (scale-down).  The
//! planner still verifies this from first principles by computing old/new
//! placement for every key — that check is the bulk workload the
//! [`PlacementRuntime`] XLA artifacts accelerate, and it catches a
//! non-consistent engine (e.g. `maglev`) by reporting its excess moves.
//!
//! The production entry point is [`migrate_streaming`]: it walks every
//! source shard one lock stripe at a time (`Shard::scan_stripe`), plans
//! each bounded batch, and applies it immediately — peak memory is one
//! stripe of keys plus one batch of moves, never the full keyset, and the
//! data path keeps serving (dual-read) while batches land.  The copy step
//! is `PUTNX` so a migration batch can never clobber a newer value a
//! client already wrote to the destination shard, nor resurrect a key a
//! mid-migration `DELTOMB` tombstoned (see [`apply`]).

use anyhow::{bail, Result};

use crate::algorithms::ConsistentHasher;
use crate::proto::{RequestRef, Response};
use crate::runtime::PlacementRuntime;
use crate::shard::ShardClient;

/// One key relocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Move {
    /// Object key.
    pub key: String,
    /// The key's digest (`shard::key_digest`), carried from planning so
    /// `apply` threads it into local shard calls instead of re-hashing.
    pub digest: u64,
    /// Source bucket.
    pub from: u32,
    /// Destination bucket.
    pub to: u32,
}

/// A computed migration plan.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    /// Keys to relocate.
    pub moves: Vec<Move>,
    /// Keys examined.
    pub scanned: usize,
}

impl MigrationPlan {
    /// Fraction of scanned keys that move.
    pub fn moved_fraction(&self) -> f64 {
        if self.scanned == 0 {
            0.0
        } else {
            self.moves.len() as f64 / self.scanned as f64
        }
    }
}

/// How placement is recomputed during planning.
pub enum PlanPath<'a> {
    /// Pure-Rust loop over the two epochs' placement engines (the old
    /// engine is the router's fork of the pre-change snapshot, so this
    /// works for every engine — stateless or stateful).
    Engines {
        /// Engine of the epoch being migrated away from.
        old: &'a dyn ConsistentHasher,
        /// Engine of the epoch being migrated into.
        new: &'a dyn ConsistentHasher,
    },
    /// AOT XLA artifact (BinomialHash engine only): bulk old/new placement
    /// on the PJRT runtime.
    Xla {
        /// Compiled artifact runtime.
        runtime: &'a PlacementRuntime,
        /// Cluster size before the change.
        n_old: u32,
        /// Cluster size after the change.
        n_new: u32,
    },
}

/// Aggregate result of an incremental migration.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MigrationStats {
    /// Keys examined across all stripes.
    pub scanned: u64,
    /// Keys copied to a new owner (and removed from the old one).
    pub moved: u64,
    /// Bounded batches planned and applied.
    pub batches: u64,
}

/// Incremental migration driver: stream the `sources` shards
/// stripe-by-stripe, plan each chunk of at most `batch_size` keys with
/// `plan_batch`, and apply it immediately.
///
/// `shards` must cover the union of the old and new topologies (every
/// `Move::to` destination must be indexable); only the `sources` shards
/// are scanned — every *reachable* old shard on scale-up and on a
/// failed-shard restore, just the retiring shard on scale-down when the
/// engine guarantees minimal disruption (every shard otherwise).  The
/// list may have holes: a degraded topology's failed shards are excluded
/// by the router, because a dead shard can neither be scanned nor be a
/// legal destination.  Unlike the stop-the-world path this
/// never materializes the cluster's keyset — memory is bounded by the
/// largest stripe — and every batch is visible to concurrent readers the
/// moment it lands.
pub fn migrate_streaming(
    shards: &[ShardClient],
    sources: &[u32],
    batch_size: usize,
    mut plan_batch: impl FnMut(&[(String, u64)]) -> Result<MigrationPlan>,
) -> Result<MigrationStats> {
    let batch_size = batch_size.max(1);
    let mut stats = MigrationStats::default();
    for shard in sources.iter().map(|&b| &shards[b as usize]) {
        for stripe in 0..crate::shard::STRIPES as u32 {
            let digested: Vec<(String, u64)> = shard
                .scan_stripe(stripe)?
                .into_iter()
                .map(|key| {
                    let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
                    (key, digest)
                })
                .collect();
            for chunk in digested.chunks(batch_size) {
                let plan = plan_batch(chunk)?;
                stats.scanned += plan.scanned as u64;
                stats.moved += apply(&plan, shards)?;
                stats.batches += 1;
            }
        }
    }
    Ok(stats)
}

/// Compute the migration plan for the scanned keys.
pub fn plan(keys: &[(String, u64)], path: PlanPath<'_>) -> Result<MigrationPlan> {
    let mut plan = MigrationPlan { moves: Vec::new(), scanned: keys.len() };
    match path {
        PlanPath::Engines { old, new } => {
            for (key, digest) in keys {
                let from = old.bucket(*digest);
                let to = new.bucket(*digest);
                if from != to {
                    plan.moves.push(Move { key: key.clone(), digest: *digest, from, to });
                }
            }
        }
        PlanPath::Xla { runtime, n_old, n_new } => {
            let digests: Vec<u64> = keys.iter().map(|(_, d)| *d).collect();
            let outcome = runtime.migration_plan(&digests, n_old, n_new)?;
            for (i, (key, digest)) in keys.iter().enumerate() {
                if outcome.moved[i] != 0 {
                    plan.moves.push(Move {
                        key: key.clone(),
                        digest: *digest,
                        from: outcome.old[i],
                        to: outcome.new[i],
                    });
                }
            }
        }
    }
    Ok(plan)
}

/// Apply a plan: copy each key to its destination shard (`PUTNX` — a
/// value a client already wrote to the destination mid-migration is newer
/// than the copy we hold and must win), then delete the source copy.
/// Values are `Arc<[u8]>`, so a local-to-local move transfers a refcount,
/// not bytes; only remote hops serialize the payload.  Returns the number
/// of keys migrated.
///
/// A refused copy has two causes, told apart by re-reading the
/// destination: a *live* value means a client write raced ahead (the
/// stale source copy is retired here), while *no* value means a
/// mid-migration DEL tombstoned the key between our read and the copy —
/// the source copy is left for that DEL's own source-side delete, so the
/// client's DEL observes the key it is deleting.
pub fn apply(plan: &MigrationPlan, shards: &[ShardClient]) -> Result<u64> {
    let mut moved = 0u64;
    for m in &plan.moves {
        let src = &shards[m.from as usize];
        let dst = &shards[m.to as usize];
        let d = Some(m.digest);
        let value = match src.call_ref(RequestRef::Get { key: &m.key }, d)? {
            Response::Val(v) => v,
            Response::Nil => continue,
            other => bail!("unexpected GET response {other:?}"),
        };
        match dst.call_ref(RequestRef::PutNx { key: &m.key, value }, d)? {
            Response::Ok => {
                src.call_ref(RequestRef::Del { key: &m.key }, d)?;
                moved += 1;
            }
            Response::Nil => {
                if matches!(
                    dst.call_ref(RequestRef::Get { key: &m.key }, d)?,
                    Response::Val(_)
                ) {
                    src.call_ref(RequestRef::Del { key: &m.key }, d)?;
                }
            }
            other => bail!("unexpected PUTNX response {other:?}"),
        }
    }
    Ok(moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::{self, BinomialHash};
    use crate::hashing::SplitMix64Rng;
    use crate::shard::Shard;

    fn keyset(k: usize) -> Vec<(String, u64)> {
        let mut rng = SplitMix64Rng::new(12);
        (0..k)
            .map(|i| {
                let key = format!("obj-{i}-{}", rng.next_u64());
                let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
                (key, digest)
            })
            .collect()
    }

    #[test]
    fn scale_up_moves_only_to_new_bucket() {
        let keys = keyset(20_000);
        let (old, new) = (BinomialHash::new(8), BinomialHash::new(9));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.to, 8, "monotonicity: moves only onto the new bucket");
        }
        let f = plan.moved_fraction();
        assert!((f - 1.0 / 9.0).abs() < 0.02, "moved fraction {f}");
    }

    #[test]
    fn scale_down_moves_only_from_removed_bucket() {
        let keys = keyset(20_000);
        let (old, new) = (BinomialHash::new(9), BinomialHash::new(8));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.from, 8, "minimal disruption: only the removed bucket's keys move");
        }
    }

    #[test]
    fn streaming_migration_moves_data_in_bounded_batches() {
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        // Place keys per n=2 (bucket 2 unused), then migrate to n=3.
        let keys = keyset(2_000);
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 2, 6);
            if let ShardClient::Local(s) = &shards[b as usize] {
                s.put(key, b"x".to_vec().into(), *digest);
            }
        }
        const BATCH: usize = 64;
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        let stats = migrate_streaming(&shards, &[0, 1], BATCH, |chunk| {
            assert!(chunk.len() <= BATCH, "batch bound violated: {}", chunk.len());
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        assert_eq!(stats.scanned, 2_000);
        assert!(stats.moved > 0);
        // 2000 keys over 2 shards x 16 stripes at batch 64: many batches.
        assert!(stats.batches >= 32, "batches={}", stats.batches);
        // Every key now lives on its n=3 bucket; totals preserved.
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 3, 6);
            assert!(shards[b as usize].get(key).unwrap().is_some(), "key {key} not on {b}");
        }
        let total: u64 = shards.iter().map(|s| s.count().unwrap()).sum();
        assert_eq!(total, 2_000);
    }

    #[test]
    fn streaming_migration_respects_newer_destination_writes() {
        // A key already present on its destination (a "client write that
        // raced ahead") must survive the migration copy untouched.
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let keys = keyset(500);
        let mut raced = None;
        for (key, digest) in &keys {
            let from = binomial::lookup(*digest, 2, 6);
            let to = binomial::lookup(*digest, 3, 6);
            shards[from as usize].put(key, b"stale".to_vec().into()).unwrap();
            if raced.is_none() && from != to {
                shards[to as usize].put(key, b"fresh".to_vec().into()).unwrap();
                raced = Some((key.clone(), to));
            }
        }
        let (raced_key, raced_to) = raced.expect("keyset contains a moving key");
        let (old, new) = (BinomialHash::new(2), BinomialHash::new(3));
        migrate_streaming(&shards, &[0, 1], 128, |chunk| {
            plan(chunk, PlanPath::Engines { old: &old, new: &new })
        })
        .unwrap();
        assert_eq!(
            shards[raced_to as usize].get(&raced_key).unwrap().as_deref(),
            Some(&b"fresh"[..]),
            "migration clobbered a newer destination write"
        );
    }

    #[test]
    fn empty_plan_on_no_change() {
        let keys = keyset(1_000);
        let (old, new) = (BinomialHash::new(5), BinomialHash::new(5));
        let plan = plan(&keys, PlanPath::Engines { old: &old, new: &new }).unwrap();
        assert!(plan.moves.is_empty());
        assert_eq!(plan.moved_fraction(), 0.0);
    }

    #[test]
    fn plan_from_forked_stateful_engine_matches_mutation() {
        // The router's scaling path plans with a fork of the live engine;
        // for a stateful engine the fork must carry the construction
        // state, or the plan would disagree with the data path's routing.
        let keys = keyset(5_000);
        let mut live = crate::algorithms::anchor::AnchorHash::with_capacity(6, 32);
        let old = live.fork();
        let added = live.add_bucket();
        let plan =
            plan(&keys, PlanPath::Engines { old: &*old, new: &live }).unwrap();
        for m in &plan.moves {
            assert_eq!(m.to, added, "anchor scale-up move not onto the new bucket");
        }
        assert!(!plan.moves.is_empty());
    }
}
