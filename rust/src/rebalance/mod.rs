//! Rebalancer: computes and applies the minimal key-movement set for a
//! topology change.
//!
//! Consistent hashing makes the plan *local*: under monotonicity only keys
//! whose new bucket is the joining one move (scale-up), and under minimal
//! disruption only keys on the leaving bucket move (scale-down).  The
//! planner still verifies this from first principles by computing old/new
//! placement for every key — that check is the bulk workload the
//! [`PlacementRuntime`] XLA artifacts accelerate, and it catches a
//! non-consistent engine (e.g. `maglev`) by reporting its excess moves.

use anyhow::Result;

use crate::runtime::PlacementRuntime;
use crate::shard::ShardClient;

/// One key relocation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Move {
    /// Object key.
    pub key: String,
    /// Source bucket.
    pub from: u32,
    /// Destination bucket.
    pub to: u32,
}

/// A computed migration plan.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    /// Keys to relocate.
    pub moves: Vec<Move>,
    /// Keys examined.
    pub scanned: usize,
}

impl MigrationPlan {
    /// Fraction of scanned keys that move.
    pub fn moved_fraction(&self) -> f64 {
        if self.scanned == 0 {
            0.0
        } else {
            self.moves.len() as f64 / self.scanned as f64
        }
    }
}

/// How placement is recomputed during planning.
pub enum PlanPath<'a> {
    /// Pure-Rust loop over arbitrary `(old, new)` placement functions.
    Rust(&'a dyn Fn(u64) -> u32, &'a dyn Fn(u64) -> u32),
    /// AOT XLA artifact (BinomialHash engine only): bulk old/new placement
    /// on the PJRT runtime.
    Xla {
        /// Compiled artifact runtime.
        runtime: &'a PlacementRuntime,
        /// Cluster size before the change.
        n_old: u32,
        /// Cluster size after the change.
        n_new: u32,
    },
}

/// Collect every key (with digest) currently stored on the given shards.
pub fn scan_cluster(shards: &[ShardClient]) -> Result<Vec<(String, u64)>> {
    let mut all = Vec::new();
    for shard in shards {
        for key in shard.scan()? {
            let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
            all.push((key, digest));
        }
    }
    Ok(all)
}

/// Compute the migration plan for the scanned keys.
pub fn plan(keys: &[(String, u64)], path: PlanPath<'_>) -> Result<MigrationPlan> {
    let mut plan = MigrationPlan { moves: Vec::new(), scanned: keys.len() };
    match path {
        PlanPath::Rust(old_fn, new_fn) => {
            for (key, digest) in keys {
                let from = old_fn(*digest);
                let to = new_fn(*digest);
                if from != to {
                    plan.moves.push(Move { key: key.clone(), from, to });
                }
            }
        }
        PlanPath::Xla { runtime, n_old, n_new } => {
            let digests: Vec<u64> = keys.iter().map(|(_, d)| *d).collect();
            let outcome = runtime.migration_plan(&digests, n_old, n_new)?;
            for (i, (key, _)) in keys.iter().enumerate() {
                if outcome.moved[i] != 0 {
                    plan.moves.push(Move {
                        key: key.clone(),
                        from: outcome.old[i],
                        to: outcome.new[i],
                    });
                }
            }
        }
    }
    Ok(plan)
}

/// Apply a plan: copy each key to its destination shard, then delete the
/// source copy.  Returns the number of keys migrated.
pub fn apply(plan: &MigrationPlan, shards: &[ShardClient]) -> Result<u64> {
    let mut moved = 0u64;
    for m in &plan.moves {
        let src = &shards[m.from as usize];
        let dst = &shards[m.to as usize];
        if let Some(value) = src.get(&m.key)? {
            dst.put(&m.key, value)?;
            src.del(&m.key)?;
            moved += 1;
        }
    }
    Ok(moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial;
    use crate::hashing::SplitMix64Rng;
    use crate::shard::Shard;

    fn keyset(k: usize) -> Vec<(String, u64)> {
        let mut rng = SplitMix64Rng::new(12);
        (0..k)
            .map(|i| {
                let key = format!("obj-{i}-{}", rng.next_u64());
                let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
                (key, digest)
            })
            .collect()
    }

    #[test]
    fn scale_up_moves_only_to_new_bucket() {
        let keys = keyset(20_000);
        let plan = plan(
            &keys,
            PlanPath::Rust(&|d| binomial::lookup(d, 8, 6), &|d| binomial::lookup(d, 9, 6)),
        )
        .unwrap();
        for m in &plan.moves {
            assert_eq!(m.to, 8, "monotonicity: moves only onto the new bucket");
        }
        let f = plan.moved_fraction();
        assert!((f - 1.0 / 9.0).abs() < 0.02, "moved fraction {f}");
    }

    #[test]
    fn scale_down_moves_only_from_removed_bucket() {
        let keys = keyset(20_000);
        let plan = plan(
            &keys,
            PlanPath::Rust(&|d| binomial::lookup(d, 9, 6), &|d| binomial::lookup(d, 8, 6)),
        )
        .unwrap();
        for m in &plan.moves {
            assert_eq!(m.from, 8, "minimal disruption: only the removed bucket's keys move");
        }
    }

    #[test]
    fn apply_moves_data() {
        let shards: Vec<ShardClient> =
            (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        // Place keys per n=2 (bucket 2 unused), then migrate to n=3.
        let keys = keyset(2_000);
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 2, 6);
            if let ShardClient::Local(s) = &shards[b as usize] {
                s.put(key.clone(), b"x".to_vec());
            }
        }
        let scanned = scan_cluster(&shards).unwrap();
        assert_eq!(scanned.len(), 2_000);
        let plan = plan(
            &scanned,
            PlanPath::Rust(&|d| binomial::lookup(d, 2, 6), &|d| binomial::lookup(d, 3, 6)),
        )
        .unwrap();
        let moved = apply(&plan, &shards).unwrap();
        assert_eq!(moved as usize, plan.moves.len());
        assert!(moved > 0);
        // Every key now lives on its n=3 bucket; totals preserved.
        for (key, digest) in &keys {
            let b = binomial::lookup(*digest, 3, 6);
            assert!(shards[b as usize].get(key).unwrap().is_some(), "key {key} not on {b}");
        }
        let total: u64 = shards.iter().map(|s| s.count().unwrap()).sum();
        assert_eq!(total, 2_000);
    }

    #[test]
    fn empty_plan_on_no_change() {
        let keys = keyset(1_000);
        let plan = plan(
            &keys,
            PlanPath::Rust(&|d| binomial::lookup(d, 5, 6), &|d| binomial::lookup(d, 5, 6)),
        )
        .unwrap();
        assert!(plan.moves.is_empty());
        assert_eq!(plan.moved_fraction(), 0.0);
    }
}
