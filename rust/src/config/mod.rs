//! Configuration: a TOML-subset file format + programmatic defaults for
//! the `binhashd` launcher.
//!
//! The parser covers the subset the config actually uses — `[section]`
//! headers, `key = value` with string / integer / boolean /
//! string-array / integer-array values, and `#` comments — implemented
//! in-tree because the build is fully offline (no serde/toml crates).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Cluster/placement settings.
    pub cluster: ClusterConfig,
    /// Router front-end settings.
    pub router: RouterConfig,
    /// Replication settings.
    pub replication: ReplicationConfig,
    /// Placement-stack settings (weights, hot-key cache).
    pub placement: PlacementConfig,
    /// AOT artifact settings.
    pub artifacts: ArtifactsConfig,
}

/// Placement engine settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Placement algorithm (see `algorithms::ALL_ALGORITHMS`).
    pub algorithm: String,
    /// BinomialHash ω (max rehash iterations).
    pub omega: u32,
    /// Initial shard count.
    pub initial_shards: u32,
}

/// Router settings.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterConfig {
    /// Listen address.
    pub listen: String,
    /// Connections pooled per remote shard.
    pub pool: usize,
    /// Remote shard addresses (empty = spawn in-process shards).
    pub shard_addrs: Vec<String>,
    /// Serving personality: `"event"` (epoll readiness loops; Linux) or
    /// `"blocking"` (thread per connection).
    pub serve: String,
    /// Event-loop thread count; `0` = one per core, capped at 8.
    pub event_loops: usize,
    /// Accept cap: connections beyond this are dropped (and counted in
    /// `STATS` as `conns_dropped`).
    pub max_conns: usize,
}

/// Replication settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicationConfig {
    /// Copies per key (1 = replication off; primary only).
    pub factor: u32,
    /// Write acknowledgement mode: `"primary"` (ack once the primary
    /// write lands; replica failures are counted, not surfaced) or
    /// `"all"` (any replica failure fails the write).
    pub write_mode: String,
}

/// Placement-stack settings: the `Weighted` virtual-bucket adapter and
/// the router's hot-key cache (see the router module's "placement
/// stack" docs).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PlacementConfig {
    /// Per-shard weights (one entry per initial shard, each ≥ 1).
    /// Empty = uniform placement with the bare engine (no `Weighted`
    /// wrapper).  A weight-2 shard owns twice the keyspace of a
    /// weight-1 shard.
    pub weights: Vec<u32>,
    /// Hot-key LRU capacity in front of shard I/O (0 = cache off).
    pub hot_cache_keys: usize,
}

/// Artifact settings.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactsConfig {
    /// Directory holding `manifest.txt` + `*.hlo.txt`.
    pub dir: String,
    /// Load the PJRT bulk runtime at router start.
    pub enable_bulk: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { algorithm: "binomial".into(), omega: 6, initial_shards: 8 }
    }
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7600".into(),
            pool: 4,
            shard_addrs: Vec::new(),
            serve: "event".into(),
            event_loops: 0,
            max_conns: 65_536,
        }
    }
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self { factor: 1, write_mode: "primary".into() }
    }
}

impl Default for ArtifactsConfig {
    fn default() -> Self {
        Self { dir: "artifacts".into(), enable_bulk: false }
    }
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cluster: ClusterConfig::default(),
            router: RouterConfig::default(),
            replication: ReplicationConfig::default(),
            placement: PlacementConfig::default(),
            artifacts: ArtifactsConfig::default(),
        }
    }
}

/// A parsed scalar/array value.
#[derive(Debug, Clone, PartialEq)]
enum Value {
    Str(String),
    Int(i64),
    Bool(bool),
    StrArray(Vec<String>),
    IntArray(Vec<i64>),
}

fn parse_value(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
        ensure!(!inner.contains('"'), "escaped quotes unsupported: {raw}");
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = raw.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::StrArray(Vec::new()));
        }
        // Homogeneous arrays only: the first item picks the type.
        let items = inner.split(',').map(parse_value).collect::<Result<Vec<_>>>()?;
        if items.iter().all(|v| matches!(v, Value::Int(_))) {
            let ints = items
                .into_iter()
                .map(|v| match v {
                    Value::Int(x) => x,
                    _ => unreachable!("all items matched Int"),
                })
                .collect();
            return Ok(Value::IntArray(ints));
        }
        let strs = items
            .into_iter()
            .map(|v| match v {
                Value::Str(x) => Ok(x),
                other => bail!("array items must be all strings or all integers, got {other:?}"),
            })
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::StrArray(strs));
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("unparseable value: {raw}")
}

/// Parse the TOML-subset text into `section.key -> value`.
fn parse_toml_subset(text: &str) -> Result<HashMap<String, Value>> {
    let mut out = HashMap::new();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (key, raw) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let value = parse_value(raw)
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        let full = if section.is_empty() {
            key.trim().to_string()
        } else {
            format!("{section}.{}", key.trim())
        };
        out.insert(full, value);
    }
    Ok(out)
}

macro_rules! take {
    ($map:expr, $key:expr, $variant:ident, $target:expr) => {
        if let Some(v) = $map.remove($key) {
            match v {
                Value::$variant(x) => $target = x.try_into().ok().unwrap_or($target),
                other => bail!("{}: wrong type {:?}", $key, other),
            }
        }
    };
}

impl Config {
    /// Parse configuration text (TOML subset), filling defaults.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = parse_toml_subset(text)?;
        let mut cfg = Config::default();
        take!(map, "cluster.algorithm", Str, cfg.cluster.algorithm);
        if let Some(v) = map.remove("cluster.omega") {
            match v {
                Value::Int(x) => cfg.cluster.omega = u32::try_from(x)?,
                other => bail!("cluster.omega: wrong type {other:?}"),
            }
        }
        if let Some(v) = map.remove("cluster.initial_shards") {
            match v {
                Value::Int(x) => cfg.cluster.initial_shards = u32::try_from(x)?,
                other => bail!("cluster.initial_shards: wrong type {other:?}"),
            }
        }
        take!(map, "router.listen", Str, cfg.router.listen);
        if let Some(v) = map.remove("router.pool") {
            match v {
                Value::Int(x) => cfg.router.pool = usize::try_from(x)?,
                other => bail!("router.pool: wrong type {other:?}"),
            }
        }
        take!(map, "router.shard_addrs", StrArray, cfg.router.shard_addrs);
        take!(map, "router.serve", Str, cfg.router.serve);
        if let Some(v) = map.remove("router.event_loops") {
            match v {
                Value::Int(x) => cfg.router.event_loops = usize::try_from(x)?,
                other => bail!("router.event_loops: wrong type {other:?}"),
            }
        }
        if let Some(v) = map.remove("router.max_conns") {
            match v {
                Value::Int(x) => cfg.router.max_conns = usize::try_from(x)?,
                other => bail!("router.max_conns: wrong type {other:?}"),
            }
        }
        if let Some(v) = map.remove("replication.factor") {
            match v {
                Value::Int(x) => cfg.replication.factor = u32::try_from(x)?,
                other => bail!("replication.factor: wrong type {other:?}"),
            }
        }
        take!(map, "replication.write_mode", Str, cfg.replication.write_mode);
        if let Some(v) = map.remove("placement.weights") {
            match v {
                Value::IntArray(xs) => {
                    cfg.placement.weights = xs
                        .into_iter()
                        .map(|x| {
                            u32::try_from(x).map_err(|_| {
                                anyhow::anyhow!("placement.weights: {x} out of range")
                            })
                        })
                        .collect::<Result<Vec<_>>>()?;
                }
                // `weights = []` parses as the empty string-array.
                Value::StrArray(xs) if xs.is_empty() => cfg.placement.weights = Vec::new(),
                other => bail!("placement.weights: wrong type {other:?}"),
            }
        }
        if let Some(v) = map.remove("placement.hot_cache_keys") {
            match v {
                Value::Int(x) => cfg.placement.hot_cache_keys = usize::try_from(x)?,
                other => bail!("placement.hot_cache_keys: wrong type {other:?}"),
            }
        }
        take!(map, "artifacts.dir", Str, cfg.artifacts.dir);
        take!(map, "artifacts.enable_bulk", Bool, cfg.artifacts.enable_bulk);
        if let Some(k) = map.keys().next() {
            bail!("unknown config key {k:?}");
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parsing config {path:?}"))
    }

    /// Serialize to the TOML subset (used by `binhashd init-config`).
    pub fn to_toml(&self) -> String {
        let addrs = self
            .router
            .shard_addrs
            .iter()
            .map(|a| format!("\"{a}\""))
            .collect::<Vec<_>>()
            .join(", ");
        let weights = self
            .placement
            .weights
            .iter()
            .map(|w| w.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "[cluster]\nalgorithm = \"{}\"\nomega = {}\ninitial_shards = {}\n\n\
             [router]\nlisten = \"{}\"\npool = {}\nshard_addrs = [{}]\n\
             serve = \"{}\"\nevent_loops = {}\nmax_conns = {}\n\n\
             [replication]\nfactor = {}\nwrite_mode = \"{}\"\n\n\
             [placement]\nweights = [{}]\nhot_cache_keys = {}\n\n\
             [artifacts]\ndir = \"{}\"\nenable_bulk = {}\n",
            self.cluster.algorithm,
            self.cluster.omega,
            self.cluster.initial_shards,
            self.router.listen,
            self.router.pool,
            addrs,
            self.router.serve,
            self.router.event_loops,
            self.router.max_conns,
            self.replication.factor,
            self.replication.write_mode,
            weights,
            self.placement.hot_cache_keys,
            self.artifacts.dir,
            self.artifacts.enable_bulk,
        )
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            crate::algorithms::by_name(&self.cluster.algorithm, 1).is_some(),
            "unknown algorithm {:?} (known: {:?})",
            self.cluster.algorithm,
            crate::algorithms::ALL_ALGORITHMS
        );
        ensure!(self.cluster.omega >= 1, "omega must be >= 1");
        ensure!(self.cluster.initial_shards >= 1, "need at least one shard");
        ensure!(
            matches!(self.router.serve.as_str(), "event" | "blocking"),
            "router.serve must be \"event\" or \"blocking\", got {:?}",
            self.router.serve
        );
        ensure!(self.router.max_conns >= 1, "max_conns must be >= 1");
        ensure!(self.replication.factor >= 1, "replication.factor must be >= 1");
        ensure!(
            self.replication.factor <= 8,
            "replication.factor must be <= 8 (got {})",
            self.replication.factor
        );
        ensure!(
            matches!(self.replication.write_mode.as_str(), "primary" | "all"),
            "replication.write_mode must be \"primary\" or \"all\", got {:?}",
            self.replication.write_mode
        );
        if !self.router.shard_addrs.is_empty() {
            ensure!(
                self.router.shard_addrs.len() == self.cluster.initial_shards as usize,
                "shard_addrs length must equal initial_shards"
            );
        }
        if !self.placement.weights.is_empty() {
            ensure!(
                self.placement.weights.len() == self.cluster.initial_shards as usize,
                "placement.weights length ({}) must equal initial_shards ({})",
                self.placement.weights.len(),
                self.cluster.initial_shards
            );
            ensure!(
                self.placement.weights.iter().all(|&w| w >= 1),
                "placement.weights entries must be >= 1"
            );
            let total: u64 = self.placement.weights.iter().map(|&w| w as u64).sum();
            ensure!(
                total <= 65_536,
                "placement.weights sum to {total} virtual buckets (max 65536)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let mut c = Config::default();
        c.router.shard_addrs = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        c.cluster.initial_shards = 2;
        let text = c.to_toml();
        let back = Config::parse(&text).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn partial_config_fills_defaults() {
        let c = Config::parse(
            "# comment\n[cluster]\nalgorithm = \"jumpback\"  # inline comment\ninitial_shards = 3\n",
        )
        .unwrap();
        assert_eq!(c.cluster.algorithm, "jumpback");
        assert_eq!(c.cluster.initial_shards, 3);
        assert_eq!(c.cluster.omega, 6); // default
        assert_eq!(c.router.pool, 4); // default
        c.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::parse("[cluster]\nbogus = 1\n").is_err());
    }

    #[test]
    fn wrong_type_rejected() {
        assert!(Config::parse("[cluster]\nomega = \"six\"\n").is_err());
    }

    #[test]
    fn bad_algorithm_rejected() {
        let mut c = Config::default();
        c.cluster.algorithm = "bogus".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn mismatched_shard_addrs_rejected() {
        let mut c = Config::default();
        c.router.shard_addrs = vec!["127.0.0.1:1".into()];
        c.cluster.initial_shards = 2;
        assert!(c.validate().is_err());
    }

    #[test]
    fn array_parsing() {
        let c = Config::parse(
            "[cluster]\ninitial_shards = 2\n[router]\nshard_addrs = [\"a:1\", \"b:2\"]\n",
        )
        .unwrap();
        assert_eq!(c.router.shard_addrs, vec!["a:1", "b:2"]);
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("[router]\nshard_addrs = []\n").unwrap();
        assert!(c.router.shard_addrs.is_empty());
    }

    #[test]
    fn serve_knobs_parse_and_validate() {
        let c = Config::parse(
            "[router]\nserve = \"blocking\"\nevent_loops = 2\nmax_conns = 100\n",
        )
        .unwrap();
        assert_eq!(c.router.serve, "blocking");
        assert_eq!(c.router.event_loops, 2);
        assert_eq!(c.router.max_conns, 100);
        c.validate().unwrap();

        // Defaults: event personality, auto loop count.
        let d = Config::default();
        assert_eq!(d.router.serve, "event");
        assert_eq!(d.router.event_loops, 0);

        let mut bad = Config::default();
        bad.router.serve = "fibers".into();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn replication_knobs_parse_and_validate() {
        let c = Config::parse("[replication]\nfactor = 2\nwrite_mode = \"all\"\n")
            .unwrap();
        assert_eq!(c.replication.factor, 2);
        assert_eq!(c.replication.write_mode, "all");
        c.validate().unwrap();

        // Defaults: replication off, primary-ack.
        let d = Config::default();
        assert_eq!(d.replication.factor, 1);
        assert_eq!(d.replication.write_mode, "primary");
        d.validate().unwrap();

        let mut bad = Config::default();
        bad.replication.factor = 0;
        assert!(bad.validate().is_err());
        bad.replication.factor = 9;
        assert!(bad.validate().is_err());
        bad.replication.factor = 2;
        bad.replication.write_mode = "quorum".into();
        assert!(bad.validate().is_err());

        assert!(Config::parse("[replication]\nfactor = \"two\"\n").is_err());
    }

    #[test]
    fn placement_knobs_parse_and_validate() {
        let c = Config::parse(
            "[cluster]\ninitial_shards = 3\n\
             [placement]\nweights = [2, 1, 1]\nhot_cache_keys = 256\n",
        )
        .unwrap();
        assert_eq!(c.placement.weights, vec![2, 1, 1]);
        assert_eq!(c.placement.hot_cache_keys, 256);
        c.validate().unwrap();

        // Defaults: no weights (bare engine), cache off.
        let d = Config::default();
        assert!(d.placement.weights.is_empty());
        assert_eq!(d.placement.hot_cache_keys, 0);
        d.validate().unwrap();

        // An explicitly empty weight list is the default layout.
        let e = Config::parse("[placement]\nweights = []\n").unwrap();
        assert!(e.placement.weights.is_empty());
        e.validate().unwrap();
    }

    #[test]
    fn placement_validation_rejects_bad_weights() {
        let mut c = Config::default();
        c.cluster.initial_shards = 2;
        c.placement.weights = vec![2, 1, 1];
        assert!(c.validate().is_err(), "length mismatch");
        c.placement.weights = vec![1, 0];
        assert!(c.validate().is_err(), "zero weight");
        c.placement.weights = vec![60_000, 60_000];
        assert!(c.validate().is_err(), "virtual-bucket blowup");
        c.placement.weights = vec![2, 1];
        c.validate().unwrap();

        assert!(
            Config::parse("[placement]\nweights = [2, \"x\"]\n").is_err(),
            "mixed-type array"
        );
        assert!(Config::parse("[placement]\nweights = [-1]\n").is_err(), "negative weight");
    }

    #[test]
    fn placement_roundtrips_through_toml() {
        let mut c = Config::default();
        c.cluster.initial_shards = 4;
        c.placement.weights = vec![2, 1, 1, 1];
        c.placement.hot_cache_keys = 128;
        let back = Config::parse(&c.to_toml()).unwrap();
        assert_eq!(c, back);
    }
}
