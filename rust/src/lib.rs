//! # binhash — BinomialHash consistent hashing & distributed-KV framework
//!
//! Production-grade reproduction of *BinomialHash: A Constant Time,
//! Minimal Memory Consistent Hashing Algorithm* (Coluzzi, Brocco,
//! Antonucci & Leidi, 2024), built as the system the paper motivates: a
//! distributed key-value store / request-routing framework whose
//! placement engine is consistent hashing.
//!
//! ## Layers
//!
//! * [`algorithms`] — BinomialHash (exact, golden-pinned against the
//!   paper's pseudocode) plus every baseline from the paper's §6 and the
//!   authors' survey.
//! * [`hashing`] — the hash substrate (xxhash64, splitmix64 family),
//!   bitwise-identical to the Python/Pallas build path.
//! * [`cluster`] / [`router`] / [`shard`] / [`rebalance`] — the
//!   coordinator: membership, epoch-snapshot request routing, in-memory
//!   storage nodes, and incremental migration. Topology changes publish
//!   immutable placement snapshots;
//!   the data path never blocks on a rebalance.  Failover (`FAIL` /
//!   `RESTORE` wire ops) publishes *degraded* epochs that route around
//!   dead shards through the fault-tolerant engines (anchor, dx,
//!   memento) and migrates a restored shard's keyspace back to it.
//! * [`net`] — connection serving behind one `Service` trait: a raw
//!   `epoll` readiness event server for 10k+ concurrent connections
//!   (std + declared syscalls — the build stays fully offline, no
//!   tokio/mio/libc crate) with the historical blocking
//!   thread-per-connection loop as the portable fallback.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas bulk
//!   placement artifacts (`artifacts/*.hlo.txt`); compiled in only with
//!   the `pjrt` cargo feature (a same-API stub otherwise).
//! * [`stats`] / [`workload`] / [`metrics`] — balance statistics (§5
//!   closed forms), workload generators, telemetry.
//!
//! ## Quickstart
//!
//! ```rust
//! use binhash::algorithms::{binomial::BinomialHash, ConsistentHasher};
//!
//! let mut ch = BinomialHash::new(11);
//! let bucket = ch.bucket_for_key(b"object/42");
//! assert!(bucket < 11);
//! ch.add_bucket(); // scale up: only ~1/12 of keys move, all onto bucket 11
//! ```
//!
//! ## Verification matrix
//!
//! The concurrent modules import all synchronization primitives from
//! [`sync`] (boundary enforced by `tools/lint_sync.py`).  Normal builds
//! compile the shim to zero-cost `std` re-exports; `--features model`
//! swaps in instrumented primitives driven by a deterministic schedule
//! explorer (`rust/tests/model.rs`), and CI additionally runs Miri and
//! the thread/address sanitizers over the same code.  See the [`sync`]
//! module docs for how to replay a failing schedule seed.

// Every unsafe block must carry a `// SAFETY:` comment explaining why
// its invariants hold (checked by clippy in the CI lint step).
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod algorithms;
pub mod cluster;
pub mod config;
pub mod hashing;
pub mod metrics;
pub mod net;
pub mod proto;
pub mod rebalance;
pub mod router;
pub mod runtime;
pub mod shard;
pub mod stats;
pub mod sync;
pub mod workload;
