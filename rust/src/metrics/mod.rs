//! Telemetry: lock-free counters and fixed-bucket latency histograms.
//!
//! Hand-rolled (no external metrics crate) so the router's hot path costs
//! exactly one relaxed atomic increment per event.

use crate::sync::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram: 64 buckets, ~2× resolution from 1µs.
///
/// Bucket `i` covers `[2^i, 2^{i+1})` nanoseconds.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// New empty histogram.
    pub const fn new() -> Self {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self { buckets: [ZERO; 64], count: ZERO, sum_ns: ZERO }
    }

    /// Record one latency observation.
    #[inline]
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let idx = (63 - ns.max(1).leading_zeros()) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.count.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.sum_ns.fetch_add(ns, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
    }

    /// Mean latency in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 // ord: Relaxed — independent telemetry counter
        }
    }

    /// Approximate quantile (upper bucket bound), `q ∈ [0, 1]`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }
}

/// Slots in [`RoutedLoad`]'s fixed counter array (shard ids alias into
/// it modulo this; a power of two so the hot-path index is one mask).
pub const ROUTED_SLOTS: usize = 1024;

/// Per-shard routed-op counters: the *measured* side of the paper's
/// balance claims.  One relaxed increment per routed singleton op;
/// [`load_factor`](Self::load_factor) reduces the array to max/mean —
/// 1.0 is perfect balance, and `1 + 2^{-ω}` is the theory ceiling for
/// BinomialHash under uniform keys (`stats::theory`).
#[derive(Debug)]
pub struct RoutedLoad {
    counts: [AtomicU64; ROUTED_SLOTS],
}

impl Default for RoutedLoad {
    fn default() -> Self {
        Self::new()
    }
}

impl RoutedLoad {
    /// New zeroed counters.
    pub const fn new() -> Self {
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self { counts: [ZERO; ROUTED_SLOTS] }
    }

    /// Count one op routed to `bucket`.
    #[inline]
    pub fn record(&self, bucket: u32) {
        self.counts[bucket as usize & (ROUTED_SLOTS - 1)]
            .fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
    }

    /// Ops routed to `bucket` so far.
    pub fn count(&self, bucket: u32) -> u64 {
        self.counts[bucket as usize & (ROUTED_SLOTS - 1)].load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
    }

    /// Measured load factor over the first `shards` buckets: the busiest
    /// bucket's share of traffic relative to a perfectly even spread
    /// (max / mean).  `0.0` before any op is routed.
    pub fn load_factor(&self, shards: u32) -> f64 {
        let n = (shards as usize).clamp(1, ROUTED_SLOTS);
        let (mut max, mut sum) = (0u64, 0u64);
        for c in &self.counts[..n] {
            let v = c.load(Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            max = max.max(v);
            sum += v;
        }
        if sum == 0 {
            0.0
        } else {
            max as f64 * n as f64 / sum as f64
        }
    }

    /// Zero every counter (bench phase boundaries).
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        }
    }
}

/// Router-level counters.
#[derive(Debug, Default)]
pub struct RouterMetrics {
    /// GET requests served.
    pub gets: AtomicU64,
    /// PUT requests served.
    pub puts: AtomicU64,
    /// DEL requests served.
    pub dels: AtomicU64,
    /// Requests that failed (shard error / bad request).
    pub errors: AtomicU64,
    /// Keys migrated by rebalances.
    pub migrated_keys: AtomicU64,
    /// Bounded batches applied by incremental migrations.
    pub migration_batches: AtomicU64,
    /// GETs answered by the previous epoch's owner mid-migration
    /// (new-owner-then-old-owner dual reads).
    pub dual_reads: AtomicU64,
    /// Topology epochs applied.
    pub epochs: AtomicU64,
    /// Shards failed over (`FAIL` admin ops that published a degraded
    /// epoch).
    pub failovers: AtomicU64,
    /// Failed shards restored (`RESTORE` admin ops that converged).
    pub restores: AtomicU64,
    /// Reads answered `UNAVAILABLE` because the key's data is marooned
    /// on a failed shard (the router routed *around* the dead shard
    /// instead of hanging on it).
    pub unavailable: AtomicU64,
    /// Valid keys admitted from `MGET` batch frames (each also counts in
    /// `gets`, exactly like singleton admission, so `mget_keys / gets`
    /// is the read path's batch adoption).
    pub mget_keys: AtomicU64,
    /// Valid keys admitted from `MPUT` batch frames (each also counts in
    /// `puts`).
    pub mput_keys: AtomicU64,
    /// Per-shard fan-outs issued by the batch path: one per (batch,
    /// owner-shard) group.  `mget_keys + mput_keys` over `batch_fanouts`
    /// is the realized batching factor — how many keys each shard
    /// round-trip amortized.
    pub batch_fanouts: AtomicU64,
    /// Replica writes fanned out behind primaries (`replication.factor`
    /// − 1 per accepted PUT/DEL when the factor is > 1).
    pub replica_writes: AtomicU64,
    /// Replica writes that errored.  Under `write_mode = "primary"`
    /// these are absorbed (the client saw the primary's ack) and left
    /// for anti-entropy; under `"all"` the request also failed.
    pub replica_write_failures: AtomicU64,
    /// GETs answered from a replica after the primary missed (degraded
    /// fallback reads).
    pub replica_reads: AtomicU64,
    /// Replica-served GETs whose value was written back to the current
    /// primary (read repair).
    pub read_repairs: AtomicU64,
    /// Shard round-trips issued by migrations (scans, batched moves,
    /// and anti-entropy `DIGEST` exchanges).
    pub migration_round_trips: AtomicU64,
    /// `(source, stripe)` scans skipped by anti-entropy digest
    /// comparison during restores.
    pub ae_stripes_skipped: AtomicU64,
    /// GETs served from the router's hot-key cache (no shard I/O; the
    /// value is an `Arc` refcount bump).
    pub hot_hits: AtomicU64,
    /// Hot-key cache entries evicted by capacity (LRU victim on fill).
    pub hot_evictions: AtomicU64,
    /// Per-shard routed-op counters (`load_factor` in STATS).
    pub routed: RoutedLoad,
    /// End-to-end request latency.
    pub latency: LatencyHistogram,
    /// Placement (hash lookup) latency.
    pub placement_latency: LatencyHistogram,
}

impl RouterMetrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line summary for logs and examples.
    pub fn summary(&self) -> String {
        format!(
            "gets={} puts={} dels={} errors={} migrated={} batches={} \
             dual_reads={} epochs={} failovers={} restores={} unavailable={} \
             mget_keys={} mput_keys={} batch_fanouts={} \
             replica_writes={} replica_write_failures={} replica_reads={} \
             read_repairs={} migration_round_trips={} ae_stripes_skipped={} \
             hot_hits={} hot_evictions={} \
             p50={}ns p99={}ns mean={:.0}ns",
            self.gets.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.puts.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.dels.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.errors.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.migrated_keys.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.migration_batches.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.dual_reads.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.epochs.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.failovers.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.restores.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.unavailable.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.mget_keys.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.mput_keys.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.batch_fanouts.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.replica_writes.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.replica_write_failures.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.replica_reads.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.read_repairs.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.migration_round_trips.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.ae_stripes_skipped.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.hot_hits.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.hot_evictions.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.latency.quantile_ns(0.5),
            self.latency.quantile_ns(0.99),
            self.latency.mean_ns(),
        )
    }
}

/// Connection-layer counters for the `net` servers (shared by the event
/// and blocking personalities; surfaced through the router's `STATS`
/// line).
#[derive(Debug, Default)]
pub struct ConnMetrics {
    /// Connections accepted (including ones later dropped by the cap).
    pub accepted: AtomicU64,
    /// Currently open connections (gauge).
    pub active: AtomicU64,
    /// Connections dropped: over the `max_conns` cap, failed to
    /// register, or discarded mid-shutdown.
    pub dropped: AtomicU64,
    /// Readiness wakeups (`epoll_wait` returns) across all event loops.
    pub wakeups: AtomicU64,
    /// Flushes cut short by `EWOULDBLOCK` (response parked until the
    /// socket turns writable again).
    pub partial_flushes: AtomicU64,
    /// Read-interest withdrawals by the backpressure rule (pending
    /// output crossed the high-water mark).
    pub deferred_reads: AtomicU64,
}

impl ConnMetrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line summary, `conns_`-prefixed so it can be appended to the
    /// router's `STATS` response unambiguously.
    pub fn summary(&self) -> String {
        format!(
            "conns_accepted={} conns_active={} conns_dropped={} \
             conns_wakeups={} conns_partial_flushes={} conns_deferred_reads={}",
            self.accepted.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.active.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.dropped.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.wakeups.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.partial_flushes.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
            self.deferred_reads.load(Ordering::Relaxed), // ord: Relaxed — independent telemetry counter
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 8, 100, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_ns() > 0.0);
        assert!(h.quantile_ns(0.5) >= 1_000);
        assert!(h.quantile_ns(1.0) >= 1_000_000);
        assert!(h.quantile_ns(0.0) <= h.quantile_ns(1.0));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn metrics_summary_formats() {
        let m = RouterMetrics::new();
        m.gets.fetch_add(3, Ordering::Relaxed); // ord: test-only
        m.mget_keys.fetch_add(2, Ordering::Relaxed); // ord: test-only
        m.batch_fanouts.fetch_add(1, Ordering::Relaxed); // ord: test-only
        m.replica_writes.fetch_add(5, Ordering::Relaxed); // ord: test-only
        m.replica_reads.fetch_add(4, Ordering::Relaxed); // ord: test-only
        m.latency.record(Duration::from_micros(5));
        let s = m.summary();
        assert!(s.contains("gets=3"));
        assert!(s.contains("mget_keys=2"));
        assert!(s.contains("mput_keys=0"));
        assert!(s.contains("batch_fanouts=1"));
        assert!(s.contains("replica_writes=5"));
        assert!(s.contains("replica_write_failures=0"));
        assert!(s.contains("replica_reads=4"));
        assert!(s.contains("read_repairs=0"));
        assert!(s.contains("migration_round_trips=0"));
        assert!(s.contains("ae_stripes_skipped=0"));
        assert!(s.contains("hot_hits=0"));
        assert!(s.contains("hot_evictions=0"));
    }

    #[test]
    fn routed_load_factor_is_max_over_mean() {
        let r = RoutedLoad::new();
        assert_eq!(r.load_factor(4), 0.0, "no traffic yet");
        for _ in 0..30 {
            r.record(0);
        }
        for b in 1..4 {
            for _ in 0..10 {
                r.record(b);
            }
        }
        // max=30, mean=15 over 4 buckets.
        assert!((r.load_factor(4) - 2.0).abs() < 1e-9);
        assert_eq!(r.count(0), 30);
        r.reset();
        assert_eq!(r.load_factor(4), 0.0);
        // Bucket ids alias modulo the slot count without panicking.
        r.record(ROUTED_SLOTS as u32 + 3);
        assert_eq!(r.count(3), 1);
    }

    #[test]
    fn conn_metrics_summary_formats() {
        let c = ConnMetrics::new();
        c.accepted.fetch_add(4, Ordering::Relaxed); // ord: test-only
        c.active.fetch_add(2, Ordering::Relaxed); // ord: test-only
        c.partial_flushes.fetch_add(1, Ordering::Relaxed); // ord: test-only
        let s = c.summary();
        assert!(s.contains("conns_accepted=4"));
        assert!(s.contains("conns_active=2"));
        assert!(s.contains("conns_dropped=0"));
        assert!(s.contains("conns_partial_flushes=1"));
        assert!(s.contains("conns_deferred_reads=0"));
    }
}
