//! Workload generators for benchmarks, tests, and the end-to-end examples.
//!
//! All generators are deterministic (seeded [`SplitMix64Rng`]) so every
//! `bench_figs` CSV series and `BENCH_router.json` phase regenerates
//! bit-identically.

use crate::hashing::{xxhash64, SplitMix64Rng};

/// Stream of uniform u64 digests (the paper's §6 benchmark workload:
/// "keys were sampled from a uniform distribution").
#[derive(Debug, Clone)]
pub struct UniformDigests {
    rng: SplitMix64Rng,
}

impl UniformDigests {
    /// Seeded uniform digest stream.
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64Rng::new(seed) }
    }

    /// Fill a buffer with the next digests.
    pub fn fill(&mut self, out: &mut [u64]) {
        for d in out.iter_mut() {
            *d = self.rng.next_u64();
        }
    }

    /// Collect `k` digests.
    pub fn take_vec(&mut self, k: usize) -> Vec<u64> {
        let mut v = vec![0u64; k];
        self.fill(&mut v);
        v
    }
}

impl Iterator for UniformDigests {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        Some(self.rng.next_u64())
    }
}

/// Zipfian-distributed *object ids*, hashed to digests — the skewed
/// workload for the end-to-end examples (hot keys stress the router's
/// per-shard queues, not the hash function itself, which sees the
/// digest of the id).
#[derive(Debug, Clone)]
pub struct ZipfKeys {
    rng: SplitMix64Rng,
    /// Precomputed CDF over the id universe.
    cdf: Vec<f64>,
}

impl ZipfKeys {
    /// `universe` distinct ids with Zipf exponent `theta` (e.g. 0.99).
    pub fn new(seed: u64, universe: usize, theta: f64) -> Self {
        assert!(universe >= 1);
        let mut weights: Vec<f64> =
            (1..=universe).map(|r| 1.0 / (r as f64).powf(theta)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        Self { rng: SplitMix64Rng::new(seed), cdf: weights }
    }

    /// Next object id (0-based rank; rank 0 is the hottest).
    pub fn next_id(&mut self) -> usize {
        let u = self.rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    /// Next key as a byte string (`"obj-<id>"`) plus its digest.
    pub fn next_key(&mut self) -> (String, u64) {
        let id = self.next_id();
        let key = format!("obj-{id}");
        let digest = xxhash64(key.as_bytes(), 0);
        (key, digest)
    }
}

/// String-key generator: synthetic object names with realistic shape
/// (`"tenant-{t}/bucket-{b}/object-{o}"`), uniform over the id space.
#[derive(Debug, Clone)]
pub struct StringKeys {
    rng: SplitMix64Rng,
    tenants: u64,
    buckets: u64,
}

impl StringKeys {
    /// Seeded generator over `tenants × buckets` namespaces.
    pub fn new(seed: u64, tenants: u64, buckets: u64) -> Self {
        Self { rng: SplitMix64Rng::new(seed), tenants: tenants.max(1), buckets: buckets.max(1) }
    }

    /// Next synthetic object key.
    pub fn next_key(&mut self) -> String {
        let t = self.rng.next_below(self.tenants);
        let b = self.rng.next_below(self.buckets);
        let o = self.rng.next_u64() & 0xFFFF_FFFF;
        format!("tenant-{t}/bucket-{b}/object-{o:08x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_deterministic() {
        let a = UniformDigests::new(42).take_vec(100);
        let b = UniformDigests::new(42).take_vec(100);
        assert_eq!(a, b);
        let c = UniformDigests::new(43).take_vec(100);
        assert_ne!(a, c);
    }

    #[test]
    fn zipf_is_skewed() {
        let mut z = ZipfKeys::new(7, 10_000, 0.99);
        let mut head = 0usize;
        let total = 50_000;
        for _ in 0..total {
            if z.next_id() < 100 {
                head += 1;
            }
        }
        // With theta=0.99 the top-1% ids get far more than 1% of traffic.
        let frac = head as f64 / total as f64;
        assert!(frac > 0.3, "head fraction {frac}");
    }

    #[test]
    fn zipf_ids_in_range() {
        let mut z = ZipfKeys::new(9, 100, 1.2);
        for _ in 0..5_000 {
            assert!(z.next_id() < 100);
        }
    }

    #[test]
    fn string_keys_unique_enough() {
        let mut g = StringKeys::new(1, 4, 16);
        let keys: std::collections::HashSet<String> =
            (0..10_000).map(|_| g.next_key()).collect();
        assert!(keys.len() > 9_900);
    }
}
