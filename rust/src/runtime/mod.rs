//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas placement
//! artifacts from the Rust coordinator.
//!
//! Python never runs here — `make artifacts` lowered the Layer-2 graphs to
//! HLO *text* (`artifacts/*.hlo.txt` + `manifest.txt`); this module parses
//! the text through the PJRT CPU client (`HloModuleProto::from_text_file`
//! → `compile` → `execute`) and exposes typed bulk operations:
//!
//! * [`PlacementRuntime::lookup_batch`] — place digests on an n-cluster;
//! * [`PlacementRuntime::migration_plan`] — old/new placement + moved set
//!   for a topology change (the rebalancer's bulk path);
//! * [`PlacementRuntime::histogram`] — per-bucket load counts.
//!
//! Artifacts are compiled once at load; executions are synchronous CPU
//! calls.
//!
//! ## Feature gate
//!
//! The PJRT path needs the `xla` bindings crate, which is not available in
//! the offline build. It is therefore compiled only with the **`pjrt`**
//! cargo feature (which additionally requires vendoring the `xla` crate
//! and declaring it as a path dependency). Without the feature, a stub
//! [`PlacementRuntime`] with the identical API compiles in: `load` returns
//! an error, so every caller degrades to the pure-Rust planning path.
//! [`Manifest`] parsing and [`MigrationOutcome`] are always available.

use anyhow::{anyhow, bail, Result};

/// Output of a bulk migration-plan execution.
#[derive(Debug, Clone)]
pub struct MigrationOutcome {
    /// Placement under the old topology.
    pub old: Vec<u32>,
    /// Placement under the new topology.
    pub new: Vec<u32>,
    /// 1 where the key moves.
    pub moved: Vec<u8>,
    /// Total number of moved keys.
    pub moved_count: u64,
}

/// Parsed `manifest.txt`: `omega <w>` line + `artifact <name> <file>` lines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// ω the artifacts were lowered with.
    pub omega: u32,
    /// `(name, file)` artifact records.
    pub artifacts: Vec<(String, String)>,
}

impl Manifest {
    /// Parse the flat manifest format emitted by `python/compile/aot.py`.
    pub fn parse(text: &str) -> Result<Self> {
        let mut omega = None;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("omega") => {
                    omega = Some(
                        it.next()
                            .ok_or_else(|| anyhow!("line {}: omega missing value", lineno + 1))?
                            .parse()?,
                    );
                }
                Some("artifact") => {
                    let name = it.next().ok_or_else(|| anyhow!("line {}: name", lineno + 1))?;
                    let file = it.next().ok_or_else(|| anyhow!("line {}: file", lineno + 1))?;
                    artifacts.push((name.to_string(), file.to_string()));
                }
                Some(other) => bail!("line {}: unknown record {other:?}", lineno + 1),
                None => {}
            }
        }
        Ok(Self {
            omega: omega.ok_or_else(|| anyhow!("manifest missing omega"))?,
            artifacts,
        })
    }
}

/// Extract the batch size from an artifact name like `lookup_b4096`.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn parse_batch(name: &str, prefix: &str) -> Option<usize> {
    name.strip_prefix(prefix)?.parse().ok()
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::MigrationOutcome;

    /// Offline stand-in for the PJRT runtime (built without the `pjrt`
    /// feature). Carries the same API so callers compile unchanged;
    /// [`PlacementRuntime::load`] always errors, which routes every
    /// planner to the pure-Rust path.
    pub struct PlacementRuntime {
        /// ω baked into the artifacts (never populated in the stub).
        pub omega: u32,
    }

    impl PlacementRuntime {
        /// Always fails: the PJRT client is not compiled in.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "binhash was built without the `pjrt` feature; cannot load XLA \
                 artifacts from {:?} (vendor the `xla` bindings crate and rebuild \
                 with `--features pjrt`)",
                dir.as_ref()
            )
        }

        /// Unreachable in the stub (no instance can be constructed).
        pub fn lookup_batch(&self, _digests: &[u64], _n: u32) -> Result<Vec<u32>> {
            bail!("pjrt feature disabled")
        }

        /// Unreachable in the stub (no instance can be constructed).
        pub fn migration_plan(
            &self,
            _digests: &[u64],
            _n_old: u32,
            _n_new: u32,
        ) -> Result<MigrationOutcome> {
            bail!("pjrt feature disabled")
        }

        /// Unreachable in the stub (no instance can be constructed).
        pub fn histogram(&self, _digests: &[u64], _n: u32) -> Result<Vec<u64>> {
            bail!("pjrt feature disabled")
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PlacementRuntime;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::BTreeMap;
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};

    use super::{parse_batch, Manifest, MigrationOutcome};

    struct SizedExe {
        batch: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    /// Compiled placement artifacts on a PJRT CPU client.
    pub struct PlacementRuntime {
        _client: xla::PjRtClient,
        lookups: Vec<SizedExe>,
        migrates: Vec<SizedExe>,
        hist: Option<SizedExe>,
        /// ω baked into the artifacts.
        pub omega: u32,
    }

    // SAFETY: the `xla` crate's handles hold `Rc`s and raw PJRT pointers, so
    // the compiler cannot derive Send.  Every `Rc` involved (client + the
    // client handles inside each executable) is created inside `load` and
    // confined to this struct; the coordinator serializes all access behind a
    // `Mutex` (see `router::Router::bulk`), so reference counts are never
    // touched from two threads at once, and the underlying PJRT C++ objects
    // are themselves thread-safe.
    unsafe impl Send for PlacementRuntime {}

    impl PlacementRuntime {
        /// Load and compile every artifact listed in `<dir>/manifest.txt`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref();
            let manifest_path = dir.join("manifest.txt");
            let manifest = Manifest::parse(
                &std::fs::read_to_string(&manifest_path)
                    .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?,
            )?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;

            let mut lookups: BTreeMap<usize, xla::PjRtLoadedExecutable> = BTreeMap::new();
            let mut migrates: BTreeMap<usize, xla::PjRtLoadedExecutable> = BTreeMap::new();
            let mut hist = None;
            for (name, file) in &manifest.artifacts {
                let path = dir.join(file);
                let compile = || -> Result<xla::PjRtLoadedExecutable> {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                    )
                    .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))
                };
                if let Some(b) = parse_batch(name, "lookup_b") {
                    lookups.insert(b, compile()?);
                } else if let Some(b) = parse_batch(name, "migrate_b") {
                    migrates.insert(b, compile()?);
                } else if let Some(b) = parse_batch(name, "hist_b") {
                    hist = Some(SizedExe { batch: b, exe: compile()? });
                }
            }
            if lookups.is_empty() {
                bail!("no lookup artifacts in {manifest_path:?}");
            }
            Ok(Self {
                _client: client,
                lookups: lookups.into_iter().map(|(batch, exe)| SizedExe { batch, exe }).collect(),
                migrates: migrates.into_iter().map(|(batch, exe)| SizedExe { batch, exe }).collect(),
                hist,
                omega: manifest.omega,
            })
        }

        /// Pick the smallest executable whose batch covers `len`, defaulting to
        /// the largest available (caller chunks by that size).
        fn pick(exes: &[SizedExe], len: usize) -> &SizedExe {
            exes.iter().find(|e| e.batch >= len).unwrap_or_else(|| exes.last().unwrap())
        }

        /// Bulk BinomialHash placement of `digests` over `n` buckets.
        ///
        /// Chunks by artifact batch size, zero-padding the tail; results are
        /// bit-identical to `algorithms::binomial::lookup` (golden-tested).
        pub fn lookup_batch(&self, digests: &[u64], n: u32) -> Result<Vec<u32>> {
            let mut out = Vec::with_capacity(digests.len());
            let mut rest = digests;
            while !rest.is_empty() {
                let sized = Self::pick(&self.lookups, rest.len());
                let take = rest.len().min(sized.batch);
                let (chunk, tail) = rest.split_at(take);
                out.extend_from_slice(&self.run_lookup(sized, chunk, n)?);
                rest = tail;
            }
            Ok(out)
        }

        fn run_lookup(&self, sized: &SizedExe, chunk: &[u64], n: u32) -> Result<Vec<u32>> {
            let padded;
            let input: &[u64] = if chunk.len() == sized.batch {
                chunk
            } else {
                let mut p = chunk.to_vec();
                p.resize(sized.batch, 0);
                padded = p;
                &padded
            };
            let d = xla::Literal::vec1(input);
            let n_lit = xla::Literal::scalar(n as u64);
            let result = sized
                .exe
                .execute::<xla::Literal>(&[d, n_lit])
                .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("sync: {e}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
            let mut v: Vec<u32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e}"))?;
            v.truncate(chunk.len());
            Ok(v)
        }

        /// Bulk migration plan: placement under `n_old` and `n_new` plus the
        /// moved mask and count.
        pub fn migration_plan(
            &self,
            digests: &[u64],
            n_old: u32,
            n_new: u32,
        ) -> Result<MigrationOutcome> {
            if self.migrates.is_empty() {
                bail!("no migrate artifacts loaded");
            }
            let mut outcome = MigrationOutcome {
                old: Vec::with_capacity(digests.len()),
                new: Vec::with_capacity(digests.len()),
                moved: Vec::with_capacity(digests.len()),
                moved_count: 0,
            };
            let mut rest = digests;
            while !rest.is_empty() {
                let sized = Self::pick(&self.migrates, rest.len());
                let take = rest.len().min(sized.batch);
                let (chunk, tail) = rest.split_at(take);

                let padded;
                let input: &[u64] = if chunk.len() == sized.batch {
                    chunk
                } else {
                    let mut p = chunk.to_vec();
                    p.resize(sized.batch, 0);
                    padded = p;
                    &padded
                };
                let d = xla::Literal::vec1(input);
                let result = sized
                    .exe
                    .execute::<xla::Literal>(&[
                        d,
                        xla::Literal::scalar(n_old as u64),
                        xla::Literal::scalar(n_new as u64),
                    ])
                    .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("sync: {e}"))?;
                let (old_l, new_l, moved_l, _count_l) =
                    result.to_tuple4().map_err(|e| anyhow!("untuple4: {e}"))?;
                let mut old: Vec<u32> = old_l.to_vec().map_err(|e| anyhow!("old: {e}"))?;
                let mut new: Vec<u32> = new_l.to_vec().map_err(|e| anyhow!("new: {e}"))?;
                let mut moved: Vec<u8> = moved_l.to_vec().map_err(|e| anyhow!("moved: {e}"))?;
                old.truncate(chunk.len());
                new.truncate(chunk.len());
                moved.truncate(chunk.len());
                // The on-device count includes zero-pad lanes; recompute over
                // the real lanes (cheap vector sum).
                outcome.moved_count += moved.iter().map(|&m| m as u64).sum::<u64>();
                outcome.old.extend_from_slice(&old);
                outcome.new.extend_from_slice(&new);
                outcome.moved.extend_from_slice(&moved);
                rest = tail;
            }
            Ok(outcome)
        }

        /// Per-bucket key counts over `n ≤ 1024` buckets (telemetry offload).
        pub fn histogram(&self, digests: &[u64], n: u32) -> Result<Vec<u64>> {
            let sized = self.hist.as_ref().ok_or_else(|| anyhow!("no hist artifact loaded"))?;
            let mut counts = vec![0u64; 1024];
            for chunk in digests.chunks(sized.batch) {
                let padded;
                let input: &[u64] = if chunk.len() == sized.batch {
                    chunk
                } else {
                    let mut p = chunk.to_vec();
                    p.resize(sized.batch, 0);
                    padded = p;
                    &padded
                };
                let result = sized
                    .exe
                    .execute::<xla::Literal>(&[
                        xla::Literal::vec1(input),
                        xla::Literal::scalar(n as u64),
                    ])
                    .map_err(|e| anyhow!("execute: {e}"))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| anyhow!("sync: {e}"))?;
                let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
                let v: Vec<u64> = out.to_vec().map_err(|e| anyhow!("to_vec: {e}"))?;
                for (c, x) in counts.iter_mut().zip(&v) {
                    *c += x;
                }
                if chunk.len() != sized.batch {
                    // Remove the zero-pad lanes' contribution exactly: digest 0
                    // is deterministic, so its bucket is known.
                    let pad = (sized.batch - chunk.len()) as u64;
                    let pad_bucket = crate::algorithms::binomial::lookup(0, n, self.omega);
                    counts[pad_bucket as usize] -= pad;
                }
            }
            counts.truncate(n.max(1) as usize);
            Ok(counts)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PlacementRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime integration tests live in rust/tests/ (they need built
    // artifacts and the `pjrt` feature). Here: manifest parsing only.
    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "# comment\nomega 6\nartifact lookup_b4096 lookup_b4096.hlo.txt\n\n\
             artifact migrate_b4096 migrate_b4096.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(m.omega, 6);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(parse_batch(&m.artifacts[0].0, "lookup_b"), Some(4096));
    }

    #[test]
    fn manifest_requires_omega() {
        assert!(Manifest::parse("artifact a b\n").is_err());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("omega 6\nwat is this\n").is_err());
    }

    #[test]
    fn parse_batch_rejects_other_prefixes() {
        assert_eq!(parse_batch("migrate_b65536", "lookup_b"), None);
        assert_eq!(parse_batch("lookup_b65536", "lookup_b"), Some(65536));
        assert_eq!(parse_batch("lookup_bXYZ", "lookup_b"), None);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_errors_with_guidance() {
        let err = PlacementRuntime::load("artifacts").unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
