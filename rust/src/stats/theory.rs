//! Closed-form expressions from the paper's §5.4 (Eqs. 1–6), used to
//! validate measurements against theory in the `bench_figs eq3` / `eq6`
//! harnesses and in property tests.

use crate::hashing::next_pow2;

/// `P(M ≤ b < n)` — Eq. (1): probability a key lands on the lowest level.
pub fn p_lowest_level(n: u32, omega: u32) -> f64 {
    assert!(n > 1);
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    let n = n as f64;
    (n - m) / n * (1.0 - ((e - n) / e).powi(omega as i32))
}

/// Expected keys per lowest-level bucket — Eq. (2), for `k` total keys.
pub fn expected_lowest_level_load(n: u32, omega: u32, k: u64) -> f64 {
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    p_lowest_level(n, omega) / (n as f64 - m) * k as f64
}

/// Expected keys per minor-tree bucket (the `K` of §5.4).
pub fn expected_minor_tree_load(n: u32, omega: u32, k: u64) -> f64 {
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    (1.0 - p_lowest_level(n, omega)) / m * k as f64
}

/// Relative imbalance `(K − K′)/(k/n)` — Eq. (3).  Independent of `k`.
pub fn relative_imbalance(n: u32, omega: u32) -> f64 {
    assert!(n > 1);
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    let nm = (n as f64 - m) / m;
    (1.0 / 2f64.powi(omega as i32)) * (1.0 + nm) * (1.0 - nm).powi(omega as i32)
}

/// Upper bound of Eq. (3) over `n ∈ [M, 2M)`: `2^{-ω}`, attained at n = M.
pub fn relative_imbalance_bound(omega: u32) -> f64 {
    1.0 / 2f64.powi(omega as i32)
}

/// Standard deviation of per-bucket load — Eq. (5), for `k` total keys.
pub fn stddev(n: u32, omega: u32, k: u64) -> f64 {
    assert!(n > 1);
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    let nf = n as f64;
    let kf = k as f64;
    kf / nf * ((nf - m) / m * ((2.0 * m - nf) / (2.0 * m)).powi(omega as i32)).sqrt()
}

/// Structural per-bucket stddev *re-derived* from Eqs. (1)/(2)/(4).
///
/// The paper's printed Eq. (5) places the `^ω` factor inside the square
/// root; deriving σ directly from K/K′ and the Eq. (4) variance gives the
/// factor *outside*:
/// `σ = (k/n) · sqrt((n−M)/M) · ((2M−n)/(2M))^ω` — strictly below the
/// printed form on (M, 2M), so Eq. (6) remains a valid upper bound.  The
/// empirical harness (`bench_figs eq6`) confirms measurements track this
/// form (plus multinomial sampling noise) rather than the printed one.
pub fn stddev_structural(n: u32, omega: u32, k: u64) -> f64 {
    assert!(n > 1);
    let e = next_pow2(n as u64) as f64;
    let m = e / 2.0;
    let nf = n as f64;
    let kf = k as f64;
    kf / nf * ((nf - m) / m).sqrt() * ((2.0 * m - nf) / (2.0 * m)).powi(omega as i32)
}

/// Expected *measured* stddev at load `q = k/n`: structural imbalance plus
/// multinomial sampling noise (`Var ≈ q(1−1/n)` per bucket).
pub fn stddev_expected_measured(n: u32, omega: u32, q: f64) -> f64 {
    let s = stddev_structural(n, omega, (q * n as f64) as u64);
    (s * s + q * (1.0 - 1.0 / n as f64)).sqrt()
}

/// Maximum of Eq. (5) over `n` at fixed load `q = k/n` — Eq. (6).
pub fn stddev_max(omega: u32, q: f64) -> f64 {
    let w = omega as f64;
    q * (1.0 / (1.0 + w) * (w / (2.0 * (1.0 + w))).powf(w)).sqrt()
}

/// The `n` (as a fraction of `M`) that attains Eq. (6): `(2+ω)/(1+ω)·M`.
pub fn stddev_argmax(omega: u32, m: u32) -> u32 {
    (((2 + omega) as f64 / (1 + omega) as f64) * m as f64).round() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_matches_direct_probability_algebra() {
        // Cross-check Eq. (3) against K and K' computed from Eq. (1)/(2).
        for &(n, omega) in &[(11u32, 6u32), (24, 4), (33, 2), (9, 1), (48, 8)] {
            let k = 1_000_000u64;
            let k_level = expected_lowest_level_load(n, omega, k);
            let k_minor = expected_minor_tree_load(n, omega, k);
            let gap = (k_minor - k_level) / (k as f64 / n as f64);
            let closed = relative_imbalance(n, omega);
            assert!((gap - closed).abs() < 1e-9, "n={n} ω={omega}: {gap} vs {closed}");
        }
    }

    #[test]
    fn eq3_bound_attained_just_above_m() {
        // The bound 2^-ω is the supremum as n → M⁺.
        for omega in 1..=8u32 {
            let m = 64u32;
            let at_m1 = relative_imbalance(m + 1, omega);
            let bound = relative_imbalance_bound(omega);
            assert!(at_m1 <= bound + 1e-12);
            assert!(at_m1 > bound * 0.8, "ω={omega}: {at_m1} vs bound {bound}");
            // Monotonically decreasing in n on (M, 2M).
            assert!(relative_imbalance(m + 20, omega) < at_m1);
        }
    }

    #[test]
    fn eq6_value_from_paper() {
        // §5.4: σ_max ≈ 0.045·q for ω = 5.
        let q = 1000.0;
        let s = stddev_max(5, q);
        assert!((s / q - 0.045).abs() < 0.002, "σ_max/q = {}", s / q);
    }

    #[test]
    fn eq6_decreasing_in_omega() {
        let q = 1000.0;
        let mut prev = f64::MAX;
        for omega in 1..=10 {
            let s = stddev_max(omega, q);
            assert!(s < prev);
            prev = s;
        }
    }

    #[test]
    fn eq5_peaks_at_argmax() {
        let omega = 5u32;
        let m = 512u32;
        let q = 1000u64;
        let peak_n = stddev_argmax(omega, m);
        let at_peak = stddev(peak_n, omega, q * peak_n as u64);
        // Eq. 5 evaluated at neighbours must not exceed the peak.
        for dn in [-40i64, -10, 10, 40] {
            let n = (peak_n as i64 + dn) as u32;
            if n > m && (n as u64) < 2 * m as u64 {
                let s = stddev(n, omega, q * n as u64);
                assert!(s <= at_peak * 1.001, "n={n}: {s} > {at_peak}");
            }
        }
        // And the peak is below the Eq. 6 bound.
        assert!(at_peak <= stddev_max(omega, q as f64) * 1.01);
    }

    #[test]
    fn structural_stddev_matches_direct_eq4_computation() {
        // Build σ directly from K, K' (Eqs. 1/2) and Eq. 4, and compare to
        // the re-derived closed form.
        for &(n, omega) in &[(40u32, 5u32), (33, 6), (48, 3), (63, 2)] {
            let k = 1_000u64 * n as u64;
            let e = next_pow2(n as u64) as f64;
            let m = e / 2.0;
            let k_level = expected_lowest_level_load(n, omega, k);
            let k_minor = expected_minor_tree_load(n, omega, k);
            let mean = k as f64 / n as f64;
            let var = (m * (mean - k_minor).powi(2)
                + (n as f64 - m) * (k_level - mean).powi(2))
                / n as f64;
            let direct = var.sqrt();
            let closed = stddev_structural(n, omega, k);
            assert!(
                (direct - closed).abs() < 1e-9 * (1.0 + direct),
                "n={n} ω={omega}: direct {direct} vs closed {closed}"
            );
            // And the paper's printed Eq. 5 upper-bounds it on (M, 2M).
            assert!(closed <= stddev(n, omega, k) * (1.0 + 1e-12));
        }
    }

    #[test]
    fn p_lowest_level_sane() {
        // For n = E (power of two) the lowest level is the whole top half.
        let p = p_lowest_level(16, 6);
        assert!(p > 0.49 && p <= 0.5, "{p}");
        // Just above a power of two, the level holds a single bucket.
        let p = p_lowest_level(9, 6);
        assert!(p < 0.12, "{p}");
    }
}
