//! Balance statistics and the paper's §5.4 closed forms.
//!
//! Used by the Fig. 6/7/8 reproduction benches, the theory-validation
//! harness (Eq. 3 / Eq. 5 / Eq. 6), and the router's load telemetry.

pub mod theory;

/// Summary statistics of a per-bucket key-count histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BalanceStats {
    /// Number of buckets.
    pub n: usize,
    /// Total keys counted.
    pub total: u64,
    /// Mean keys per bucket (k/n).
    pub mean: f64,
    /// Population standard deviation of keys per bucket.
    pub stddev: f64,
    /// Minimum bucket load.
    pub min: u64,
    /// Maximum bucket load.
    pub max: u64,
}

impl BalanceStats {
    /// Compute stats from a histogram of per-bucket counts.
    ///
    /// # Panics
    /// Panics on an empty histogram.
    pub fn from_counts(counts: &[u64]) -> Self {
        assert!(!counts.is_empty());
        let n = counts.len();
        let total: u64 = counts.iter().sum();
        let mean = total as f64 / n as f64;
        let var = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n as f64;
        Self {
            n,
            total,
            mean,
            stddev: var.sqrt(),
            min: *counts.iter().min().unwrap(),
            max: *counts.iter().max().unwrap(),
        }
    }

    /// Relative standard deviation σ / mean (the paper's Fig. 7/8 metric).
    pub fn rel_stddev(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean
        }
    }

    /// Fig. 6 metric: relative difference of least/most loaded bucket
    /// vs. the mean, returned as `(min_rel, max_rel)` where
    /// `min_rel = (mean − min)/mean` and `max_rel = (max − mean)/mean`.
    pub fn min_max_relative(&self) -> (f64, f64) {
        if self.mean == 0.0 {
            return (0.0, 0.0);
        }
        (
            (self.mean - self.min as f64) / self.mean,
            (self.max as f64 - self.mean) / self.mean,
        )
    }
}

/// Build a per-bucket histogram by running `lookup` over `k` digests drawn
/// from the given deterministic stream.
pub fn histogram<F: Fn(u64) -> u32>(
    lookup: F,
    n: u32,
    keys: impl Iterator<Item = u64>,
) -> Vec<u64> {
    let mut counts = vec![0u64; n as usize];
    for d in keys {
        let b = lookup(d);
        debug_assert!(b < n, "bucket {b} out of range [0, {n})");
        counts[b as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_flat_histogram() {
        let s = BalanceStats::from_counts(&[100, 100, 100, 100]);
        assert_eq!(s.mean, 100.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min_max_relative(), (0.0, 0.0));
        assert_eq!(s.rel_stddev(), 0.0);
    }

    #[test]
    fn stats_skewed_histogram() {
        let s = BalanceStats::from_counts(&[50, 150]);
        assert_eq!(s.mean, 100.0);
        assert_eq!(s.stddev, 50.0);
        assert_eq!(s.min_max_relative(), (0.5, 0.5));
        assert_eq!(s.total, 200);
    }

    #[test]
    fn histogram_counts_everything() {
        let counts = histogram(|d| (d % 7) as u32, 7, 0..70_000u64);
        assert_eq!(counts.iter().sum::<u64>(), 70_000);
        assert!(counts.iter().all(|&c| c == 10_000));
    }
}
