//! Router-side hot-key cache: a fixed-capacity, striped LRU in front
//! of shard I/O.
//!
//! Zipfian traffic concentrates a large share of GETs on a handful of
//! keys; under 2:1 weights those keys also concentrate on the heavy
//! shards.  Values are already `Arc<[u8]>` end to end, so a cache hit
//! is a linear probe plus a refcount bump — no copy, no allocation —
//! which is what lets `zero_alloc.rs` keep passing with the cache on
//! the hit path.
//!
//! # Invalidation rule
//!
//! The cache is *write-invalidated* and *epoch-cleared*:
//!
//! - `PUT`/`DEL` invalidate the exact key **after** the shard write
//!   completes (see [`HotCache::invalidate`]).
//! - Every `Router::publish` — scale up/down, migration settle, FAIL,
//!   RESTORE, weight change — clears the whole cache before the new
//!   snapshot is visible, so a cached value never serves across an
//!   epoch publish.
//!
//! # Stale-fill race
//!
//! A GET that misses reads the shard and then fills the cache.  If a
//! concurrent write or epoch publish lands between the shard read and
//! the fill, the fill would resurrect the stale value.  Each stripe
//! therefore carries a generation counter, bumped by `invalidate` and
//! `clear`: the GET records the generation *before* shard I/O
//! ([`HotCache::generation`]) and [`HotCache::fill`] drops the fill if
//! the generation moved.  The check runs under the stripe lock, so a
//! fill either predates the invalidation entirely or observes its
//! bump.

use crate::sync::{Arc, Mutex};

/// Lock stripes; power of two so stripe selection is one mask.
const STRIPES: usize = 8;

struct Entry {
    digest: u64,
    key: String,
    value: Arc<[u8]>,
    /// Last-touched stamp from the stripe's tick counter; the eviction
    /// victim is the entry with the smallest stamp (LRU).
    touched: u64,
}

struct Stripe {
    entries: Vec<Entry>,
    /// Monotone access clock for LRU stamps.
    tick: u64,
    /// Bumped by `invalidate`/`clear`; guards against stale fills.
    generation: u64,
}

/// Fixed-capacity hot-key LRU, striped by digest.
///
/// Capacity is split evenly across stripes, so the effective total is
/// `per_stripe * STRIPES` (rounded up from the configured
/// `hot_cache_keys`).  Lookups, fills, and invalidations take one
/// stripe lock; `clear` walks all stripes.
pub struct HotCache {
    stripes: Vec<Mutex<Stripe>>,
    per_stripe: usize,
}

impl HotCache {
    /// Build a cache holding at least `capacity` keys, or `None` when
    /// `capacity` is zero (cache disabled).
    pub fn new(capacity: usize) -> Option<HotCache> {
        if capacity == 0 {
            return None;
        }
        let per_stripe = capacity.div_ceil(STRIPES);
        let stripes = (0..STRIPES)
            .map(|_| {
                Mutex::new(Stripe {
                    entries: Vec::with_capacity(per_stripe),
                    tick: 0,
                    generation: 0,
                })
            })
            .collect();
        Some(HotCache { stripes, per_stripe })
    }

    /// Total keys the cache can hold.
    pub fn capacity(&self) -> usize {
        self.per_stripe * STRIPES
    }

    fn stripe(&self, digest: u64) -> &Mutex<Stripe> {
        &self.stripes[digest as usize & (STRIPES - 1)]
    }

    /// Look up `key`; a hit bumps the LRU stamp and clones the `Arc`.
    pub fn get(&self, digest: u64, key: &str) -> Option<Arc<[u8]>> {
        let mut s = self.stripe(digest).lock().unwrap();
        s.tick += 1;
        let tick = s.tick;
        let e = s
            .entries
            .iter_mut()
            .find(|e| e.digest == digest && e.key == key)?;
        e.touched = tick;
        Some(Arc::clone(&e.value))
    }

    /// Stripe generation for `digest`, read *before* shard I/O; pass
    /// it back to [`fill`](Self::fill) to detect concurrent writes.
    pub fn generation(&self, digest: u64) -> u64 {
        self.stripe(digest).lock().unwrap().generation
    }

    /// Insert `key` after a cache miss.  `gen` must be the value
    /// [`generation`](Self::generation) returned before the shard
    /// read; if the stripe moved on since, the fill is dropped.
    /// Returns `true` when a victim was evicted to make room.
    pub fn fill(&self, digest: u64, key: &str, value: &Arc<[u8]>, gen: u64) -> bool {
        let mut s = self.stripe(digest).lock().unwrap();
        if s.generation != gen {
            return false; // a write or epoch publish raced the shard read
        }
        s.tick += 1;
        let tick = s.tick;
        if let Some(e) = s
            .entries
            .iter_mut()
            .find(|e| e.digest == digest && e.key == key)
        {
            e.value = Arc::clone(value);
            e.touched = tick;
            return false;
        }
        let mut evicted = false;
        if s.entries.len() >= self.per_stripe {
            let victim = s
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.touched)
                .map(|(i, _)| i)
                .expect("per_stripe >= 1, so a full stripe has a victim");
            s.entries.swap_remove(victim);
            evicted = true;
        }
        s.entries.push(Entry {
            digest,
            key: key.to_owned(),
            value: Arc::clone(value),
            touched: tick,
        });
        evicted
    }

    /// Drop `key` and bump the stripe generation (called after every
    /// PUT/DEL shard write).
    pub fn invalidate(&self, digest: u64, key: &str) {
        let mut s = self.stripe(digest).lock().unwrap();
        s.generation += 1;
        if let Some(i) = s
            .entries
            .iter()
            .position(|e| e.digest == digest && e.key == key)
        {
            s.entries.swap_remove(i);
        }
    }

    /// Drop everything and bump every stripe generation (called by
    /// `Router::publish` so nothing serves across an epoch).
    pub fn clear(&self) {
        for stripe in &self.stripes {
            let mut s = stripe.lock().unwrap();
            s.generation += 1;
            s.entries.clear();
        }
    }

    /// Cached entries across all stripes (tests/diagnostics).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().entries.len()).sum()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(s: &str) -> Arc<[u8]> {
        Arc::from(s.as_bytes())
    }

    /// Digests that all land in stripe 0 so LRU order is observable.
    fn d(i: u64) -> u64 {
        i * STRIPES as u64
    }

    #[test]
    fn miss_fill_hit_roundtrip() {
        let c = HotCache::new(64).unwrap();
        assert!(c.get(d(1), "a").is_none());
        let g = c.generation(d(1));
        assert!(!c.fill(d(1), "a", &val("alpha"), g));
        assert_eq!(c.get(d(1), "a").as_deref(), Some(b"alpha".as_ref()));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_capacity_disables_the_cache() {
        assert!(HotCache::new(0).is_none());
        // Tiny capacities round up to one key per stripe.
        assert_eq!(HotCache::new(1).unwrap().capacity(), STRIPES);
    }

    #[test]
    fn digest_match_still_compares_the_full_key() {
        let c = HotCache::new(64).unwrap();
        let g = c.generation(7);
        c.fill(7, "a", &val("alpha"), g);
        // Same digest, different key: a digest collision must miss.
        assert!(c.get(7, "b").is_none());
        assert_eq!(c.get(7, "a").as_deref(), Some(b"alpha".as_ref()));
    }

    #[test]
    fn lru_evicts_the_coldest_entry() {
        let c = HotCache::new(STRIPES * 2).unwrap(); // 2 per stripe
        let g = c.generation(0);
        c.fill(d(1), "k1", &val("v1"), g);
        c.fill(d(2), "k2", &val("v2"), g);
        // Touch k1 so k2 becomes the LRU victim.
        assert!(c.get(d(1), "k1").is_some());
        assert!(c.fill(d(3), "k3", &val("v3"), g), "full stripe evicts");
        assert!(c.get(d(2), "k2").is_none(), "cold entry evicted");
        assert!(c.get(d(1), "k1").is_some());
        assert!(c.get(d(3), "k3").is_some());
    }

    #[test]
    fn fill_overwrites_in_place_without_eviction() {
        let c = HotCache::new(STRIPES).unwrap(); // 1 per stripe
        let g = c.generation(d(1));
        c.fill(d(1), "a", &val("old"), g);
        assert!(!c.fill(d(1), "a", &val("new"), c.generation(d(1))));
        assert_eq!(c.get(d(1), "a").as_deref(), Some(b"new".as_ref()));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn invalidate_drops_the_key_and_blocks_stale_fills() {
        let c = HotCache::new(64).unwrap();
        let g = c.generation(d(1));
        c.fill(d(1), "a", &val("alpha"), g);
        // A GET records the generation, reads the shard...
        let stale_gen = c.generation(d(1));
        // ...then a PUT lands and invalidates.
        c.invalidate(d(1), "a");
        assert!(c.get(d(1), "a").is_none());
        // The in-flight GET's fill must be dropped, not resurrect "alpha".
        assert!(!c.fill(d(1), "a", &val("alpha"), stale_gen));
        assert!(c.get(d(1), "a").is_none());
    }

    #[test]
    fn clear_empties_everything_and_blocks_stale_fills() {
        let c = HotCache::new(64).unwrap();
        for i in 0..10u64 {
            let g = c.generation(i);
            c.fill(i, "k", &val("v"), g);
        }
        let stale_gen = c.generation(3);
        c.clear();
        assert!(c.is_empty());
        assert!(!c.fill(3, "k", &val("v"), stale_gen));
        assert!(c.is_empty(), "post-clear fill with a stale epoch dropped");
    }

    #[test]
    fn hit_is_a_refcount_bump_on_the_same_allocation() {
        let c = HotCache::new(64).unwrap();
        let v = val("shared");
        let g = c.generation(d(1));
        c.fill(d(1), "a", &v, g);
        let hit = c.get(d(1), "a").unwrap();
        assert!(Arc::ptr_eq(&v, &hit));
    }
}
