//! Request router — the coordinator's front-end.
//!
//! Accepts client connections speaking the wire protocol, places each key
//! with the cluster's consistent-hashing engine (constant-time BinomialHash
//! by default), and forwards to the owning shard.
//!
//! ## Lock-free, allocation-free data path
//!
//! BinomialHash decides placement in nanoseconds with 8 bytes of state;
//! the routing around it is built to the same budget.  In steady state a
//! local GET/PUT/DEL through [`Router::handle_ref`] performs **zero heap
//! allocations** (pinned by `rust/tests/zero_alloc.rs`) and acquires **no
//! lock** for snapshot access:
//!
//! * The current [`PlacementSnapshot`] is published through a hand-rolled
//!   atomic `Arc` swap: an `AtomicPtr` whose pointer owns one strong
//!   count.  [`Router::snapshot`] is one atomic pointer load plus a
//!   refcount bump, guarded by a generation-validated reader gate: a
//!   reader registers in the gate slot of the current generation's
//!   parity, re-checks the generation, and only then touches the
//!   pointer (retrying if a publish raced in).  A publisher swaps the
//!   pointer, advances the generation, and drains the *superseded*
//!   parity slot to zero before releasing the superseded snapshot's
//!   stored count — that closes the classic load-then-bump race (a
//!   reader holding the superseded raw pointer without having bumped its
//!   count yet).  Readers arriving during the drain validate against the
//!   new generation and land in the other slot, so publication cannot be
//!   starved.
//! * Requests are parsed into borrowed [`RequestRef`]s from a reusable
//!   per-connection [`proto::RecvBuf`] — no per-line `String`, no key
//!   copies — and responses are coalesced per pipelined burst (one flush
//!   per drained read buffer, not per response).
//! * Values are `Arc<[u8]>` end to end: a GET bumps a refcount, a PUT
//!   moves the parsed buffer into the shard map, and the key digest the
//!   router computes for placement is threaded into local shard calls so
//!   the stripe map never re-hashes the key.
//!
//! Reclamation keeps the pre-existing protocol: superseded snapshots are
//! quiesced with `Arc::strong_count` (now with bounded exponential
//! backoff instead of a pure `yield_now` spin) before migration batches
//! delete source copies.
//!
//! ## Concurrency model: epoch snapshots + incremental migration
//!
//! Topology changes are serialized by an admin mutex and proceed in three
//! phases, none of which blocks GET/PUT/DEL:
//!
//! 1. **Publish** a new epoch whose snapshot routes with the *new* engine
//!    — a [`ConsistentHasher::fork`](crate::algorithms::ConsistentHasher::fork)
//!    of the current one with the bucket added/removed — and carries a
//!    [`MigrationOrigin`] (a fork of the old engine), enabling dual-read:
//!    a GET that misses on a key's new owner retries the old owner.  PUTs
//!    land on the new owner and retire the old copy; DELs tombstone the
//!    new owner (`DELTOMB`) and remove the old copy.
//! 2. **Quiesce** the superseded snapshot (wait for its in-flight readers
//!    to drain; readers hold a snapshot only for one request, so this
//!    settles in microseconds), then run the incremental migration:
//!    stream every source shard stripe-by-stripe and move keys in bounded
//!    batches ([`rebalance::migrate_streaming`]), optionally planning
//!    batches on the PJRT bulk artifacts.
//! 3. **Settle**: publish the same epoch without the origin (and, on
//!    scale-down, without the retiring shard handle), then purge the
//!    migration tombstones.
//!
//! Snapshot hold-time contract: the data path holds a snapshot for one
//! shard call.  Aggregations that fan out over possibly-remote shards
//! (`COUNT`, [`Router::shard_count`]) clone the shard handles and drop
//! the snapshot *before* any I/O, so a slow shard can never stall a
//! concurrent scale op at its quiesce barrier.
//!
//! Because each epoch's engine is forked from the previous one, every
//! registered engine scales; engines without exact minimal disruption
//! (maglev, the modulo anti-baseline) scan every shard on scale-down
//! ([`ConsistentHasher::minimal_disruption`](crate::algorithms::ConsistentHasher::minimal_disruption)).
//! The copy step (`PUTNX`) cannot clobber a newer client write, and the
//! `DELTOMB` tombstone bars it from resurrecting a key whose DEL raced
//! the migration sweep.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::cluster::{Cluster, EventKind, MigrationOrigin, PlacementSnapshot, TopologyEvent};
use crate::metrics::RouterMetrics;
use crate::proto::{self, Request, RequestRef, Response, Value};
use crate::rebalance::{self, MigrationStats, PlanPath};
use crate::runtime::PlacementRuntime;
use crate::shard::{Shard, ShardClient};

/// Shard factory used on scale-up.
pub type ShardSpawner = Box<dyn Fn(u32) -> ShardClient + Send + Sync>;

/// Keys per migration batch: small enough that a batch is visible to
/// readers almost immediately, large enough to amortize planning.
const MIGRATION_BATCH: usize = 512;

// The atomic snapshot swap shares `PlacementSnapshot` across threads
// through a raw pointer — outside the compiler's auto-trait reasoning —
// so pin the bound it would otherwise infer from `Arc` alone.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<PlacementSnapshot>();
};

/// The router: published placement snapshot + metrics + optional XLA bulk
/// runtime.
pub struct Router {
    /// Current snapshot, published as a raw `Arc` pointer that owns one
    /// strong count; swapped atomically on each migration phase.  Never
    /// mutated through — only loaded (data path) and swapped (publish).
    current: AtomicPtr<PlacementSnapshot>,
    /// Publication generation; bumped by `publish` after each swap.
    /// Readers validate it between registering in a gate slot and
    /// touching the pointer, so a reader that raced a publish retries
    /// instead of bumping a possibly-reclaimed snapshot.
    generation: AtomicU64,
    /// Readers currently inside the load-and-bump window, slotted by
    /// generation parity.  `publish` bumps `generation` and then drains
    /// the *superseded* parity slot to zero; readers validated against
    /// the new generation live in the other slot, so the drain waits only
    /// for the finite set of pre-swap readers and cannot be starved.
    gate: [AtomicU64; 2],
    /// Serializes topology changes and owns the event log. The data path
    /// never touches this; `SCALEUP`/`SCALEDOWN` take it with `try_lock`
    /// and answer `ERR MIGRATING` when a change is already in flight.
    admin: Mutex<Vec<TopologyEvent>>,
    /// Request/latency counters.
    pub metrics: RouterMetrics,
    /// Bulk placement runtime for rebalance planning (None = Rust path).
    /// Serialized behind a mutex — see the Send safety note in `runtime`.
    bulk: Option<Mutex<PlacementRuntime>>,
    spawn_shard: ShardSpawner,
}

impl Router {
    /// Router over an existing cluster, spawning in-process shards on
    /// scale-up.
    pub fn new(cluster: Cluster) -> Arc<Self> {
        Self::with_options(cluster, Box::new(|id| ShardClient::Local(Shard::new(id))), None)
    }

    /// Router with a custom shard factory and/or bulk runtime.
    pub fn with_options(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
    ) -> Arc<Self> {
        let (snapshot, events) = cluster.into_snapshot();
        Arc::new(Self {
            current: AtomicPtr::new(Arc::into_raw(Arc::new(snapshot)).cast_mut()),
            generation: AtomicU64::new(0),
            gate: [AtomicU64::new(0), AtomicU64::new(0)],
            admin: Mutex::new(events),
            metrics: RouterMetrics::new(),
            bulk: bulk.map(Mutex::new),
            spawn_shard,
        })
    }

    /// The current placement snapshot: one atomic pointer load plus a
    /// refcount bump — no lock, no allocation, never blocks on a
    /// migration.
    ///
    /// Hold-time contract: drop the handle promptly (one request's worth
    /// of work). Scale operations wait for superseded snapshots' readers
    /// to drain before deleting migrated source copies, so a handle held
    /// across blocking work stalls — not corrupts — topology changes.
    pub fn snapshot(&self) -> Arc<PlacementSnapshot> {
        // Generation-validated gate (SeqCst throughout): register in the
        // current generation's slot, then re-check the generation.  If a
        // publish raced in between, this slot may be (or already have
        // been) drained — deregister and retry against the new
        // generation.  A validated reader is provably covered: its slot
        // increment is globally ordered before the publisher's generation
        // bump (the validation load still saw the old generation), hence
        // before the publisher's drain of that slot.
        loop {
            let gen = self.generation.load(Ordering::SeqCst);
            let slot = &self.gate[(gen & 1) as usize];
            slot.fetch_add(1, Ordering::SeqCst);
            if self.generation.load(Ordering::SeqCst) == gen {
                let ptr = self.current.load(Ordering::SeqCst);
                // SAFETY: `ptr` came from `Arc::into_raw` and its strong
                // count cannot reach zero here: the store itself owns one
                // count, and `publish` releases it only after draining
                // this generation's slot — which this reader occupies.
                let snap = unsafe {
                    Arc::increment_strong_count(ptr);
                    Arc::from_raw(ptr.cast_const())
                };
                slot.fetch_sub(1, Ordering::SeqCst);
                return snap;
            }
            slot.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Publish a new snapshot: swap the pointer, advance the generation,
    /// drain the superseded generation's reader slot, then release the
    /// superseded snapshot's stored count (in-flight readers keep it
    /// alive via their own counts until they drop).
    ///
    /// Callers are serialized by the admin mutex, so at most one drain is
    /// in flight and the two gate slots strictly alternate.
    fn publish(&self, snapshot: PlacementSnapshot) {
        let new_ptr = Arc::into_raw(Arc::new(snapshot)).cast_mut();
        let old_ptr = self.current.swap(new_ptr, Ordering::SeqCst);
        let gen = self.generation.fetch_add(1, Ordering::SeqCst);
        // Drain readers validated against the superseded generation: a
        // finite set (new readers land in the other slot; a reader that
        // raced us blips this slot once, fails validation, and leaves),
        // each inside a nanoseconds-long load-and-bump window.
        let slot = &self.gate[(gen & 1) as usize];
        let mut spins = 0u32;
        while slot.load(Ordering::SeqCst) != 0 {
            if spins < 64 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
            spins += 1;
        }
        // SAFETY: `old_ptr` came from `Arc::into_raw` in `with_options`
        // or a previous `publish`; reclaiming the store's single count.
        // Every reader that loaded `old_ptr` has already bumped its own
        // strong count (it was validated, so the drain waited for it).
        unsafe { drop(Arc::from_raw(old_ptr.cast_const())) };
    }

    /// Wait until no in-flight request still routes with `snap` (all
    /// reader clones dropped). After a publish no new reader can acquire
    /// it, and readers hold a snapshot only for the duration of one shard
    /// call, so this normally settles in microseconds; the backoff ramps
    /// from busy-spin through `yield_now` to bounded sleeps so a reader
    /// stuck behind a slow remote shard doesn't burn a core here.
    fn quiesce(snap: &Arc<PlacementSnapshot>) {
        let mut round = 0u32;
        while Arc::strong_count(snap) > 1 {
            match round {
                0..=15 => std::hint::spin_loop(),
                16..=63 => std::thread::yield_now(),
                _ => {
                    // 50µs, 100µs, ... capped at 3.2ms per wait.
                    let exp = (round - 64).min(6);
                    std::thread::sleep(Duration::from_micros(50u64 << exp));
                }
            }
            round = round.saturating_add(1);
        }
    }

    /// Current `(epoch, n, algorithm)`.
    pub fn topology(&self) -> (u64, u32, &'static str) {
        let snap = self.snapshot();
        (snap.epoch, snap.engine.len(), snap.engine.name())
    }

    /// Topology events recorded so far.
    pub fn events(&self) -> Vec<TopologyEvent> {
        self.admin.lock().unwrap().clone()
    }

    /// Key count on one shard (telemetry; used by examples/benches).
    pub fn shard_count(&self, bucket: u32) -> Result<u64> {
        // Clone the handle and drop the snapshot before the (possibly
        // remote, slow) COUNT round-trip — see the hold-time contract.
        let shard = {
            let snap = self.snapshot();
            ensure!((bucket as usize) < snap.shards.len(), "bucket {bucket} out of range");
            snap.shards[bucket as usize].clone()
        };
        shard.count()
    }

    /// Handle one data/admin request end-to-end (owned form; the server
    /// loop and the zero-allocation fast path go through
    /// [`handle_ref`](Self::handle_ref)).
    pub fn handle(&self, req: Request) -> Response {
        self.handle_ref(req.as_view())
    }

    /// Handle one data/admin request end-to-end without taking ownership
    /// of the key.  Steady-state GET/PUT/DEL through here is allocation-
    /// and lock-free (one atomic snapshot load, digest reuse in the local
    /// shard call, `Arc` value sharing).
    pub fn handle_ref(&self, req: RequestRef<'_>) -> Response {
        let start = Instant::now();
        let resp = match req {
            RequestRef::Get { key } => self.data_get(key),
            RequestRef::Put { key, value } => self.data_put(key, value),
            RequestRef::Del { key } => self.data_del(key),
            // COUNT sums every shard. The handles are cloned and the
            // snapshot dropped before any shard I/O so a slow shard
            // cannot stall a concurrent scale op's quiesce barrier.
            // Mid-migration a key sits on both owners between the copy
            // and the source delete, so the total can transiently
            // over-report by up to one batch.
            RequestRef::Count => {
                let shards = self.snapshot().shards.clone();
                let mut total = 0u64;
                let mut err = None;
                for s in &shards {
                    match s.count() {
                        Ok(x) => total += x,
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Response::Num(total),
                    Some(e) => Response::Err(e.to_string()),
                }
            }
            RequestRef::Stats => {
                let snap = self.snapshot();
                Response::Info(format!(
                    "epoch={} n={} algo={} state={} {}",
                    snap.epoch,
                    snap.engine.len(),
                    snap.engine.name(),
                    if snap.is_migrating() { "migrating" } else { "steady" },
                    self.metrics.summary()
                ))
            }
            RequestRef::Scan
            | RequestRef::ScanStripe { .. }
            | RequestRef::PutNx { .. }
            | RequestRef::DelTomb { .. }
            | RequestRef::PurgeTombs => Response::Err("shard-internal command".into()),
            RequestRef::ScaleUp => match self.scale_up() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            RequestRef::ScaleDown => match self.scale_down() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
        };
        if matches!(resp, Response::Err(_)) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.latency.record(start.elapsed());
        resp
    }

    /// Validate a key, count the op, and return its digest.
    fn admit(&self, key: &str, counter: &AtomicU64) -> Result<u64, Response> {
        if !proto::valid_key(key) {
            return Err(Response::Err(format!("invalid key {key:?}")));
        }
        counter.fetch_add(1, Ordering::Relaxed);
        Ok(crate::hashing::xxhash64(key.as_bytes(), 0))
    }

    fn data_get(&self, key: &str) -> Response {
        let digest = match self.admit(key, &self.metrics.gets) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration, the key may not have reached its new owner
            // yet: dual-read, new owner then old owner — and if both miss,
            // re-probe the new owner once.  Copies always land new-first
            // (PUTNX/PUT before the source DEL), so a key that vanished
            // from the old owner between our two probes is already
            // readable on the new one; the third probe closes that window.
            Some((_, old_shard)) => {
                match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                    Ok(Response::Nil) => {
                        self.metrics.dual_reads.fetch_add(1, Ordering::Relaxed);
                        match old_shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                            Ok(Response::Nil) => {
                                match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                                    Ok(resp) => resp,
                                    Err(e) => Response::Err(e.to_string()),
                                }
                            }
                            Ok(resp) => resp,
                            Err(e) => Response::Err(e.to_string()),
                        }
                    }
                    Ok(resp) => resp,
                    Err(e) => Response::Err(e.to_string()),
                }
            }
            None => match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    fn data_put(&self, key: &str, value: Value) -> Response {
        let digest = match self.admit(key, &self.metrics.puts) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration: write the new owner, then retire the old copy
            // so neither the migration sweep nor a dual-read can resurface
            // a stale value.  The old-copy delete is best-effort: once the
            // new owner holds the value, reads route there first and the
            // migration sweep (PUTNX) cannot clobber it, so a cleanup
            // failure must not turn a durable write into a client error.
            Some((_, old_shard)) => {
                let resp = match shard.call_ref(RequestRef::Put { key, value }, Some(digest)) {
                    Ok(resp) => resp,
                    Err(e) => return Response::Err(e.to_string()),
                };
                let _ = old_shard.call_ref(RequestRef::Del { key }, Some(digest));
                resp
            }
            None => match shard.call_ref(RequestRef::Put { key, value }, Some(digest)) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    fn data_del(&self, key: &str) -> Response {
        let digest = match self.admit(key, &self.metrics.dels) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration: the key may live on either owner — delete
            // both; it existed if either copy did.  The new-owner delete
            // leaves a tombstone so an in-flight migration copy (PUTNX)
            // of this key cannot resurrect it after the delete wins the
            // race; the tombstones are purged when the migration settles.
            Some((_, old_shard)) => {
                let new_r = shard.call_ref(RequestRef::DelTomb { key }, Some(digest));
                let old_r = old_shard.call_ref(RequestRef::Del { key }, Some(digest));
                match (new_r, old_r) {
                    (Ok(Response::Ok), Ok(_)) | (Ok(_), Ok(Response::Ok)) => Response::Ok,
                    (Ok(resp), Ok(_)) => resp,
                    (Err(e), _) | (_, Err(e)) => Response::Err(e.to_string()),
                }
            }
            None => match shard.call_ref(RequestRef::Del { key }, Some(digest)) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    /// Clear migration tombstones on every shard (idempotent; called once
    /// a migration settles, and defensively before a new one starts).
    fn purge_tombstones(shards: &[ShardClient]) -> Result<()> {
        for s in shards {
            s.purge_tombstones()?;
        }
        Ok(())
    }

    /// Add a shard and incrementally migrate exactly the keys that now
    /// belong to it, serving reads and writes throughout.  Returns the new
    /// cluster size.
    pub fn scale_up(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base.shards)?;
        let n_old = base.engine.len();
        let n_new = n_old + 1;
        // Fail fast — nothing is mutated or published for an engine at
        // its pre-allocated capacity (anchor's anchor set, dx's NSArray);
        // `add_bucket` would panic mid-change otherwise.
        if let Some(cap) = base.engine.max_buckets() {
            ensure!(
                n_new <= cap,
                "engine {:?} is at its capacity of {cap} buckets; cannot scale up",
                base.engine.name()
            );
        }
        // A fork of an engine with outstanding arbitrary removals would
        // not grow at the LIFO tail (or would panic in add_bucket);
        // reject before anything is mutated or published.
        ensure!(
            base.engine.lifo_ready(),
            "engine {:?} has outstanding arbitrary removals; restore failed buckets \
             before scaling",
            base.engine.name()
        );
        // The next epoch's engine is a fork of the live one with the new
        // bucket added; the origin keeps an unmodified fork for dual-read
        // and migration planning.  No engine is rebuilt from its name, so
        // stateful engines carry their full state across the change.
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let added = new_engine.add_bucket();
        // The new shard handle is pushed at index n_old, so the engine
        // must have grown at the LIFO tail.  An engine with outstanding
        // arbitrary removals (e.g. anchor restoring a failed bucket
        // instead) would route the "new" bucket to the wrong handle; the
        // mutated fork is discarded and nothing has been published.
        ensure!(
            added == n_old,
            "engine {:?} added bucket {added} instead of the LIFO tail {n_old} \
             (restore failed buckets before scaling)",
            base.engine.name()
        );

        let mut shards = base.shards.clone();
        let joining = (self.spawn_shard)(n_old);
        // A joining shard may be a reconnection to a remote process with
        // leftover state (e.g. retired earlier after a best-effort purge
        // failed); clear its tombstones before any migration copy can be
        // refused by them.  Failing here is still pre-publish.
        joining.purge_tombstones()?;
        shards.push(joining);
        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: shards.clone(),
            // Monotonicity: any old shard may hold keys that now belong to
            // the joining bucket, so all of them are migration sources.
            origin: Some(MigrationOrigin { engine: old_engine, sources: 0..n_old }),
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Joined(n_old),
            at: std::time::SystemTime::now(),
        });
        // No reader may still route with the pre-migration snapshot once
        // batches start deleting source copies (such a reader would have
        // no dual-read fallback); readers drain in microseconds.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
        });
        // Drain dual-read holders of the migrating snapshot before
        // returning, so every future topology change only ever has one
        // live predecessor to quiesce — after which no request can still
        // be writing migration tombstones, and they can be purged.  The
        // scale op has fully settled by now, so a transient purge failure
        // must not turn it into a client error: stale tombstones are
        // harmless until the next migration, and the next scale op
        // re-purges (and fails fast there) before publishing anything.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating.shards);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(n_new)
    }

    /// Remove the last shard after incrementally migrating its keys away,
    /// serving reads and writes throughout.  Returns the new cluster size.
    pub fn scale_down(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base.shards)?;
        let n_old = base.engine.len();
        ensure!(n_old > 1, "cannot scale below one shard");
        let n_new = n_old - 1;
        // As in scale_up: a degraded engine cannot shrink at the LIFO
        // tail (memento/dx panic in remove_bucket); reject up front.
        ensure!(
            base.engine.lifo_ready(),
            "engine {:?} has outstanding arbitrary removals; restore failed buckets \
             before scaling",
            base.engine.name()
        );
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let removed = new_engine.remove_bucket();
        // As in scale_up: the shard list drops index n_new, so the engine
        // must have shrunk at the LIFO tail (a discarded fork; nothing
        // published on error).
        ensure!(
            removed == n_new,
            "engine {:?} removed bucket {removed} instead of the LIFO tail {n_new} \
             (restore failed buckets before scaling)",
            base.engine.name()
        );
        // Minimal disruption: only the retiring shard's keys move, so it
        // is the sole migration source — a scale-down costs O(retiring
        // shard), not O(cluster keyset).  Engines without the exact
        // guarantee (maglev's table rebuild, modulo) also shuffle keys
        // between surviving shards, so every shard must be scanned.
        let sources = if base.engine.minimal_disruption() { n_new..n_old } else { 0..n_old };

        let epoch = base.epoch + 1;
        // The migrating snapshot routes with the new engine (never onto
        // the retiring shard) but keeps the full shard list so dual reads
        // still reach the retiring shard's keys.
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin: Some(MigrationOrigin { engine: old_engine, sources }),
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Left(n_new),
            at: std::time::SystemTime::now(),
        });
        let mut shards = base.shards.clone();
        // Same hazard as scale-up: a reader still routing with the old
        // snapshot would miss keys whose source copy a batch just deleted.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        // Settle: drop the retiring shard handle.
        shards.truncate(n_new as usize);
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
        });
        // As in scale_up: drain dual-read holders, then purge the
        // tombstones their DELs may have written (best-effort — the op
        // has settled; the next scale op re-purges before publishing).
        // The retiring shard is included: a remote process outlives its
        // handle and could rejoin a later epoch carrying stale tombstones.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating.shards);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(n_new)
    }

    /// Complete an interrupted migration: if a previous scale op failed
    /// mid-sweep (e.g. a remote shard hiccup) the migrating snapshot is
    /// still published — dual-read keeps every key serveable — but the
    /// topology never settled.  Re-running the sweep is idempotent (PUTNX
    /// copies, source deletes of already-moved keys are no-ops), after
    /// which the snapshot settles normally.  Without this, a retried scale
    /// op would build a fresh origin from the stuck topology and strand
    /// never-migrated keys outside both routes.
    fn resume_interrupted(
        &self,
        base: Arc<PlacementSnapshot>,
    ) -> Result<Arc<PlacementSnapshot>> {
        if !base.is_migrating() {
            return Ok(base);
        }
        self.run_migration(&base)?;
        let n = base.engine.len();
        let mut shards = base.shards.clone();
        shards.truncate(n as usize); // no-op for an interrupted scale-up
        self.publish(PlacementSnapshot {
            epoch: base.epoch,
            engine: base.engine.fork(),
            shards,
            origin: None,
        });
        Self::quiesce(&base);
        drop(base);
        Ok(self.snapshot())
    }

    /// Stream-migrate everything the snapshot's origin still owns, in
    /// bounded batches, updating migration metrics.
    fn run_migration(&self, snap: &PlacementSnapshot) -> Result<MigrationStats> {
        let origin = snap.origin.as_ref().expect("run_migration needs a migrating snapshot");
        let stats = self.migrate_batches(snap, origin)?;
        self.metrics.migrated_keys.fetch_add(stats.moved, Ordering::Relaxed);
        self.metrics.migration_batches.fetch_add(stats.batches, Ordering::Relaxed);
        Ok(stats)
    }

    fn migrate_batches(
        &self,
        snap: &PlacementSnapshot,
        origin: &MigrationOrigin,
    ) -> Result<MigrationStats> {
        // The XLA bulk path computes BinomialHash placement; use it only
        // when that is the active engine.
        if let (Some(bulk), "binomial") = (&self.bulk, snap.engine.name()) {
            let n_old = origin.engine.len();
            let n_new = snap.engine.len();
            let runtime = bulk.lock().unwrap();
            return rebalance::migrate_streaming(
                &snap.shards,
                origin.sources.clone(),
                MIGRATION_BATCH,
                |chunk| rebalance::plan(chunk, PlanPath::Xla { runtime: &runtime, n_old, n_new }),
            );
        }
        rebalance::migrate_streaming(
            &snap.shards,
            origin.sources.clone(),
            MIGRATION_BATCH,
            |chunk| {
                rebalance::plan(
                    chunk,
                    PlanPath::Engines { old: &*origin.engine, new: &*snap.engine },
                )
            },
        )
    }

    /// Serve the router protocol on a TCP listener (thread per connection).
    pub fn serve(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        loop {
            let (sock, _) = listener.accept()?;
            let router = self.clone();
            std::thread::spawn(move || {
                let _ = router.serve_conn(sock);
            });
        }
    }

    fn serve_conn(self: Arc<Self>, sock: TcpStream) -> Result<()> {
        sock.set_nodelay(true)?;
        let mut rd = BufReader::new(sock.try_clone()?);
        let mut wr = sock;
        // Borrowed parsing + coalesced responses; recoverable parse
        // failures answer ERR and keep the connection (see
        // `proto::serve_framed`).
        proto::serve_framed(&mut rd, &mut wr, |req| self.handle_ref(req))
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        // SAFETY: reclaiming the stored pointer's strong count; no reader
        // can race a `&mut self` drop.
        unsafe { drop(Arc::from_raw(self.current.load(Ordering::SeqCst).cast_const())) };
    }
}

/// Build an in-process cluster: `n` local shards + the chosen engine.
pub fn local_cluster(algorithm: &str, n: u32) -> Result<Cluster> {
    let placement = crate::algorithms::by_name(algorithm, n)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algorithm:?}"))?;
    let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
    Ok(Cluster::new(placement, shards))
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    fn val(bytes: &[u8]) -> Value {
        bytes.to_vec().into()
    }

    #[test]
    fn put_get_del_roundtrip() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle(Request::Put { key: "a".into(), value: val(b"1") }),
            Response::Ok
        );
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Val(val(b"1")));
        assert_eq!(router.handle(Request::Del { key: "a".into() }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Nil);
    }

    #[test]
    fn borrowed_and_owned_paths_agree() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle_ref(RequestRef::Put { key: "b", value: val(b"2") }),
            Response::Ok
        );
        assert_eq!(router.handle(Request::Get { key: "b".into() }), Response::Val(val(b"2")));
        assert_eq!(router.handle_ref(RequestRef::Get { key: "b" }), Response::Val(val(b"2")));
        assert_eq!(router.handle_ref(RequestRef::Del { key: "b" }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "b".into() }), Response::Nil);
    }

    #[test]
    fn snapshot_swap_is_visible_and_refcounted() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let before = router.snapshot();
        assert_eq!(before.epoch, 0);
        // Publish a new snapshot while `before` is still held — exactly
        // what a scale op's publish phase does under in-flight readers.
        // (Not `scale_up()` here: that quiesces on outstanding handles
        // and would wait for `before`.)
        router.publish(PlacementSnapshot {
            epoch: before.epoch + 1,
            engine: before.engine.fork(),
            shards: before.shards.clone(),
            origin: None,
        });
        // The superseded handle stays valid after the swap...
        assert_eq!(before.epoch, 0);
        assert_eq!(before.engine.len(), 2);
        // ...and new loads see the published epoch.
        let after = router.snapshot();
        assert_eq!(after.epoch, 1);
        assert!(!Arc::ptr_eq(&before, &after));
        // Two loads of an unchanged snapshot share the allocation.
        assert!(Arc::ptr_eq(&after, &router.snapshot()));
        // `before` is now the only holder of the superseded snapshot.
        assert_eq!(Arc::strong_count(&before), 1);
    }

    #[test]
    fn scale_up_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Put { key: format!("k{i}"), value: val(&[i as u8]) }),
                Response::Ok
            );
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(val(&[i as u8])),
                "key k{i} lost after scale-up"
            );
        }
    }

    #[test]
    fn scale_down_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 5).unwrap());
        for i in 0..500 {
            router.handle(Request::Put { key: format!("k{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(val(&[i as u8])),
                "key k{i} lost after scale-down"
            );
        }
    }

    #[test]
    fn scale_cycle_with_jumpback_engine() {
        let router = Router::new(local_cluster("jumpback", 4).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("j{i}"), value: val(&[1]) });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("j{i}") }),
                Response::Val(val(&[1]))
            );
        }
    }

    #[test]
    fn scale_cycle_with_stateful_memento_engine() {
        let router = Router::new(local_cluster("memento", 3).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("s{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("s{i}") }),
                Response::Val(val(&[i as u8])),
                "key s{i} lost scaling a stateful engine"
            );
        }
    }

    #[test]
    fn maglev_scale_down_scans_all_shards() {
        // maglev lacks exact minimal disruption: on scale-down keys can
        // move between surviving shards, so the migration must scan every
        // shard, not just the retiring one.
        let router = Router::new(local_cluster("maglev", 4).unwrap());
        for i in 0..400 {
            router.handle(Request::Put { key: format!("m{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..400 {
            assert_eq!(
                router.handle(Request::Get { key: format!("m{i}") }),
                Response::Val(val(&[i as u8])),
                "key m{i} stranded after maglev scale-down"
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(400));
    }

    #[test]
    fn scaling_engine_at_capacity_is_rejected_without_mutation() {
        use crate::algorithms::anchor::AnchorHash;
        let shards = (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let cluster = Cluster::new(Box::new(AnchorHash::with_capacity(3, 3)), shards);
        let router = Router::new(cluster);
        let before = router.topology();
        assert!(matches!(router.handle(Request::ScaleUp), Response::Err(_)));
        assert_eq!(router.topology(), before, "failed scale must not mutate topology");
        assert_eq!(router.snapshot().shards.len(), 3);
    }

    #[test]
    fn scaling_with_outstanding_failures_is_rejected_without_mutation() {
        // An engine with an arbitrary removal outstanding cannot scale at
        // the LIFO tail (anchor would restore the failed bucket instead
        // of growing; memento and dx panic in add_bucket/remove_bucket).
        // The router must answer ERR before mutating or publishing
        // anything — and without poisoning the admin mutex, so later
        // admin ops still work.
        use crate::algorithms::ConsistentHasher;
        use crate::algorithms::{
            anchor::AnchorHash, dx::DxHash, memento::MementoHash, FaultTolerant,
        };
        let degraded: Vec<Box<dyn ConsistentHasher>> = vec![
            {
                let mut e = AnchorHash::with_capacity(4, 8);
                e.remove_arbitrary(1);
                Box::new(e)
            },
            {
                let mut e = DxHash::with_capacity(4, 8);
                e.remove_arbitrary(1);
                Box::new(e)
            },
            {
                let mut e = MementoHash::new(4);
                e.remove_arbitrary(1);
                Box::new(e)
            },
        ];
        for engine in degraded {
            let name = engine.name();
            let shards = (0..engine.len()).map(|i| ShardClient::Local(Shard::new(i))).collect();
            let router = Router::new(Cluster::new(engine, shards));
            let before = router.topology();
            assert!(
                matches!(router.handle(Request::ScaleUp), Response::Err(_)),
                "{name}: degraded scale-up must be rejected"
            );
            assert!(
                matches!(router.handle(Request::ScaleDown), Response::Err(_)),
                "{name}: degraded scale-down must be rejected"
            );
            assert_eq!(router.topology(), before, "{name}: failed scale mutated topology");
            // The admin mutex must not be poisoned by the rejection.
            assert!(router.events().is_empty(), "{name}: rejected scale logged an event");
        }
    }

    #[test]
    fn del_during_migration_cannot_resurrect_key() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let old_engine = crate::algorithms::by_name("binomial", 2).unwrap();
        let new_engine = crate::algorithms::by_name("binomial", 3).unwrap();
        // A key that moves onto the joining bucket when scaling 2 -> 3.
        let key = (0..)
            .map(|i| format!("mv{i}"))
            .find(|k| {
                let d = crate::hashing::xxhash64(k.as_bytes(), 0);
                old_engine.bucket(d) != new_engine.bucket(d)
            })
            .unwrap();
        let d = crate::hashing::xxhash64(key.as_bytes(), 0);
        let (from, to) = (old_engine.bucket(d), new_engine.bucket(d));
        assert_eq!(
            router.handle(Request::Put { key: key.clone(), value: val(b"v") }),
            Response::Ok
        );

        // Freeze the moment mid-migration where the sweep has read the
        // source copy but not yet written it to the destination.
        let base = router.snapshot();
        let mut shards = base.shards.clone();
        shards.push(ShardClient::Local(Shard::new(2)));
        let copied = shards[from as usize].get(&key).unwrap().unwrap();
        router.publish(PlacementSnapshot {
            epoch: base.epoch + 1,
            engine: new_engine,
            shards: shards.clone(),
            origin: Some(MigrationOrigin { engine: old_engine, sources: 0..2 }),
        });

        // The client DEL lands while the copy is in flight...
        assert_eq!(router.handle(Request::Del { key: key.clone() }), Response::Ok);
        // ...then the sweep's PUTNX arrives late and must be refused.
        assert!(!shards[to as usize].put_nx(&key, copied).unwrap());
        assert_eq!(
            router.handle(Request::Get { key: key.clone() }),
            Response::Nil,
            "DEL racing a migration copy resurrected the key"
        );
    }

    #[test]
    fn epochs_advance_and_settle() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert_eq!(router.topology().0, 0);
        router.scale_up().unwrap();
        assert_eq!(router.topology().0, 1);
        assert!(!router.snapshot().is_migrating(), "scale_up must settle before returning");
        router.scale_down().unwrap();
        assert_eq!(router.topology().0, 2);
        assert_eq!(router.events().len(), 2);
    }

    #[test]
    fn stats_reports_topology() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("n=2"));
                assert!(s.contains("algo=binomial"));
                assert!(s.contains("state=steady"));
                assert!(s.contains("epoch=0"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn invalid_key_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(
            router.handle(Request::Get { key: "bad key".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn shard_internal_commands_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(router.handle(Request::Scan), Response::Err(_)));
        assert!(matches!(
            router.handle(Request::ScanStripe { stripe: 0 }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::PutNx { key: "k".into(), value: val(&[1]) }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::DelTomb { key: "k".into() }),
            Response::Err(_)
        ));
        assert!(matches!(router.handle(Request::PurgeTombs), Response::Err(_)));
    }

    #[test]
    fn count_sums_shards() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..64 {
            router.handle(Request::Put { key: format!("c{i}"), value: val(&[0]) });
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
    }

    #[test]
    fn count_does_not_hold_the_snapshot_across_shard_io() {
        // COUNT must clone the handles and release the snapshot before
        // summing — otherwise a slow shard would stall a concurrent scale
        // op's quiesce barrier.  With local shards "slow I/O" can't be
        // injected directly, so pin the observable contract: while a
        // COUNT's result is still being consumed, the router can publish
        // and fully settle a topology change.
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..100 {
            router.handle(Request::Put { key: format!("h{i}"), value: val(&[1]) });
        }
        let before = router.snapshot();
        let counted = router.handle(Request::Count);
        // The snapshot handle from before the COUNT is the only
        // outstanding one — COUNT itself left nothing pinned.
        assert_eq!(Arc::strong_count(&before), 2, "COUNT leaked a snapshot reference");
        drop(before);
        assert_eq!(counted, Response::Num(100));
        router.scale_up().unwrap();
        assert_eq!(router.handle(Request::Count), Response::Num(100));
    }

    #[test]
    fn tcp_end_to_end() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: val(b"yz") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "x".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"yz")));
    }

    #[test]
    fn router_malformed_command_keeps_the_connection() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        wr.write_all(b"FROB x\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        // The connection survived: a valid request still round-trips.
        proto::write_request(&mut wr, &Request::Put { key: "y".into(), value: val(b"1") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "y".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"1")));
    }
}
