//! Request router — the coordinator's front-end.
//!
//! Accepts client connections speaking the wire protocol, places each key
//! with the cluster's consistent-hashing engine (constant-time BinomialHash
//! by default), and forwards to the owning shard.
//!
//! ## Concurrency model: epoch snapshots + incremental migration
//!
//! The data path routes with an immutable [`PlacementSnapshot`] behind an
//! `Arc` swap (hand-rolled with `std::sync`: the `RwLock` is held only for
//! the `Arc` clone/store — a few ns — never across shard I/O or migration
//! work).  Topology changes are serialized by an admin mutex and proceed
//! in three phases, none of which blocks GET/PUT/DEL:
//!
//! 1. **Publish** a new epoch whose snapshot routes with the *new* engine
//!    — a [`ConsistentHasher::fork`](crate::algorithms::ConsistentHasher::fork)
//!    of the current one with the bucket added/removed — and carries a
//!    [`MigrationOrigin`] (a fork of the old engine), enabling dual-read:
//!    a GET that misses on a key's new owner retries the old owner.  PUTs
//!    land on the new owner and retire the old copy; DELs tombstone the
//!    new owner (`DELTOMB`) and remove the old copy.
//! 2. **Quiesce** the superseded snapshot (wait for its in-flight readers
//!    — `Arc::strong_count` — to drain; readers hold a snapshot only for
//!    one request, so this settles in microseconds), then run the
//!    incremental migration: stream every source shard stripe-by-stripe
//!    and move keys in bounded batches ([`rebalance::migrate_streaming`]),
//!    optionally planning batches on the PJRT bulk artifacts.
//! 3. **Settle**: publish the same epoch without the origin (and, on
//!    scale-down, without the retiring shard handle), then purge the
//!    migration tombstones.
//!
//! Because each epoch's engine is forked from the previous one, every
//! registered engine scales — the stateless constant-time family and the
//! stateful minimal-memory one (anchor, dx, memento) alike; there is no
//! name-reconstruction whitelist.  Engines without exact minimal
//! disruption (maglev, the modulo anti-baseline) scan every shard on
//! scale-down instead of only the retiring one
//! ([`ConsistentHasher::minimal_disruption`](crate::algorithms::ConsistentHasher::minimal_disruption)).
//!
//! The copy step (`PUTNX`) cannot clobber a newer client write, and the
//! `DELTOMB` tombstone bars it from resurrecting a key whose DEL raced
//! the migration sweep — the former "known anomaly" of this module.

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::cluster::{Cluster, EventKind, MigrationOrigin, PlacementSnapshot, TopologyEvent};
use crate::metrics::RouterMetrics;
use crate::proto::{self, Request, Response};
use crate::rebalance::{self, MigrationStats, PlanPath};
use crate::runtime::PlacementRuntime;
use crate::shard::{Shard, ShardClient};

/// Shard factory used on scale-up.
pub type ShardSpawner = Box<dyn Fn(u32) -> ShardClient + Send + Sync>;

/// Keys per migration batch: small enough that a batch is visible to
/// readers almost immediately, large enough to amortize planning.
const MIGRATION_BATCH: usize = 512;

/// The router: published placement snapshot + metrics + optional XLA bulk
/// runtime.
pub struct Router {
    /// Current snapshot; swapped atomically on each migration phase.
    current: RwLock<Arc<PlacementSnapshot>>,
    /// Serializes topology changes and owns the event log. The data path
    /// never touches this; `SCALEUP`/`SCALEDOWN` take it with `try_lock`
    /// and answer `ERR MIGRATING` when a change is already in flight.
    admin: Mutex<Vec<TopologyEvent>>,
    /// Request/latency counters.
    pub metrics: RouterMetrics,
    /// Bulk placement runtime for rebalance planning (None = Rust path).
    /// Serialized behind a mutex — see the Send safety note in `runtime`.
    bulk: Option<Mutex<PlacementRuntime>>,
    spawn_shard: ShardSpawner,
}

impl Router {
    /// Router over an existing cluster, spawning in-process shards on
    /// scale-up.
    pub fn new(cluster: Cluster) -> Arc<Self> {
        Self::with_options(cluster, Box::new(|id| ShardClient::Local(Shard::new(id))), None)
    }

    /// Router with a custom shard factory and/or bulk runtime.
    pub fn with_options(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
    ) -> Arc<Self> {
        let (snapshot, events) = cluster.into_snapshot();
        Arc::new(Self {
            current: RwLock::new(Arc::new(snapshot)),
            admin: Mutex::new(events),
            metrics: RouterMetrics::new(),
            bulk: bulk.map(Mutex::new),
            spawn_shard,
        })
    }

    /// The current placement snapshot (one `Arc` clone; never blocks on a
    /// migration).
    ///
    /// Hold-time contract: drop the handle promptly (one request's worth
    /// of work). Scale operations wait for superseded snapshots' readers
    /// to drain before deleting migrated source copies, so a handle held
    /// across blocking work stalls — not corrupts — topology changes.
    pub fn snapshot(&self) -> Arc<PlacementSnapshot> {
        self.current.read().unwrap().clone()
    }

    fn publish(&self, snapshot: PlacementSnapshot) {
        *self.current.write().unwrap() = Arc::new(snapshot);
    }

    /// Wait until no in-flight request still routes with `snap` (all
    /// reader clones dropped). After a publish no new reader can acquire
    /// it, and readers hold a snapshot only for the duration of one shard
    /// call, so this settles in microseconds.
    fn quiesce(snap: &Arc<PlacementSnapshot>) {
        while Arc::strong_count(snap) > 1 {
            std::thread::yield_now();
        }
    }

    /// Current `(epoch, n, algorithm)`.
    pub fn topology(&self) -> (u64, u32, &'static str) {
        let snap = self.snapshot();
        (snap.epoch, snap.engine.len(), snap.engine.name())
    }

    /// Topology events recorded so far.
    pub fn events(&self) -> Vec<TopologyEvent> {
        self.admin.lock().unwrap().clone()
    }

    /// Key count on one shard (telemetry; used by examples/benches).
    pub fn shard_count(&self, bucket: u32) -> Result<u64> {
        let snap = self.snapshot();
        ensure!((bucket as usize) < snap.shards.len(), "bucket {bucket} out of range");
        snap.shards[bucket as usize].count()
    }

    /// Handle one data/admin request end-to-end.
    pub fn handle(&self, req: Request) -> Response {
        let start = Instant::now();
        let resp = match req {
            Request::Get { key } => self.data_get(key),
            Request::Put { key, value } => self.data_put(key, value),
            Request::Del { key } => self.data_del(key),
            // COUNT sums every shard in the snapshot. Mid-migration a key
            // sits on both owners between the copy and the source delete,
            // so the total can transiently over-report by up to one batch.
            Request::Count => {
                let snap = self.snapshot();
                let mut total = 0u64;
                let mut err = None;
                for s in &snap.shards {
                    match s.count() {
                        Ok(x) => total += x,
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Response::Num(total),
                    Some(e) => Response::Err(e.to_string()),
                }
            }
            Request::Stats => {
                let snap = self.snapshot();
                Response::Info(format!(
                    "epoch={} n={} algo={} state={} {}",
                    snap.epoch,
                    snap.engine.len(),
                    snap.engine.name(),
                    if snap.is_migrating() { "migrating" } else { "steady" },
                    self.metrics.summary()
                ))
            }
            Request::Scan
            | Request::ScanStripe { .. }
            | Request::PutNx { .. }
            | Request::DelTomb { .. }
            | Request::PurgeTombs => Response::Err("shard-internal command".into()),
            Request::ScaleUp => match self.scale_up() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            Request::ScaleDown => match self.scale_down() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
        };
        if matches!(resp, Response::Err(_)) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.latency.record(start.elapsed());
        resp
    }

    /// Validate a key, count the op, and return its digest.
    fn admit(&self, key: &str, counter: &std::sync::atomic::AtomicU64) -> Result<u64, Response> {
        if !proto::valid_key(key) {
            return Err(Response::Err(format!("invalid key {key:?}")));
        }
        counter.fetch_add(1, Ordering::Relaxed);
        Ok(crate::hashing::xxhash64(key.as_bytes(), 0))
    }

    fn data_get(&self, key: String) -> Response {
        let digest = match self.admit(&key, &self.metrics.gets) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration, the key may not have reached its new owner
            // yet: dual-read, new owner then old owner — and if both miss,
            // re-probe the new owner once.  Copies always land new-first
            // (PUTNX/PUT before the source DEL), so a key that vanished
            // from the old owner between our two probes is already
            // readable on the new one; the third probe closes that window.
            Some((_, old_shard)) => match shard.call(Request::Get { key: key.clone() }) {
                Ok(Response::Nil) => {
                    self.metrics.dual_reads.fetch_add(1, Ordering::Relaxed);
                    match old_shard.call(Request::Get { key: key.clone() }) {
                        Ok(Response::Nil) => match shard.call(Request::Get { key }) {
                            Ok(resp) => resp,
                            Err(e) => Response::Err(e.to_string()),
                        },
                        Ok(resp) => resp,
                        Err(e) => Response::Err(e.to_string()),
                    }
                }
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
            None => match shard.call(Request::Get { key }) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    fn data_put(&self, key: String, value: Vec<u8>) -> Response {
        let digest = match self.admit(&key, &self.metrics.puts) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration: write the new owner, then retire the old copy
            // so neither the migration sweep nor a dual-read can resurface
            // a stale value.  The old-copy delete is best-effort: once the
            // new owner holds the value, reads route there first and the
            // migration sweep (PUTNX) cannot clobber it, so a cleanup
            // failure must not turn a durable write into a client error.
            Some((_, old_shard)) => {
                let resp = match shard.call(Request::Put { key: key.clone(), value }) {
                    Ok(resp) => resp,
                    Err(e) => return Response::Err(e.to_string()),
                };
                let _ = old_shard.call(Request::Del { key });
                resp
            }
            None => match shard.call(Request::Put { key, value }) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    fn data_del(&self, key: String) -> Response {
        let digest = match self.admit(&key, &self.metrics.dels) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match snap.fallback_route(digest, bucket) {
            // Mid-migration: the key may live on either owner — delete
            // both; it existed if either copy did.  The new-owner delete
            // leaves a tombstone so an in-flight migration copy (PUTNX)
            // of this key cannot resurrect it after the delete wins the
            // race; the tombstones are purged when the migration settles.
            Some((_, old_shard)) => {
                let new_r = shard.call(Request::DelTomb { key: key.clone() });
                let old_r = old_shard.call(Request::Del { key });
                match (new_r, old_r) {
                    (Ok(Response::Ok), Ok(_)) | (Ok(_), Ok(Response::Ok)) => Response::Ok,
                    (Ok(resp), Ok(_)) => resp,
                    (Err(e), _) | (_, Err(e)) => Response::Err(e.to_string()),
                }
            }
            None => match shard.call(Request::Del { key }) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        }
    }

    /// Clear migration tombstones on every shard (idempotent; called once
    /// a migration settles, and defensively before a new one starts).
    fn purge_tombstones(shards: &[ShardClient]) -> Result<()> {
        for s in shards {
            s.purge_tombstones()?;
        }
        Ok(())
    }

    /// Add a shard and incrementally migrate exactly the keys that now
    /// belong to it, serving reads and writes throughout.  Returns the new
    /// cluster size.
    pub fn scale_up(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base.shards)?;
        let n_old = base.engine.len();
        let n_new = n_old + 1;
        // Fail fast — nothing is mutated or published for an engine at
        // its pre-allocated capacity (anchor's anchor set, dx's NSArray);
        // `add_bucket` would panic mid-change otherwise.
        if let Some(cap) = base.engine.max_buckets() {
            ensure!(
                n_new <= cap,
                "engine {:?} is at its capacity of {cap} buckets; cannot scale up",
                base.engine.name()
            );
        }
        // A fork of an engine with outstanding arbitrary removals would
        // not grow at the LIFO tail (or would panic in add_bucket);
        // reject before anything is mutated or published.
        ensure!(
            base.engine.lifo_ready(),
            "engine {:?} has outstanding arbitrary removals; restore failed buckets \
             before scaling",
            base.engine.name()
        );
        // The next epoch's engine is a fork of the live one with the new
        // bucket added; the origin keeps an unmodified fork for dual-read
        // and migration planning.  No engine is rebuilt from its name, so
        // stateful engines carry their full state across the change.
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let added = new_engine.add_bucket();
        // The new shard handle is pushed at index n_old, so the engine
        // must have grown at the LIFO tail.  An engine with outstanding
        // arbitrary removals (e.g. anchor restoring a failed bucket
        // instead) would route the "new" bucket to the wrong handle; the
        // mutated fork is discarded and nothing has been published.
        ensure!(
            added == n_old,
            "engine {:?} added bucket {added} instead of the LIFO tail {n_old} \
             (restore failed buckets before scaling)",
            base.engine.name()
        );

        let mut shards = base.shards.clone();
        let joining = (self.spawn_shard)(n_old);
        // A joining shard may be a reconnection to a remote process with
        // leftover state (e.g. retired earlier after a best-effort purge
        // failed); clear its tombstones before any migration copy can be
        // refused by them.  Failing here is still pre-publish.
        joining.purge_tombstones()?;
        shards.push(joining);
        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: shards.clone(),
            // Monotonicity: any old shard may hold keys that now belong to
            // the joining bucket, so all of them are migration sources.
            origin: Some(MigrationOrigin { engine: old_engine, sources: 0..n_old }),
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Joined(n_old),
            at: std::time::SystemTime::now(),
        });
        // No reader may still route with the pre-migration snapshot once
        // batches start deleting source copies (such a reader would have
        // no dual-read fallback); readers drain in microseconds.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
        });
        // Drain dual-read holders of the migrating snapshot before
        // returning, so every future topology change only ever has one
        // live predecessor to quiesce — after which no request can still
        // be writing migration tombstones, and they can be purged.  The
        // scale op has fully settled by now, so a transient purge failure
        // must not turn it into a client error: stale tombstones are
        // harmless until the next migration, and the next scale op
        // re-purges (and fails fast there) before publishing anything.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating.shards);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(n_new)
    }

    /// Remove the last shard after incrementally migrating its keys away,
    /// serving reads and writes throughout.  Returns the new cluster size.
    pub fn scale_down(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base.shards)?;
        let n_old = base.engine.len();
        ensure!(n_old > 1, "cannot scale below one shard");
        let n_new = n_old - 1;
        // As in scale_up: a degraded engine cannot shrink at the LIFO
        // tail (memento/dx panic in remove_bucket); reject up front.
        ensure!(
            base.engine.lifo_ready(),
            "engine {:?} has outstanding arbitrary removals; restore failed buckets \
             before scaling",
            base.engine.name()
        );
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let removed = new_engine.remove_bucket();
        // As in scale_up: the shard list drops index n_new, so the engine
        // must have shrunk at the LIFO tail (a discarded fork; nothing
        // published on error).
        ensure!(
            removed == n_new,
            "engine {:?} removed bucket {removed} instead of the LIFO tail {n_new} \
             (restore failed buckets before scaling)",
            base.engine.name()
        );
        // Minimal disruption: only the retiring shard's keys move, so it
        // is the sole migration source — a scale-down costs O(retiring
        // shard), not O(cluster keyset).  Engines without the exact
        // guarantee (maglev's table rebuild, modulo) also shuffle keys
        // between surviving shards, so every shard must be scanned.
        let sources = if base.engine.minimal_disruption() { n_new..n_old } else { 0..n_old };

        let epoch = base.epoch + 1;
        // The migrating snapshot routes with the new engine (never onto
        // the retiring shard) but keeps the full shard list so dual reads
        // still reach the retiring shard's keys.
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin: Some(MigrationOrigin { engine: old_engine, sources }),
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Left(n_new),
            at: std::time::SystemTime::now(),
        });
        let mut shards = base.shards.clone();
        // Same hazard as scale-up: a reader still routing with the old
        // snapshot would miss keys whose source copy a batch just deleted.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        // Settle: drop the retiring shard handle.
        shards.truncate(n_new as usize);
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
        });
        // As in scale_up: drain dual-read holders, then purge the
        // tombstones their DELs may have written (best-effort — the op
        // has settled; the next scale op re-purges before publishing).
        // The retiring shard is included: a remote process outlives its
        // handle and could rejoin a later epoch carrying stale tombstones.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating.shards);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(n_new)
    }

    /// Complete an interrupted migration: if a previous scale op failed
    /// mid-sweep (e.g. a remote shard hiccup) the migrating snapshot is
    /// still published — dual-read keeps every key serveable — but the
    /// topology never settled.  Re-running the sweep is idempotent (PUTNX
    /// copies, source deletes of already-moved keys are no-ops), after
    /// which the snapshot settles normally.  Without this, a retried scale
    /// op would build a fresh origin from the stuck topology and strand
    /// never-migrated keys outside both routes.
    fn resume_interrupted(
        &self,
        base: Arc<PlacementSnapshot>,
    ) -> Result<Arc<PlacementSnapshot>> {
        if !base.is_migrating() {
            return Ok(base);
        }
        self.run_migration(&base)?;
        let n = base.engine.len();
        let mut shards = base.shards.clone();
        shards.truncate(n as usize); // no-op for an interrupted scale-up
        self.publish(PlacementSnapshot {
            epoch: base.epoch,
            engine: base.engine.fork(),
            shards,
            origin: None,
        });
        Self::quiesce(&base);
        drop(base);
        Ok(self.snapshot())
    }

    /// Stream-migrate everything the snapshot's origin still owns, in
    /// bounded batches, updating migration metrics.
    fn run_migration(&self, snap: &PlacementSnapshot) -> Result<MigrationStats> {
        let origin = snap.origin.as_ref().expect("run_migration needs a migrating snapshot");
        let stats = self.migrate_batches(snap, origin)?;
        self.metrics.migrated_keys.fetch_add(stats.moved, Ordering::Relaxed);
        self.metrics.migration_batches.fetch_add(stats.batches, Ordering::Relaxed);
        Ok(stats)
    }

    fn migrate_batches(
        &self,
        snap: &PlacementSnapshot,
        origin: &MigrationOrigin,
    ) -> Result<MigrationStats> {
        // The XLA bulk path computes BinomialHash placement; use it only
        // when that is the active engine.
        if let (Some(bulk), "binomial") = (&self.bulk, snap.engine.name()) {
            let n_old = origin.engine.len();
            let n_new = snap.engine.len();
            let runtime = bulk.lock().unwrap();
            return rebalance::migrate_streaming(
                &snap.shards,
                origin.sources.clone(),
                MIGRATION_BATCH,
                |chunk| rebalance::plan(chunk, PlanPath::Xla { runtime: &runtime, n_old, n_new }),
            );
        }
        rebalance::migrate_streaming(
            &snap.shards,
            origin.sources.clone(),
            MIGRATION_BATCH,
            |chunk| {
                rebalance::plan(
                    chunk,
                    PlanPath::Engines { old: &*origin.engine, new: &*snap.engine },
                )
            },
        )
    }

    /// Serve the router protocol on a TCP listener (thread per connection).
    pub fn serve(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        loop {
            let (sock, _) = listener.accept()?;
            let router = self.clone();
            std::thread::spawn(move || {
                let _ = router.serve_conn(sock);
            });
        }
    }

    fn serve_conn(self: Arc<Self>, sock: TcpStream) -> Result<()> {
        sock.set_nodelay(true)?;
        let mut rd = BufReader::new(sock.try_clone()?);
        let mut wr = sock;
        while let Some(req) = proto::read_request(&mut rd)? {
            let resp = self.handle(req);
            proto::write_response(&mut wr, &resp)?;
        }
        Ok(())
    }
}

/// Build an in-process cluster: `n` local shards + the chosen engine.
pub fn local_cluster(algorithm: &str, n: u32) -> Result<Cluster> {
    let placement = crate::algorithms::by_name(algorithm, n)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algorithm:?}"))?;
    let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
    Ok(Cluster::new(placement, shards))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_del_roundtrip() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle(Request::Put { key: "a".into(), value: b"1".to_vec() }),
            Response::Ok
        );
        assert_eq!(
            router.handle(Request::Get { key: "a".into() }),
            Response::Val(b"1".to_vec())
        );
        assert_eq!(router.handle(Request::Del { key: "a".into() }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Nil);
    }

    #[test]
    fn scale_up_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Put { key: format!("k{i}"), value: vec![i as u8] }),
                Response::Ok
            );
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8]),
                "key k{i} lost after scale-up"
            );
        }
    }

    #[test]
    fn scale_down_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 5).unwrap());
        for i in 0..500 {
            router.handle(Request::Put { key: format!("k{i}"), value: vec![i as u8] });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8]),
                "key k{i} lost after scale-down"
            );
        }
    }

    #[test]
    fn scale_cycle_with_jumpback_engine() {
        let router = Router::new(local_cluster("jumpback", 4).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("j{i}"), value: vec![1] });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("j{i}") }),
                Response::Val(vec![1])
            );
        }
    }

    #[test]
    fn scale_cycle_with_stateful_memento_engine() {
        let router = Router::new(local_cluster("memento", 3).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("s{i}"), value: vec![i as u8] });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("s{i}") }),
                Response::Val(vec![i as u8]),
                "key s{i} lost scaling a stateful engine"
            );
        }
    }

    #[test]
    fn maglev_scale_down_scans_all_shards() {
        // maglev lacks exact minimal disruption: on scale-down keys can
        // move between surviving shards, so the migration must scan every
        // shard, not just the retiring one.
        let router = Router::new(local_cluster("maglev", 4).unwrap());
        for i in 0..400 {
            router.handle(Request::Put { key: format!("m{i}"), value: vec![i as u8] });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..400 {
            assert_eq!(
                router.handle(Request::Get { key: format!("m{i}") }),
                Response::Val(vec![i as u8]),
                "key m{i} stranded after maglev scale-down"
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(400));
    }

    #[test]
    fn scaling_engine_at_capacity_is_rejected_without_mutation() {
        use crate::algorithms::anchor::AnchorHash;
        let shards = (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let cluster = Cluster::new(Box::new(AnchorHash::with_capacity(3, 3)), shards);
        let router = Router::new(cluster);
        let before = router.topology();
        assert!(matches!(router.handle(Request::ScaleUp), Response::Err(_)));
        assert_eq!(router.topology(), before, "failed scale must not mutate topology");
        assert_eq!(router.snapshot().shards.len(), 3);
    }

    #[test]
    fn scaling_with_outstanding_failures_is_rejected_without_mutation() {
        // An engine with an arbitrary removal outstanding cannot scale at
        // the LIFO tail (anchor would restore the failed bucket instead
        // of growing; memento and dx panic in add_bucket/remove_bucket).
        // The router must answer ERR before mutating or publishing
        // anything — and without poisoning the admin mutex, so later
        // admin ops still work.
        use crate::algorithms::{
            anchor::AnchorHash, dx::DxHash, memento::MementoHash, FaultTolerant,
        };
        use crate::algorithms::ConsistentHasher;
        let degraded: Vec<Box<dyn ConsistentHasher>> = vec![
            {
                let mut e = AnchorHash::with_capacity(4, 8);
                e.remove_arbitrary(1);
                Box::new(e)
            },
            {
                let mut e = DxHash::with_capacity(4, 8);
                e.remove_arbitrary(1);
                Box::new(e)
            },
            {
                let mut e = MementoHash::new(4);
                e.remove_arbitrary(1);
                Box::new(e)
            },
        ];
        for engine in degraded {
            let name = engine.name();
            let shards = (0..engine.len()).map(|i| ShardClient::Local(Shard::new(i))).collect();
            let router = Router::new(Cluster::new(engine, shards));
            let before = router.topology();
            assert!(
                matches!(router.handle(Request::ScaleUp), Response::Err(_)),
                "{name}: degraded scale-up must be rejected"
            );
            assert!(
                matches!(router.handle(Request::ScaleDown), Response::Err(_)),
                "{name}: degraded scale-down must be rejected"
            );
            assert_eq!(router.topology(), before, "{name}: failed scale mutated topology");
            // The admin mutex must not be poisoned by the rejection.
            assert!(router.events().is_empty(), "{name}: rejected scale logged an event");
        }
    }

    #[test]
    fn del_during_migration_cannot_resurrect_key() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let old_engine = crate::algorithms::by_name("binomial", 2).unwrap();
        let new_engine = crate::algorithms::by_name("binomial", 3).unwrap();
        // A key that moves onto the joining bucket when scaling 2 -> 3.
        let key = (0..)
            .map(|i| format!("mv{i}"))
            .find(|k| {
                let d = crate::hashing::xxhash64(k.as_bytes(), 0);
                old_engine.bucket(d) != new_engine.bucket(d)
            })
            .unwrap();
        let d = crate::hashing::xxhash64(key.as_bytes(), 0);
        let (from, to) = (old_engine.bucket(d), new_engine.bucket(d));
        assert_eq!(
            router.handle(Request::Put { key: key.clone(), value: b"v".to_vec() }),
            Response::Ok
        );

        // Freeze the moment mid-migration where the sweep has read the
        // source copy but not yet written it to the destination.
        let base = router.snapshot();
        let mut shards = base.shards.clone();
        shards.push(ShardClient::Local(Shard::new(2)));
        let copied = shards[from as usize].get(&key).unwrap().unwrap();
        router.publish(PlacementSnapshot {
            epoch: base.epoch + 1,
            engine: new_engine,
            shards: shards.clone(),
            origin: Some(MigrationOrigin { engine: old_engine, sources: 0..2 }),
        });

        // The client DEL lands while the copy is in flight...
        assert_eq!(router.handle(Request::Del { key: key.clone() }), Response::Ok);
        // ...then the sweep's PUTNX arrives late and must be refused.
        assert!(!shards[to as usize].put_nx(&key, copied).unwrap());
        assert_eq!(
            router.handle(Request::Get { key: key.clone() }),
            Response::Nil,
            "DEL racing a migration copy resurrected the key"
        );
    }

    #[test]
    fn epochs_advance_and_settle() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert_eq!(router.topology().0, 0);
        router.scale_up().unwrap();
        assert_eq!(router.topology().0, 1);
        assert!(!router.snapshot().is_migrating(), "scale_up must settle before returning");
        router.scale_down().unwrap();
        assert_eq!(router.topology().0, 2);
        assert_eq!(router.events().len(), 2);
    }

    #[test]
    fn stats_reports_topology() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("n=2"));
                assert!(s.contains("algo=binomial"));
                assert!(s.contains("state=steady"));
                assert!(s.contains("epoch=0"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn invalid_key_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(
            router.handle(Request::Get { key: "bad key".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn shard_internal_commands_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(router.handle(Request::Scan), Response::Err(_)));
        assert!(matches!(
            router.handle(Request::ScanStripe { stripe: 0 }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::PutNx { key: "k".into(), value: vec![1] }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::DelTomb { key: "k".into() }),
            Response::Err(_)
        ));
        assert!(matches!(router.handle(Request::PurgeTombs), Response::Err(_)));
    }

    #[test]
    fn count_sums_shards() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..64 {
            router.handle(Request::Put { key: format!("c{i}"), value: vec![0] });
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
    }

    #[test]
    fn tcp_end_to_end() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: b"yz".to_vec() })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "x".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(b"yz".to_vec()));
    }
}
