//! Request router — the coordinator's front-end.
//!
//! Accepts client connections speaking the wire protocol, places each key
//! with the cluster's consistent-hashing engine (constant-time BinomialHash
//! by default), and forwards to the owning shard.
//!
//! ## Lock-free, allocation-free data path
//!
//! BinomialHash decides placement in nanoseconds with 8 bytes of state;
//! the routing around it is built to the same budget.  In steady state a
//! local GET/PUT/DEL through [`Router::handle_ref`] performs **zero heap
//! allocations** (pinned by `rust/tests/zero_alloc.rs`) and acquires **no
//! lock** for snapshot access:
//!
//! * The current [`PlacementSnapshot`] is published through
//!   [`SnapshotCell`](crate::sync::cell::SnapshotCell) — an atomic `Arc`
//!   swap whose pointer owns one strong count.  [`Router::snapshot`] is
//!   one atomic pointer load plus a refcount bump, guarded by a
//!   generation-validated reader gate: a reader registers in the gate
//!   slot of the current generation's parity, re-checks the generation,
//!   and only then touches the pointer (retrying if a publish raced in).
//!   A publisher swaps the pointer, advances the generation, and drains
//!   the *superseded* parity slot to zero before releasing the
//!   superseded snapshot's stored count — that closes the classic
//!   load-then-bump race (a reader holding the superseded raw pointer
//!   without having bumped its count yet).  Readers arriving during the
//!   drain validate against the new generation and land in the other
//!   slot, so publication cannot be starved.  The protocol is
//!   model-checked under `--features model` (`rust/tests/model.rs`).
//! * Requests are parsed into borrowed [`RequestRef`]s from a reusable
//!   per-connection [`proto::RecvBuf`] — no per-line `String`, no key
//!   copies — and responses are coalesced per pipelined burst (one flush
//!   per drained read buffer, not per response).
//! * Values are `Arc<[u8]>` end to end: a GET bumps a refcount, a PUT
//!   moves the parsed buffer into the shard map, and the key digest the
//!   router computes for placement is threaded into local shard calls so
//!   the stripe map never re-hashes the key.
//!
//! Reclamation keeps the pre-existing protocol: superseded snapshots are
//! quiesced with `Arc::strong_count` (bounded exponential backoff via
//! [`sync::Backoff`](crate::sync::Backoff)) before migration batches
//! delete source copies.
//!
//! ## Memory-ordering table
//!
//! Every atomic in the router's orbit, its ordering, and why (each use
//! site also carries an inline `ord:` comment — `tools/lint_sync.py`
//! rejects unannotated `Ordering::` uses):
//!
//! | Atomic | Ordering | Why |
//! |---|---|---|
//! | cell `ptr` load/swap | `SeqCst` | Must interleave in one total order with the generation bump and slot drain; the covered-reader proof is a single-total-order argument (see [`crate::sync::cell`]). |
//! | cell `generation` load / `fetch_add` | `SeqCst` | Reader validation (`load — register — re-load`) pairs with the publisher's `swap — bump — drain`; weaker orders would let a validated reader's registration be missed by the drain. |
//! | cell `gate[parity]` add/sub/load | `SeqCst` | The drain must observe every covered reader's registration; registration must not sink below validation. |
//! | `quiesce` via `Arc::strong_count` | `Acquire` (inside `std::sync::Arc`) | Not a site we pick: `Arc`'s own refcount protocol guarantees the count read happens-after reader drops. |
//! | `metrics.*` counters | `Relaxed` | Independent telemetry counters: each is an isolated monotone tally, read only by `summary()`/tests; no other memory is published through them. |
//! | shard `ops`, `RemotePool.next` | `Relaxed` | Same: standalone counters / round-robin cursor, no release/acquire role. |
//!
//! The `SeqCst` sites are deliberately *not* downgraded to
//! acquire/release: the gate's safety argument is stated in terms of the
//! sequentially consistent total order (the model checker also only
//! explores SC interleavings, so a weaker-order variant would be
//! asserting more than it checks — see `sync`'s module docs).
//!
//! ## Serving: readiness event loops over the same data path
//!
//! The router implements [`net::Service`](crate::net::Service), so both
//! server personalities drive the identical handler code:
//! [`Router::serve`] is the portable blocking thread-per-connection
//! fallback, and [`Router::server`] builds the Linux epoll event server
//! ([`crate::net`]) — a few shared-nothing event loops, each calling
//! `Service::handle` → `snapshot()` directly.  Because snapshot access
//! is the lock-free cell above, event loops share **no router-side locks
//! on the data path**; fan-in scales with loops.  The state-machine
//! diagram, interest-transition table, and backpressure rule live in the
//! [`crate::net`] module docs; connection counters surface in `STATS`
//! via [`ConnMetrics`] (`conns_*` fields).  New cross-thread state this
//! introduces (the accepted-socket handoff queue) is model-checked like
//! the cell — see `sync::handoff` and `rust/tests/model.rs`.
//!
//! ## Batched data plane: one fan-out per shard, not one per key
//!
//! Placement costs nanoseconds; a shard round-trip costs micro- to
//! milliseconds.  [`Router::handle_batch`] exploits that asymmetry for
//! `MGET`/`MPUT`/`MDEL` frames: it computes **all placements up front**,
//! groups the keys by owner bucket with one in-place sort of packed
//! `(bucket, index)` words, and issues **one fan-out per owner shard** —
//! a stripe-grouped in-process run for local shards, a single `MULTI`
//! round-trip for remote ones.  A batch of `k` keys over `s` owners
//! costs `s` round-trips instead of `k`.
//!
//! The up-front placement is itself batched: the digest column in
//! [`BatchScratch`] is placed by **one
//! [`bucket_batch`](crate::algorithms::ConsistentHasher::bucket_batch)
//! call** into a parallel `buckets` column instead of one scalar
//! `bucket` per key.  For the binomial engine that call is the
//! lane-parallel kernel
//! ([`algorithms::binomial::lookup_batch`](crate::algorithms::binomial::lookup_batch)
//! — eight independent rehash chains per chunk, §Perf there); the
//! `Weighted` adapter forwards to it and applies the owner map in
//! place; every other engine runs the scalar default, placement-
//! identical either way.  When a PJRT bulk runtime is loaded and the
//! bare binomial engine is active, batches of ≥ `PJRT_BATCH_MIN` keys
//! route through the compiled XLA artifact instead (the migration
//! planner's bulk path, turned data-plane).  The replica fan-out phase
//! batches the same way: at factor 2 on a fault-tolerant engine each
//! primary group's replica set is one `bucket_batch` call through that
//! primary's precomputed minus fork.  Both columns live in the
//! caller-owned scratch — `clear()` + `resize()` on warm `Vec`s — so
//! batched placement stays allocation-free once warm (the armed MGET
//! window in `rust/tests/zero_alloc.rs` covers the `buckets` column).
//!
//! Ordering guarantees, in decreasing strength:
//!
//! * **Positional reassembly** — the i-th sub-response always answers
//!   the i-th key, whatever order the fan-outs ran in (each fan-out
//!   writes its answers through the original indices).
//! * **In-batch order for duplicate keys** — duplicates share an owner
//!   and a stripe, and every grouping stage preserves request order
//!   within a group (the packed words sort by `(bucket, index)`, so a
//!   group's indices stay ascending; each stripe pass walks them in that
//!   order), so `MPUT [k=1, k=2]` always leaves `k=2`.
//! * **No cross-key atomicity** — keys route and apply independently;
//!   concurrent writers may interleave between a batch's keys.  The
//!   contract is per-key linearizability, exactly as if the client had
//!   pipelined singletons.
//!
//! Per-key failure isolation matches the singleton path: an invalid key,
//! a marooned (failed-shard) read, or one shard's failed round-trip each
//! answer `ERR` for their own keys only — the rest of the batch stands.
//! Keys still mid-migration peel off to the singleton dual-read /
//! dual-write path (same snapshot), so a batch never weakens the
//! migration contract.  The rebalancer rides the same machinery
//! (`rebalance::apply` batches its GET/PUTNX/DEL sweep per
//! (source, destination) pair), cutting migration round-trips by the
//! batch factor.
//!
//! ## Placement stack: weighted virtual buckets + hot-key cache
//!
//! Placement is a stack of composable layers (diagrammed in
//! [`crate::cluster`]): engine → optional
//! [`Weighted`](crate::algorithms::weighted::Weighted) adapter →
//! optional [`ReplicaMap`] → [`PlacementSnapshot`].  The router is
//! layer-agnostic — it holds a `Box<dyn ConsistentHasher>` and every
//! admin op forks it — with one weighted-only addition:
//! [`Router::set_weight`] changes a shard's weight through the
//! [`as_weighted_mut`](crate::algorithms::ConsistentHasher::as_weighted_mut)
//! hook (the weighted twin of the failover path's
//! `as_fault_tolerant_mut`) and migrates the affected key share through
//! the same publish → quiesce → sweep → settle machinery as a scale op.
//!
//! In front of the whole stack sits an optional fixed-capacity hot-key
//! LRU ([`cache::HotCache`], `[placement] hot_cache_keys`): singleton
//! GETs probe it before any shard I/O — values are `Arc<[u8]>`, so a
//! hit is a refcount bump, keeping the hit path allocation-free
//! (pinned by `rust/tests/zero_alloc.rs`).  Consistency rule: the
//! cache is **write-invalidated** (every PUT/DEL — singleton or
//! batched — invalidates its exact key after the shard write) and
//! **epoch-cleared** (every [`Router::publish`] clears it before the
//! new snapshot is visible, so a cached value never serves across a
//! migration settle, FAIL, RESTORE, weight change, or any other epoch
//! publish).  The stale-fill race between a GET's shard read and its
//! cache fill is closed by per-stripe generation counters — see
//! [`cache`]'s module docs.  `hot_hits`/`hot_evictions` and the
//! measured per-shard `load_factor` surface in `STATS`.
//!
//! ## Concurrency model: epoch snapshots + incremental migration
//!
//! Topology changes are serialized by an admin mutex and proceed in three
//! phases, none of which blocks GET/PUT/DEL:
//!
//! 1. **Publish** a new epoch whose snapshot routes with the *new* engine
//!    — a [`ConsistentHasher::fork`](crate::algorithms::ConsistentHasher::fork)
//!    of the current one with the bucket added/removed — and carries a
//!    [`MigrationOrigin`] (a fork of the old engine), enabling dual-read:
//!    a GET that misses on a key's new owner retries the old owner.  PUTs
//!    land on the new owner and retire the old copy; DELs tombstone the
//!    new owner (`DELTOMB`) and remove the old copy.
//! 2. **Quiesce** the superseded snapshot (wait for its in-flight readers
//!    to drain; readers hold a snapshot only for one request, so this
//!    settles in microseconds), then run the incremental migration:
//!    stream every source shard stripe-by-stripe and move keys in bounded
//!    batches ([`rebalance::migrate_streaming`]), optionally planning
//!    batches on the PJRT bulk artifacts.
//! 3. **Settle**: publish the same epoch without the origin (and, on
//!    scale-down, without the retiring shard handle), then purge the
//!    migration tombstones.
//!
//! Snapshot hold-time contract: the data path holds a snapshot for one
//! shard call.  Aggregations that fan out over possibly-remote shards
//! (`COUNT`, [`Router::shard_count`]) clone the shard handles and drop
//! the snapshot *before* any I/O, so a slow shard can never stall a
//! concurrent scale op at its quiesce barrier.
//!
//! Because each epoch's engine is forked from the previous one, every
//! registered engine scales; engines without exact minimal disruption
//! (maglev, the modulo anti-baseline) scan every shard on scale-down
//! ([`ConsistentHasher::minimal_disruption`](crate::algorithms::ConsistentHasher::minimal_disruption)).
//! The copy step (`PUTNX`) cannot clobber a newer client write, and the
//! `DELTOMB` tombstone bars it from resurrecting a key whose DEL raced
//! the migration sweep.
//!
//! ## Failover: steady → degraded → restored (or rescaled)
//!
//! LIFO scaling retires the *tail* shard after draining it; real shards
//! die in arbitrary positions with their data still on them.  The
//! fault-tolerant engines (anchor, dx, memento) already place around
//! arbitrary holes; [`Router::fail_shard`] and [`Router::restore_shard`]
//! (wire ops `FAIL <id>` / `RESTORE <id>`) drive that capability through
//! the same epoch-snapshot machinery:
//!
//! * **FAIL** forks the live engine, reaches its
//!   [`FaultTolerant`](crate::algorithms::FaultTolerant) surface through
//!   [`as_fault_tolerant_mut`](crate::algorithms::ConsistentHasher::as_fault_tolerant_mut)
//!   (the hook that survives the type-erasing `fork`), applies
//!   `remove_arbitrary(id)`, and publishes a **degraded** epoch — O(1)
//!   engine work, no shard I/O, no quiesce wait (a reader stuck on the
//!   dying shard must not delay the failover that routes around it).
//!   The dead shard's handle stays in the snapshot (bucket ids never
//!   shift) but [`PlacementSnapshot::is_failed`] bars every code path
//!   from contacting it: reads, dual-read fallbacks, mid-migration
//!   write-backs, COUNT/STATS fan-outs, tombstone purges and migration
//!   scans all skip it.  FAIL even composes with an in-flight migration:
//!   the origin engine gets the same arbitrary removal (so dual-read
//!   keeps working) and the dead shard is dropped from the remaining
//!   migration sources.
//! * **Degraded serving**: at `replication.factor` 1, keys whose
//!   pre-failure owner was the dead shard are *marooned* — there is no
//!   replica to fail over to.  A GET that misses and maps to a dead
//!   pre-failure owner answers a distinguishable `ERR UNAVAILABLE: …`
//!   instead of a silent `NIL` or a hang on a dead connection; a PUT
//!   makes the key immediately reachable again on its surviving owner.
//!   The factor-1 check is conservative: a key PUT-then-DELeted *while*
//!   degraded also reads `UNAVAILABLE` until the shard is restored.
//!   With factor R > 1 a degraded miss instead probes the key's live
//!   replicas — the current map first, then each failure's pre-removal
//!   engine — serves (and read-repairs) the surviving copy, and
//!   reserves `UNAVAILABLE` for the pigeonhole case: outstanding
//!   failures ≥ R, so every copy-holder may be dead.  That also
//!   un-falses the conservatism above — a key PUT-then-DELeted while
//!   degraded reads `NIL` (its live replicas agree it is gone).
//! * **RESTORE** wipes the rejoining shard (`WIPE` — it missed every
//!   write and delete while it was down, so its contents are
//!   unreconcilable), forks-and-`restore(id)`s the engine, and publishes
//!   the restored epoch *with a migration origin* (the degraded engine):
//!   keys written to survivors during the outage stream back to the
//!   restored shard in bounded batches while dual-read serves them, then
//!   the epoch settles.  The sweep is **anti-entropy**, not a blind
//!   re-stream: the restored shard's per-stripe content digests
//!   (`DIGEST`) are compared with each survivor's up front, and every
//!   already-converged `(source, stripe)` pair — including the common
//!   empty-stripe case — is skipped without a scan, so round-trips
//!   scale with the *divergent* stripes, not the survivor keyset.  With
//!   factor R > 1 the sweep also leaves the source copy in place
//!   whenever the source is one of the key's replicas under the
//!   restored engine (`Move::keep_source`), so a restore re-establishes
//!   replica coverage instead of thinning it.  Engines constrain
//!   restore order through
//!   [`restore_blocked`](crate::algorithms::FaultTolerant::restore_blocked)
//!   (anchor: reverse removal order) — violations answer `ERR`, never
//!   panic under the admin lock.
//! * **Scaling while degraded** is per-engine
//!   ([`grow_ready`](crate::algorithms::ConsistentHasher::grow_ready) /
//!   [`shrink_ready`](crate::algorithms::ConsistentHasher::shrink_ready)):
//!   dx grows at its frontier with holes outstanding (the scale composes
//!   with the failure; migration sources skip dead shards), while anchor
//!   and memento fail fast with the engine's own reason *and* the failed
//!   bucket list, so the operator knows exactly what to `RESTORE` first.
//!
//! ## Replication: top-R placement from the same engine
//!
//! With `replication.factor = R` (> 1), every key lives on its top-R
//! buckets — there is no separate replica ring.  Replica rank r is
//! derived by forking the engine with the primary (and prior replicas)
//! removed: for the fault-tolerant engines that is the *same*
//! per-failure fork the degraded path keeps, so after a FAIL a key's
//! new primary **is** its rank-1 replica and plain routing already
//! serves the surviving copy (pinned by
//! `ft_replica_matches_degraded_engine_construction` in
//! `cluster`).  The per-primary minus forks are precomputed at publish
//! time into a [`ReplicaMap`] carried by the snapshot, so the hot path
//! pays one extra engine lookup per replica and allocates nothing at
//! factor 1.
//!
//! Consistency contract (deliberately primary-ack, not quorum-commit):
//!
//! * **Writes ack on the primary.**  PUT/DEL apply to the primary
//!   exactly as at factor 1 (including the mid-migration dual-write),
//!   then fan out to the R−1 replicas — batched frames re-group the
//!   replica writes per shard like the primary fan-out.  Under the
//!   default `write_mode = "primary"` a replica failure is counted
//!   (`replica_write_failures`) and left for read repair or the next
//!   restore sweep; under `"all"` it fails the request (the primary
//!   copy still landed).
//! * **Reads are primary-first**: one probe in steady state, identical
//!   to factor 1.  Only a degraded miss fans out to replicas, and a
//!   replica hit is written back to the current primary
//!   (`read_repairs`) so the next read is one probe again.
//! * **No cross-key or cross-copy atomicity.**  Each copy applies
//!   independently; a degraded reader racing a write may observe a
//!   replica's older value until the fan-out lands.  Replica sets are
//!   maintained by writes, read repair, and the restore sweep — scale
//!   migrations relocate primaries only, so a topology change thins
//!   replica coverage until subsequent writes restore it.  Orphaned
//!   copies left by a topology change are inert (readers derive
//!   copy-holders from engines, never from scans) but count in
//!   `COUNT`, which reports reachable *copies*, not unique keys, when
//!   R > 1.

pub mod cache;

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::algorithms::ConsistentHasher;
use crate::cluster::{
    bucket_csv as csv, Cluster, DegradedState, EventKind, MigrationOrigin, PlacementSnapshot,
    ReplicaMap, TopologyEvent,
};
use crate::metrics::{ConnMetrics, RouterMetrics};
use crate::net::{self, Server, ServerOpts, Service};
use crate::proto::{self, BatchOp, BatchSource, Request, RequestRef, Response, Value};
use crate::rebalance::{self, MigrationStats, PlanPath};
use crate::runtime::PlacementRuntime;
use crate::shard::{Shard, ShardClient};
use crate::sync::cell::SnapshotCell;
use crate::sync::{Arc, AtomicU64, Backoff, Mutex, Ordering};

/// Shard factory used on scale-up.
pub type ShardSpawner = Box<dyn Fn(u32) -> ShardClient + Send + Sync>;

/// Reusable scratch for [`Router::handle_batch`]: the per-key digest
/// table, the (bucket, index) grouping order, and the per-fan-out
/// selection — allocated once per connection (or per caller), reused
/// across batches, so a steady stream of batches allocates nothing here.
#[derive(Debug, Default)]
pub struct BatchScratch {
    /// `digests[i]` = xxhash64 of key `i` (0 for invalid keys, which
    /// never route).
    digests: Vec<u64>,
    /// `buckets[i]` = owner bucket of key `i` under the snapshot engine,
    /// filled by one `bucket_batch` call over the whole digest column
    /// (invalid keys carry the digest-0 placement, which is never read).
    buckets: Vec<u32>,
    /// Steady keys packed as `bucket << 32 | index`; sorted to group.
    order: Vec<u64>,
    /// The current fan-out's key indices (one owner shard's share).
    sel: Vec<u32>,
    /// Mid-migration keys deferred to the singleton dual-read/dual-write
    /// path (run after the placement phase, so their shard round-trips
    /// never pollute the placement-latency histogram).
    defer: Vec<u32>,
    /// Replica-write grouping for factor > 1 batches, packed like
    /// `order` (`bucket << 32 | index`, one word per replica copy).
    rep_order: Vec<u64>,
    /// The current primary group's accepted-write digests, batched
    /// through the rank-1 minus fork (factor-2 replica derivation).
    rep_digests: Vec<u64>,
    /// The rank-1 buckets `bucket_batch` computed for `rep_digests`.
    rep_buckets: Vec<u32>,
    /// Replica fan-out responses — positional like `out`, but kept
    /// separate so replica answers are only error-accounted and never
    /// clobber the client's sub-responses.
    rep_out: Vec<Response>,
}

impl BatchScratch {
    /// New empty scratch.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Keys per migration batch: small enough that a batch is visible to
/// readers almost immediately, large enough to amortize planning.
const MIGRATION_BATCH: usize = 512;

/// Smallest batch worth routing through the PJRT bulk runtime: below
/// this the mutex + host/device transfer costs more than the in-process
/// lane-parallel kernel saves.
const PJRT_BATCH_MIN: usize = 64;

/// Buckets in `0..slots` the engine reports as not working.  Derived from
/// the engine itself (not the snapshot's degraded record) so it is
/// correct even for a router constructed directly over a pre-degraded
/// engine.
fn failed_buckets(engine: &dyn ConsistentHasher, slots: usize) -> Vec<u32> {
    match engine.as_fault_tolerant() {
        None => Vec::new(),
        Some(ft) => (0..slots as u32).filter(|&b| !ft.is_working(b)).collect(),
    }
}

/// Append the top-`factor` copy-holders of `digest` under `engine`
/// (primary first, then replicas) — the same minus-fork construction
/// [`ReplicaMap`] precomputes, run on demand against a *historic*
/// engine.  Slow path only (forks per call): degraded misses probing a
/// failure's pre-removal topology.
fn holders_under(
    engine: &dyn ConsistentHasher,
    digest: u64,
    factor: u32,
    out: &mut Vec<u32>,
) {
    let mut cur = engine.fork();
    let mut b = cur.bucket(digest);
    out.push(b);
    for _ in 1..factor {
        if cur.len() <= 1 {
            break;
        }
        let Some(ft) = cur.as_fault_tolerant_mut() else {
            break;
        };
        ft.remove_arbitrary(b);
        b = cur.bucket(digest);
        out.push(b);
    }
}

/// The one operator-facing rejection for scale/restore ops blocked by a
/// degraded engine: names the engine, the engine's own reason, and the
/// failed buckets, so the operator sees exactly which bucket to
/// `RESTORE` (previously two near-identical strings that named neither).
fn scale_rejection(engine: &dyn ConsistentHasher, slots: usize, reason: &str) -> anyhow::Error {
    let failed = failed_buckets(engine, slots);
    if failed.is_empty() {
        anyhow!("engine {:?} cannot scale: {reason}", engine.name())
    } else {
        anyhow!(
            "engine {:?} cannot scale: {reason} (failed buckets: {}; RESTORE them first)",
            engine.name(),
            csv(&failed)
        )
    }
}

// The snapshot cell shares `PlacementSnapshot` across threads through a
// raw pointer — outside the compiler's auto-trait reasoning for this
// struct — so pin the bound the cell requires (`SnapshotCell<T>` is
// `Send + Sync` iff `T` is, via its `PhantomData<Arc<T>>`).
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<PlacementSnapshot>();
};

/// The router: published placement snapshot + metrics + optional XLA bulk
/// runtime.
pub struct Router {
    /// Current snapshot, published through the lock-free
    /// [`SnapshotCell`] (atomic `Arc` swap with a generation-validated
    /// reader gate — the protocol lives, documented and model-checked,
    /// in [`crate::sync::cell`]).
    current: SnapshotCell<PlacementSnapshot>,
    /// Serializes topology changes and owns the event log. The data path
    /// never touches this; `SCALEUP`/`SCALEDOWN` take it with `try_lock`
    /// and answer `ERR MIGRATING` when a change is already in flight.
    admin: Mutex<Vec<TopologyEvent>>,
    /// Request/latency counters.
    pub metrics: RouterMetrics,
    /// Connection-layer counters, shared with the serving
    /// [`net::Server`] so `STATS` reports accepted/active/dropped
    /// connections, readiness wakeups, partial flushes, and
    /// backpressure-deferred reads.
    pub conns: Arc<ConnMetrics>,
    /// Bulk placement runtime for rebalance planning (None = Rust path).
    /// Serialized behind a mutex — see the Send safety note in `runtime`.
    bulk: Option<Mutex<PlacementRuntime>>,
    spawn_shard: ShardSpawner,
    /// Copies per key (`replication.factor`); 1 = replication off.
    factor: u32,
    /// `write_mode = "all"`: a replica write error fails the request
    /// instead of being absorbed into `replica_write_failures`.
    write_all: bool,
    /// Hot-key LRU in front of shard I/O (`[placement] hot_cache_keys`;
    /// `None` = off).  Write-invalidated, cleared on every publish —
    /// see the placement-stack section of the module docs.
    hot: Option<cache::HotCache>,
}

impl Router {
    /// Router over an existing cluster, spawning in-process shards on
    /// scale-up.
    pub fn new(cluster: Cluster) -> Arc<Self> {
        Self::with_options(cluster, Box::new(|id| ShardClient::Local(Shard::new(id))), None)
    }

    /// Router with a custom shard factory and/or bulk runtime
    /// (replication off).
    pub fn with_options(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
    ) -> Arc<Self> {
        Self::with_replication(cluster, spawn_shard, bulk, 1, false)
    }

    /// Router with replication: every key lives on its top-`factor`
    /// buckets (see the module docs' replication section).  `write_all`
    /// maps the config's `write_mode = "all"` — replica write errors
    /// fail the request instead of being absorbed into
    /// `replica_write_failures`.
    pub fn with_replication(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
        factor: u32,
        write_all: bool,
    ) -> Arc<Self> {
        Self::with_placement(cluster, spawn_shard, bulk, factor, write_all, 0)
    }

    /// Router with the full placement-stack knobs: replication plus the
    /// hot-key cache (`hot_cache_keys` keys; 0 = off).  The cluster's
    /// engine may itself be a
    /// [`Weighted`](crate::algorithms::weighted::Weighted) stack — the
    /// router is layer-agnostic except for [`set_weight`](Self::set_weight).
    pub fn with_placement(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
        factor: u32,
        write_all: bool,
        hot_cache_keys: usize,
    ) -> Arc<Self> {
        let factor = factor.max(1);
        let (mut snapshot, events) = cluster.into_snapshot();
        snapshot.replicas =
            ReplicaMap::build(snapshot.engine.as_ref(), snapshot.shards.len(), factor);
        Arc::new(Self {
            current: SnapshotCell::new(snapshot),
            admin: Mutex::new(events),
            metrics: RouterMetrics::new(),
            conns: Arc::new(ConnMetrics::new()),
            bulk: bulk.map(Mutex::new),
            spawn_shard,
            factor,
            write_all,
            hot: cache::HotCache::new(hot_cache_keys),
        })
    }

    /// The current placement snapshot: one atomic pointer load plus a
    /// refcount bump — no lock, no allocation, never blocks on a
    /// migration.
    ///
    /// Hold-time contract: drop the handle promptly (one request's worth
    /// of work). Scale operations wait for superseded snapshots' readers
    /// to drain before deleting migrated source copies, so a handle held
    /// across blocking work stalls — not corrupts — topology changes.
    pub fn snapshot(&self) -> Arc<PlacementSnapshot> {
        // The generation-validated reader gate lives in
        // `sync::cell::SnapshotCell` — see its docs for the covered-
        // reader argument and `rust/tests/model.rs` for the schedules
        // that check it.
        self.current.load()
    }

    /// Publish a new snapshot: swap the cell's pointer, advance its
    /// generation, drain the superseded generation's reader slot, then
    /// release the superseded snapshot's stored count (in-flight readers
    /// keep it alive via their own counts until they drop).
    ///
    /// Callers are serialized by the admin mutex, so at most one drain is
    /// in flight and the cell's two gate slots strictly alternate.
    fn publish(&self, mut snapshot: PlacementSnapshot) {
        // Every published topology derives its replica map here,
        // centrally, from the engine it routes with — construction
        // sites leave `replicas: None`.
        snapshot.replicas =
            ReplicaMap::build(snapshot.engine.as_ref(), snapshot.shards.len(), self.factor);
        // The hot-key cache never serves across an epoch publish: clear
        // it (bumping every stripe generation, so in-flight fills that
        // read their shard under the old epoch drop themselves) before
        // the new snapshot becomes visible.  This one choke point
        // covers scale settle, FAIL, RESTORE, and weight changes.
        if let Some(hot) = &self.hot {
            hot.clear();
        }
        drop(self.current.store(snapshot));
    }

    /// Wait until no in-flight request still routes with `snap` (all
    /// reader clones dropped). After a publish no new reader can acquire
    /// it, and readers hold a snapshot only for the duration of one shard
    /// call, so this normally settles in microseconds; [`Backoff`] ramps
    /// from busy-spin through `yield_now` to bounded sleeps so a reader
    /// stuck behind a slow remote shard doesn't burn a core here.
    fn quiesce(snap: &Arc<PlacementSnapshot>) {
        let mut backoff = Backoff::new();
        while Arc::strong_count(snap) > 1 {
            backoff.snooze();
        }
    }

    /// Current `(epoch, n, algorithm)`.
    pub fn topology(&self) -> (u64, u32, &'static str) {
        let snap = self.snapshot();
        (snap.epoch, snap.engine.len(), snap.engine.name())
    }

    /// Topology events recorded so far.
    pub fn events(&self) -> Vec<TopologyEvent> {
        self.admin.lock().unwrap().clone()
    }

    /// Key count on one shard (telemetry; used by examples/benches).
    pub fn shard_count(&self, bucket: u32) -> Result<u64> {
        // Clone the handle and drop the snapshot before the (possibly
        // remote, slow) COUNT round-trip — see the hold-time contract.
        let shard = {
            let snap = self.snapshot();
            ensure!((bucket as usize) < snap.shards.len(), "bucket {bucket} out of range");
            ensure!(!snap.is_failed(bucket), "UNAVAILABLE: shard {bucket} is failed");
            snap.shards[bucket as usize].clone()
        };
        shard.count()
    }

    /// Handle one data/admin request end-to-end (owned form; the server
    /// loop and the zero-allocation fast path go through
    /// [`handle_ref`](Self::handle_ref)).
    pub fn handle(&self, req: Request) -> Response {
        self.handle_ref(req.as_view())
    }

    /// Handle one data/admin request end-to-end without taking ownership
    /// of the key.  Steady-state GET/PUT/DEL through here is allocation-
    /// and lock-free (one atomic snapshot load, digest reuse in the local
    /// shard call, `Arc` value sharing).
    ///
    /// Batch frames answer [`Response::Multi`] through transient scratch;
    /// callers with a request stream (the server loop, benches) use
    /// [`handle_batch`](Self::handle_batch) with reused scratch instead.
    pub fn handle_ref(&self, req: RequestRef<'_>) -> Response {
        let req = match req.into_batch() {
            Ok((op, batch)) => {
                let mut out = Vec::new();
                self.handle_batch(op, &batch, &mut BatchScratch::new(), &mut out);
                return Response::Multi(out);
            }
            Err(req) => req,
        };
        let start = Instant::now();
        let resp = match req {
            RequestRef::Get { key } => self.data_get(key),
            RequestRef::Put { key, value } => self.data_put(key, value),
            RequestRef::Del { key } => self.data_del(key),
            // COUNT sums every *reachable* shard. The handles are cloned
            // and the snapshot dropped before any shard I/O so a slow
            // shard cannot stall a concurrent scale op's quiesce barrier;
            // failed shards are skipped (a dead connection would hang the
            // whole aggregation), so a degraded COUNT reports the
            // reachable keyset only.  Mid-migration a key sits on both
            // owners between the copy and the source delete, so the total
            // can transiently over-report by up to one batch.
            RequestRef::Count => {
                let shards: Vec<ShardClient> = {
                    let snap = self.snapshot();
                    snap.shards
                        .iter()
                        .enumerate()
                        .filter(|(b, _)| !snap.is_failed(*b as u32))
                        .map(|(_, s)| s.clone())
                        .collect()
                };
                let mut total = 0u64;
                let mut err = None;
                for s in &shards {
                    match s.count() {
                        Ok(x) => total += x,
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Response::Num(total),
                    Some(e) => Response::Err(e.to_string()),
                }
            }
            RequestRef::Stats => {
                let snap = self.snapshot();
                let state = if snap.is_migrating() {
                    "migrating"
                } else if snap.is_degraded() {
                    "degraded"
                } else {
                    "steady"
                };
                // Remote-pool timeout tallies live on the pools, not in
                // RouterMetrics (a pool outlives snapshots and is shared
                // by clones); sum them over the current shard set.
                let remote_timeouts: u64 = snap
                    .shards
                    .iter()
                    .map(|s| match s {
                        ShardClient::Remote(pool) => pool.timeouts(),
                        _ => 0,
                    })
                    .sum();
                Response::Info(format!(
                    "epoch={} n={} shards={} algo={} state={} failed={} load_factor={:.3} {} {} remote_timeouts={}",
                    snap.epoch,
                    snap.engine.len(),
                    snap.shards.len(),
                    snap.engine.name(),
                    state,
                    match &snap.degraded {
                        Some(d) => d.failed_csv(),
                        None => "-".to_string(),
                    },
                    // Measured max/mean routed-op share over the shard
                    // slots (1.0 = perfectly even; see stats::theory for
                    // the algorithmic ceiling).
                    self.metrics.routed.load_factor(snap.shards.len() as u32),
                    self.metrics.summary(),
                    self.conns.summary(),
                    remote_timeouts
                ))
            }
            RequestRef::Scan
            | RequestRef::ScanStripe { .. }
            | RequestRef::PutNx { .. }
            | RequestRef::DelTomb { .. }
            | RequestRef::PurgeTombs
            | RequestRef::Wipe
            | RequestRef::Digest => Response::Err("shard-internal command".into()),
            RequestRef::ScaleUp => match self.scale_up() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            RequestRef::ScaleDown => match self.scale_down() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            RequestRef::Fail { shard } => match self.fail_shard(shard) {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            RequestRef::Restore { shard } => match self.restore_shard(shard) {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            RequestRef::MGet { .. }
            | RequestRef::MPut { .. }
            | RequestRef::MPutNx { .. }
            | RequestRef::MDel { .. }
            | RequestRef::MDelTomb { .. } => unreachable!("batches split off above"),
        };
        if matches!(resp, Response::Err(_)) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        }
        self.metrics.latency.record(start.elapsed());
        resp
    }

    /// Validate a key, count the op, and return its digest.
    fn admit(&self, key: &str, counter: &AtomicU64) -> Result<u64, Response> {
        if !proto::valid_key(key) {
            return Err(Response::Err(format!("invalid key {key:?}")));
        }
        counter.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(crate::hashing::xxhash64(key.as_bytes(), 0))
    }

    /// The distinguishable degraded-read answer: the key's data sits on a
    /// failed shard, so a miss on the surviving owner is *not* "absent".
    fn unavailable(&self, key: &str, failed: u32) -> Response {
        self.metrics.unavailable.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Response::Err(format!(
            "UNAVAILABLE: key {key} is marooned on failed shard {failed}; \
             RESTORE {failed} (it rejoins empty) or re-PUT the key"
        ))
    }

    /// Fan an accepted write out to the key's replica buckets (no-op at
    /// factor 1).  Replica errors are counted and the first is returned
    /// so `write_mode = "all"` can surface it; under the default
    /// primary-ack mode the caller drops it — a degraded read falls
    /// back to whichever copies did land, and the next restore sweep
    /// repairs the rest.
    fn replicate(
        &self,
        snap: &PlacementSnapshot,
        key: &str,
        value: Option<&Value>,
        digest: u64,
        primary: u32,
    ) -> Option<String> {
        snap.replicas.as_ref()?;
        let mut replicas = Vec::new();
        snap.replicas_into(digest, primary, &mut replicas);
        let mut first_err = None;
        for &b in &replicas {
            let r = match value {
                Some(v) => snap.shards[b as usize]
                    .call_ref(RequestRef::Put { key, value: v.clone() }, Some(digest)),
                None => {
                    snap.shards[b as usize].call_ref(RequestRef::Del { key }, Some(digest))
                }
            };
            self.metrics.replica_writes.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            let err = match r {
                Ok(Response::Err(e)) => Some(e),
                Err(e) => Some(e.to_string()),
                Ok(_) => None,
            };
            if let Some(e) = err {
                self.metrics.replica_write_failures.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                if first_err.is_none() {
                    first_err = Some(format!("replica {b}: {e}"));
                }
            }
        }
        first_err
    }

    /// A degraded GET that missed its primary: probe the key's surviving
    /// replica copies before deciding between `NIL` and `UNAVAILABLE`.
    ///
    /// Probe order: the current replica map first (O(1) engine
    /// lookups), then — because a copy written under an older topology
    /// may sit on a bucket the current map no longer names — the
    /// replica set under each failure's pre-removal engine (on-demand
    /// forks; this path only runs on a degraded miss, never in steady
    /// state).  A hit is served and written back to the current primary
    /// (read repair), so the next read for the key is one probe again.
    ///
    /// The all-miss verdict: `UNAVAILABLE` only when the outstanding
    /// failures could have swallowed every copy (failed count ≥ factor,
    /// the pigeonhole bound — factor 1 keeps the original behavior of
    /// treating any marooned miss as unavailable); otherwise a live
    /// member of every copy-holder set was consulted and the key is
    /// genuinely absent: `NIL`.  That retires the factor-1 false
    /// `UNAVAILABLE` for a key PUT-then-DELeted while degraded (pinned
    /// in `rust/tests/failover.rs`).
    fn degraded_miss(&self, snap: &PlacementSnapshot, key: &str, digest: u64) -> Response {
        if snap.replicas.is_some() {
            let primary = snap.engine.bucket(digest);
            let mut holders: Vec<u32> = Vec::new();
            snap.replicas_into(digest, primary, &mut holders);
            if let Some(deg) = &snap.degraded {
                for (engine, _) in &deg.maroons {
                    holders_under(engine.as_ref(), digest, self.factor, &mut holders);
                }
            }
            let mut probed: Vec<u32> = Vec::new();
            for &b in &holders {
                if b == primary
                    || b as usize >= snap.shards.len()
                    || snap.is_failed(b)
                    || probed.contains(&b)
                {
                    continue;
                }
                probed.push(b);
                if let Ok(Response::Val(v)) =
                    snap.shards[b as usize].call_ref(RequestRef::Get { key }, Some(digest))
                {
                    self.metrics.replica_reads.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                    let repaired = snap.shards[primary as usize]
                        .call_ref(RequestRef::Put { key, value: v.clone() }, Some(digest));
                    if matches!(repaired, Ok(Response::Ok)) {
                        self.metrics.read_repairs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                    }
                    return Response::Val(v);
                }
            }
        }
        match snap.marooned(digest) {
            Some(f)
                if snap.degraded.as_ref().map_or(0, |d| d.failed.len()) as u32
                    >= self.factor =>
            {
                self.unavailable(key, f)
            }
            _ => Response::Nil,
        }
    }

    fn data_get(&self, key: &str) -> Response {
        let digest = match self.admit(key, &self.metrics.gets) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        // Hot-key cache probe before any placement or shard I/O: a hit
        // is an `Arc` refcount bump (allocation-free — pinned by
        // zero_alloc.rs).  Safe to answer without consulting the
        // snapshot because the cache is write-invalidated and cleared
        // on every epoch publish, so an entry can only exist for the
        // current topology and the current value.
        if let Some(hot) = &self.hot {
            if let Some(v) = hot.get(digest, key) {
                self.metrics.hot_hits.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                return Response::Val(v);
            }
        }
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        self.metrics.routed.record(bucket);
        // Record the stripe generation *before* the shard read; a fill
        // whose generation was superseded by a concurrent write or
        // publish is dropped inside `fill` (see cache's module docs).
        let gen = self.hot.as_ref().map(|h| h.generation(digest));
        let resp = self.get_routed(&snap, key, digest, bucket, shard);
        if let (Some(hot), Some(gen), Response::Val(v)) = (&self.hot, gen, &resp) {
            if hot.fill(digest, key, v, gen) {
                self.metrics.hot_evictions.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            }
        }
        resp
    }

    /// The GET core after admission and routing — shared by the singleton
    /// path and the batch path's mid-migration keys.
    fn get_routed(
        &self,
        snap: &PlacementSnapshot,
        key: &str,
        digest: u64,
        bucket: u32,
        shard: &ShardClient,
    ) -> Response {
        let resp = match snap.fallback_route(digest, bucket) {
            // Mid-migration, the key may not have reached its new owner
            // yet: dual-read, new owner then old owner — and if both miss,
            // re-probe the new owner once.  Copies always land new-first
            // (PUTNX/PUT before the source DEL), so a key that vanished
            // from the old owner between our two probes is already
            // readable on the new one; the third probe closes that window.
            Some((old_bucket, old_shard)) => {
                match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                    // The old owner died mid-migration (FAIL composed
                    // into an in-flight sweep): the un-migrated copy is
                    // marooned there — never dial a dead shard.
                    Ok(Response::Nil) if snap.is_failed(old_bucket) => {
                        return self.unavailable(key, old_bucket);
                    }
                    Ok(Response::Nil) => {
                        self.metrics.dual_reads.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                        match old_shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                            Ok(Response::Nil) => {
                                match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                                    Ok(resp) => resp,
                                    Err(e) => Response::Err(e.to_string()),
                                }
                            }
                            Ok(resp) => resp,
                            Err(e) => Response::Err(e.to_string()),
                        }
                    }
                    Ok(resp) => resp,
                    Err(e) => Response::Err(e.to_string()),
                }
            }
            None => match shard.call_ref(RequestRef::Get { key }, Some(digest)) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        };
        // A miss while degraded may be a marooned key (its pre-failure
        // owner is dead) or one whose surviving copy sits on a replica
        // — free on healthy snapshots.
        if matches!(resp, Response::Nil) && snap.is_degraded() {
            return self.degraded_miss(snap, key, digest);
        }
        resp
    }

    fn data_put(&self, key: &str, value: Value) -> Response {
        let digest = match self.admit(key, &self.metrics.puts) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        self.metrics.routed.record(bucket);
        self.put_routed(&snap, key, value, digest, bucket, shard)
    }

    /// The PUT core after admission and routing — shared by the singleton
    /// path and the batch path's mid-migration keys.
    fn put_routed(
        &self,
        snap: &PlacementSnapshot,
        key: &str,
        value: Value,
        digest: u64,
        bucket: u32,
        shard: &ShardClient,
    ) -> Response {
        let resp = match snap.fallback_route(digest, bucket) {
            // Mid-migration: write the new owner, then retire the old copy
            // so neither the migration sweep nor a dual-read can resurface
            // a stale value.  The old-copy delete is best-effort: once the
            // new owner holds the value, reads route there first and the
            // migration sweep (PUTNX) cannot clobber it, so a cleanup
            // failure must not turn a durable write into a client error —
            // and it is skipped entirely when the old owner is a failed
            // shard (its copy is unreachable either way, and it rejoins
            // only after a WIPE).
            Some((old_bucket, old_shard)) => {
                let resp = match shard
                    .call_ref(RequestRef::Put { key, value: value.clone() }, Some(digest))
                {
                    Ok(resp) => resp,
                    Err(e) => return Response::Err(e.to_string()),
                };
                if !snap.is_failed(old_bucket) {
                    let _ = old_shard.call_ref(RequestRef::Del { key }, Some(digest));
                }
                resp
            }
            None => match shard
                .call_ref(RequestRef::Put { key, value: value.clone() }, Some(digest))
            {
                Ok(resp) => resp,
                Err(e) => return Response::Err(e.to_string()),
            },
        };
        // The shard write is done — drop the cached copy *now* (after
        // the write, so a concurrent miss-fill either predates this
        // invalidation's generation bump or observes the new value).
        if let Some(hot) = &self.hot {
            hot.invalidate(digest, key);
        }
        // The primary copy landed; fan out to the replicas (no-op at
        // factor 1 — the `Value` clone above is an `Arc` refcount bump,
        // not an allocation).
        match self.replicate(snap, key, Some(&value), digest, bucket) {
            Some(err) if self.write_all => Response::Err(err),
            _ => resp,
        }
    }

    fn data_del(&self, key: &str) -> Response {
        let digest = match self.admit(key, &self.metrics.dels) {
            Ok(d) => d,
            Err(resp) => return resp,
        };
        let t0 = Instant::now();
        let snap = self.snapshot();
        let (bucket, shard) = snap.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        self.metrics.routed.record(bucket);
        self.del_routed(&snap, key, digest, bucket, shard)
    }

    /// The DEL core after admission and routing — shared by the singleton
    /// path and the batch path's mid-migration keys.
    fn del_routed(
        &self,
        snap: &PlacementSnapshot,
        key: &str,
        digest: u64,
        bucket: u32,
        shard: &ShardClient,
    ) -> Response {
        let resp = match snap.fallback_route(digest, bucket) {
            // Mid-migration: the key may live on either owner — delete
            // both; it existed if either copy did.  The new-owner delete
            // leaves a tombstone so an in-flight migration copy (PUTNX)
            // of this key cannot resurrect it after the delete wins the
            // race; the tombstones are purged when the migration settles.
            // A failed old owner is never dialed: its copy can only
            // resurface through a RESTORE, which wipes it first, so the
            // delete is vacuously complete there.
            Some((old_bucket, old_shard)) => {
                let new_r = shard.call_ref(RequestRef::DelTomb { key }, Some(digest));
                let old_r = if snap.is_failed(old_bucket) {
                    Ok(Response::Nil)
                } else {
                    old_shard.call_ref(RequestRef::Del { key }, Some(digest))
                };
                match (new_r, old_r) {
                    (Ok(Response::Ok), Ok(_)) | (Ok(_), Ok(Response::Ok)) => Response::Ok,
                    (Ok(resp), Ok(_)) => resp,
                    (Err(e), _) | (_, Err(e)) => Response::Err(e.to_string()),
                }
            }
            None => match shard.call_ref(RequestRef::Del { key }, Some(digest)) {
                Ok(resp) => resp,
                Err(e) => Response::Err(e.to_string()),
            },
        };
        // Shard deletes are done — drop the cached copy (same ordering
        // argument as the PUT path).
        if let Some(hot) = &self.hot {
            hot.invalidate(digest, key);
        }
        // Deletes always fan out, whatever the primary answered — a
        // replica may hold a copy the primary never saw (e.g. written
        // before a failover moved the primary), and a surviving stale
        // copy would resurface through a later degraded read.
        match self.replicate(snap, key, None, digest, bucket) {
            Some(err) if self.write_all => Response::Err(err),
            _ => resp,
        }
    }

    /// Handle one keybatch end to end with caller-reused scratch: compute
    /// every placement up front, group the keys by owner bucket, issue
    /// **one fan-out per owner shard** (a stripe-grouped in-process run
    /// locally, a single `MULTI` round-trip remotely), and leave the
    /// positional sub-responses in `out` — `out[i]` answers key `i`, in
    /// request order, whatever the grouping did internally.
    ///
    /// Semantics per key are exactly the singleton ops':
    ///
    /// * a key still mid-migration leaves the fan-out and runs the
    ///   singleton dual-read / dual-write path with this same snapshot;
    /// * while degraded, a missing key marooned on a failed bucket
    ///   answers its per-key `ERR UNAVAILABLE: …` without poisoning the
    ///   rest of the batch;
    /// * an invalid key answers its per-key `ERR`; a failed shard
    ///   round-trip answers `ERR` for that shard's keys only.
    ///
    /// There is **no cross-key atomicity**: each key routes and applies
    /// independently, and concurrent writers may interleave between a
    /// batch's keys — the guarantee is per-key linearizability plus
    /// in-batch order for duplicate keys (they share an owner and a
    /// stripe, and every grouping stage is order-preserving within a
    /// group).  Steady-state local batches through here are
    /// allocation-free once `scratch`/`out` are warm (pinned by
    /// `rust/tests/zero_alloc.rs`).
    ///
    /// The shard-internal ops (`PutNx`, `DelTomb`) are rejected per key,
    /// like their singleton forms.
    pub fn handle_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        src: &S,
        scratch: &mut BatchScratch,
        out: &mut Vec<Response>,
    ) {
        let start = Instant::now();
        let n = src.len();
        out.clear();
        out.resize(n, Response::Nil);
        if matches!(op, BatchOp::PutNx | BatchOp::DelTomb) {
            for slot in out.iter_mut() {
                *slot = Response::Err("shard-internal command".into());
            }
            self.metrics.errors.fetch_add(n as u64, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            self.metrics.latency.record(start.elapsed());
            return;
        }
        // Phase 1 — place every key up front: digest the column, then
        // one [`ConsistentHasher::bucket_batch`] call over the whole
        // batch (the binomial engine's lane-parallel kernel; the PJRT
        // runtime when one is loaded), then pack each steady key as
        // (bucket << 32 | index) — one in-place sort groups the batch
        // by owner while keeping request order inside each group.
        // Mid-migration keys are only *marked* here; their per-key shard
        // round-trips run after the placement timer stops, so the
        // placement histogram keeps measuring placement, not I/O.
        let snap = self.snapshot();
        let t0 = Instant::now();
        scratch.digests.clear();
        scratch.order.clear();
        scratch.defer.clear();
        let mut valid = 0u64;
        for i in 0..n {
            let key = src.key(i);
            if !proto::valid_key(key) {
                // The only Err sub-responses that exist this early, so
                // the routing loop below skips exactly these keys.
                out[i] = Response::Err(format!("invalid key {key:?}"));
                scratch.digests.push(0);
                continue;
            }
            valid += 1;
            scratch.digests.push(crate::hashing::xxhash64(key.as_bytes(), 0));
        }
        self.place_batch(&snap, &scratch.digests, &mut scratch.buckets);
        for i in 0..n {
            if matches!(out[i], Response::Err(_)) {
                continue; // invalid key — its placeholder placement is dead
            }
            let digest = scratch.digests[i];
            let bucket = scratch.buckets[i];
            self.metrics.routed.record(bucket);
            if snap.fallback_route(digest, bucket).is_some() {
                scratch.defer.push(i as u32);
                continue;
            }
            scratch.order.push(((bucket as u64) << 32) | i as u64);
        }
        self.metrics.placement_latency.record(t0.elapsed());
        // Only admitted (valid) keys count, exactly like singleton admit().
        match op {
            BatchOp::Get => {
                self.metrics.gets.fetch_add(valid, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                self.metrics.mget_keys.fetch_add(valid, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            }
            BatchOp::Put => {
                self.metrics.puts.fetch_add(valid, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                self.metrics.mput_keys.fetch_add(valid, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            }
            BatchOp::Del => {
                self.metrics.dels.fetch_add(valid, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            }
            BatchOp::PutNx | BatchOp::DelTomb => unreachable!("rejected above"),
        }

        // Mid-migration keys: exact singleton dual-read/dual-write
        // semantics, with this same snapshot.
        for &i in scratch.defer.iter() {
            let i = i as usize;
            let key = src.key(i);
            let digest = scratch.digests[i];
            let (bucket, shard) = snap.route(digest);
            out[i] = match op {
                BatchOp::Get => self.get_routed(&snap, key, digest, bucket, shard),
                BatchOp::Put => {
                    self.put_routed(&snap, key, src.value(i), digest, bucket, shard)
                }
                BatchOp::Del => self.del_routed(&snap, key, digest, bucket, shard),
                BatchOp::PutNx | BatchOp::DelTomb => unreachable!(),
            };
        }

        // Phase 2 — one fan-out per owner shard, ascending bucket order.
        scratch.order.sort_unstable();
        let mut g = 0usize;
        while g < scratch.order.len() {
            let bucket = (scratch.order[g] >> 32) as u32;
            scratch.sel.clear();
            while g < scratch.order.len() && (scratch.order[g] >> 32) as u32 == bucket {
                scratch.sel.push(scratch.order[g] as u32);
                g += 1;
            }
            self.metrics.batch_fanouts.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
            let shard = &snap.shards[bucket as usize];
            if let Err(e) = shard.call_batch(op, &scratch.sel, src, &scratch.digests, out) {
                // One shard failing its round-trip poisons only its own
                // keys; the other groups' answers stand.
                let msg = e.to_string();
                for &i in scratch.sel.iter() {
                    out[i as usize] = Response::Err(msg.clone());
                }
            }
        }

        // Batched writes invalidate the hot-key cache exactly like
        // singletons, after their shard fan-out.  Conservative: every
        // admitted write key is invalidated, whatever its shard
        // answered (an over-invalidation is always safe; the deferred
        // keys were already invalidated inside put_routed/del_routed).
        if matches!(op, BatchOp::Put | BatchOp::Del) {
            if let Some(hot) = &self.hot {
                for &w in scratch.order.iter() {
                    let i = w as u32 as usize;
                    hot.invalidate(scratch.digests[i], src.key(i));
                }
            }
        }

        // Phase 2b — replica fan-out for writes (factor > 1): every key
        // whose primary write was accepted is packed again by *replica*
        // bucket and fanned out with the same per-shard grouping.  The
        // replica answers land in `rep_out` — error-accounted, never
        // clobbering the client's positional sub-responses (except
        // under `write_mode = "all"`, where a replica failure fails its
        // key).
        if matches!(op, BatchOp::Put | BatchOp::Del) && snap.replicas.is_some() {
            scratch.rep_order.clear();
            let mut reps: Vec<u32> = Vec::new();
            // `order` is already sorted by primary bucket, so the keys
            // arrive in primary groups — and at factor 2 on a
            // fault-tolerant engine each group's whole replica set is
            // one `bucket_batch` call through that primary's
            // precomputed minus fork.  Deeper ranks (factor > 2) and
            // probe engines keep the per-key derivation.
            let mut g = 0usize;
            while g < scratch.order.len() {
                let bucket = (scratch.order[g] >> 32) as u32;
                scratch.sel.clear();
                scratch.rep_digests.clear();
                while g < scratch.order.len() && (scratch.order[g] >> 32) as u32 == bucket {
                    let i = scratch.order[g] as u32;
                    g += 1;
                    if matches!(out[i as usize], Response::Err(_)) {
                        continue; // the primary write failed — nothing to replicate
                    }
                    scratch.sel.push(i);
                    scratch.rep_digests.push(scratch.digests[i as usize]);
                }
                if let Some(m1) = snap.rank1_batch_engine(bucket) {
                    scratch.rep_buckets.clear();
                    scratch.rep_buckets.resize(scratch.rep_digests.len(), 0);
                    m1.bucket_batch(&scratch.rep_digests, &mut scratch.rep_buckets);
                    for (&i, &rb) in scratch.sel.iter().zip(scratch.rep_buckets.iter()) {
                        scratch.rep_order.push(((rb as u64) << 32) | i as u64);
                    }
                } else {
                    for &i in scratch.sel.iter() {
                        reps.clear();
                        snap.replicas_into(scratch.digests[i as usize], bucket, &mut reps);
                        for &rb in &reps {
                            scratch.rep_order.push(((rb as u64) << 32) | i as u64);
                        }
                    }
                }
            }
            scratch.rep_order.sort_unstable();
            scratch.rep_out.clear();
            scratch.rep_out.resize(n, Response::Nil);
            let mut g = 0usize;
            while g < scratch.rep_order.len() {
                let bucket = (scratch.rep_order[g] >> 32) as u32;
                scratch.sel.clear();
                while g < scratch.rep_order.len()
                    && (scratch.rep_order[g] >> 32) as u32 == bucket
                {
                    scratch.sel.push(scratch.rep_order[g] as u32);
                    g += 1;
                }
                self.metrics
                    .replica_writes
                    .fetch_add(scratch.sel.len() as u64, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                let shard = &snap.shards[bucket as usize];
                match shard.call_batch(op, &scratch.sel, src, &scratch.digests, &mut scratch.rep_out)
                {
                    Ok(()) => {
                        for &i in scratch.sel.iter() {
                            if let Response::Err(e) = &scratch.rep_out[i as usize] {
                                self.metrics
                                    .replica_write_failures
                                    .fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                                if self.write_all {
                                    out[i as usize] =
                                        Response::Err(format!("replica {bucket}: {e}"));
                                }
                            }
                        }
                    }
                    Err(e) => {
                        self.metrics
                            .replica_write_failures
                            .fetch_add(scratch.sel.len() as u64, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                        if self.write_all {
                            let msg = format!("replica {bucket}: {e}");
                            for &i in scratch.sel.iter() {
                                out[i as usize] = Response::Err(msg.clone());
                            }
                        }
                    }
                }
            }
        }

        // Phase 3 — degraded read check: a miss whose pre-failure owner
        // is dead is marooned or replica-served, not absent (free on
        // healthy snapshots; per-key slow-path answers already ran this
        // check, and re-running `degraded_miss` on them is idempotent).
        if op == BatchOp::Get && snap.is_degraded() {
            for i in 0..n {
                if matches!(out[i], Response::Nil) {
                    out[i] = self.degraded_miss(&snap, src.key(i), scratch.digests[i]);
                }
            }
        }

        let errors = out.iter().filter(|r| matches!(r, Response::Err(_))).count() as u64;
        if errors > 0 {
            self.metrics.errors.fetch_add(errors, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        }
        self.metrics.latency.record(start.elapsed());
    }

    /// Clear migration tombstones on every *reachable* shard (idempotent;
    /// called once a migration settles, and defensively before a new one
    /// starts).  Failed shards are skipped — a dead connection must not
    /// block an admin op, and a failed shard is wiped (keys *and*
    /// tombstones) before it can rejoin anyway.
    fn purge_tombstones(snap: &PlacementSnapshot) -> Result<()> {
        for (b, s) in snap.shards.iter().enumerate() {
            if !snap.is_failed(b as u32) {
                s.purge_tombstones()?;
            }
        }
        Ok(())
    }

    /// Add a shard and incrementally migrate exactly the keys that now
    /// belong to it, serving reads and writes throughout.  Returns the new
    /// *working* shard count.
    ///
    /// Composes with a degraded topology when the engine's growth does
    /// ([`ConsistentHasher::grow_ready`]): dx grows at its frontier with
    /// holes outstanding, anchor/memento answer a clean `ERR` naming the
    /// buckets to restore.  Dead shards are excluded from the migration
    /// scan — keys marooned on them stay marooned (and keep answering
    /// `UNAVAILABLE`) across the scale.
    pub fn scale_up(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base)?;
        // The shard list covers every assigned bucket id (working or
        // failed); the joining handle lands at its tail.  On a healthy
        // topology this equals the working count.
        let n_slots = base.shards.len() as u32;
        let n_work = base.engine.len();
        // Fail fast — nothing is mutated or published for an engine at
        // its pre-allocated capacity (anchor's anchor set, dx's NSArray);
        // `add_bucket` would panic mid-change otherwise.
        if let Some(cap) = base.engine.max_buckets() {
            ensure!(
                n_work < cap,
                "engine {:?} is at its capacity of {cap} buckets; cannot scale up",
                base.engine.name()
            );
        }
        // Per-engine degraded-scaling hint: reject (naming the engine's
        // reason and the failed buckets) before anything is mutated or
        // published, instead of panicking in add_bucket.
        base.engine
            .grow_ready()
            .map_err(|reason| scale_rejection(&*base.engine, n_slots as usize, &reason))?;
        // The next epoch's engine is a fork of the live one with the new
        // bucket added; the origin keeps an unmodified fork for dual-read
        // and migration planning.  No engine is rebuilt from its name, so
        // stateful engines carry their full state across the change.
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let added = new_engine.add_bucket();
        // The new shard handle is pushed at index n_slots, so the engine
        // must have grown at the assignment frontier.  An engine that
        // grew elsewhere would route the "new" bucket to the wrong
        // handle; the mutated fork is discarded and nothing has been
        // published.
        ensure!(
            added == n_slots,
            "engine {:?} added bucket {added} instead of the frontier {n_slots}; \
             scale aborted before publishing{}",
            base.engine.name(),
            match failed_buckets(&*base.engine, n_slots as usize) {
                f if f.is_empty() => String::new(),
                f => format!(" (failed buckets: {}; RESTORE them first)", csv(&f)),
            }
        );

        let mut shards = base.shards.clone();
        let joining = (self.spawn_shard)(n_slots);
        // A joining shard may be a reconnection to a remote process with
        // leftover state (e.g. retired earlier after a best-effort purge
        // failed); clear its tombstones before any migration copy can be
        // refused by them.  Failing here is still pre-publish.
        joining.purge_tombstones()?;
        shards.push(joining);
        // Monotonicity: any reachable old shard may hold keys that now
        // belong to the joining bucket, so all of them are migration
        // sources; dead shards cannot be scanned.
        let sources: Vec<u32> = (0..n_slots).filter(|&b| !base.is_failed(b)).collect();
        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: shards.clone(),
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources,
                settle_len: shards.len(),
                ae_dest: None,
            }),
            degraded: base.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Joined(n_slots),
            at: std::time::SystemTime::now(),
        });
        // No reader may still route with the pre-migration snapshot once
        // batches start deleting source copies (such a reader would have
        // no dual-read fallback); readers drain in microseconds.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
            degraded: migrating.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        // Drain dual-read holders of the migrating snapshot before
        // returning, so every future topology change only ever has one
        // live predecessor to quiesce — after which no request can still
        // be writing migration tombstones, and they can be purged.  The
        // scale op has fully settled by now, so a transient purge failure
        // must not turn it into a client error: stale tombstones are
        // harmless until the next migration, and the next scale op
        // re-purges (and fails fast there) before publishing anything.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(n_work + 1)
    }

    /// Remove the last shard after incrementally migrating its keys away,
    /// serving reads and writes throughout.  Returns the new *working*
    /// shard count.
    ///
    /// Composes with a degraded topology when the engine's shrink does
    /// ([`ConsistentHasher::shrink_ready`]): dx retires a working
    /// frontier bucket with holes outstanding, anchor/memento answer a
    /// clean `ERR` naming the buckets to restore.
    pub fn scale_down(&self) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base)?;
        let n_slots = base.shards.len() as u32;
        let n_work = base.engine.len();
        ensure!(n_work > 1, "cannot scale below one working shard");
        // Per-engine degraded-scaling hint (memento/dx would panic in
        // remove_bucket otherwise); reject up front with the engine's
        // reason and the failed bucket list.
        base.engine
            .shrink_ready()
            .map_err(|reason| scale_rejection(&*base.engine, n_slots as usize, &reason))?;
        let retiring = n_slots - 1;
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        let removed = new_engine.remove_bucket();
        // The shard list drops its tail index, so the engine must have
        // shrunk exactly there (a discarded fork; nothing published on
        // error).
        ensure!(
            removed == retiring,
            "engine {:?} removed bucket {removed} instead of the frontier {retiring}; \
             scale aborted before publishing{}",
            base.engine.name(),
            match failed_buckets(&*base.engine, n_slots as usize) {
                f if f.is_empty() => String::new(),
                f => format!(" (failed buckets: {}; RESTORE them first)", csv(&f)),
            }
        );
        // Minimal disruption: only the retiring shard's keys move, so it
        // is the sole migration source — a scale-down costs O(retiring
        // shard), not O(cluster keyset).  Engines without the exact
        // guarantee (maglev's table rebuild, modulo) also shuffle keys
        // between surviving shards, so every reachable shard must be
        // scanned (those engines are never degraded — they are not fault
        // tolerant — but the filter keeps the invariant explicit).
        let sources: Vec<u32> = if base.engine.minimal_disruption() {
            vec![retiring]
        } else {
            (0..n_slots).filter(|&b| !base.is_failed(b)).collect()
        };

        let epoch = base.epoch + 1;
        // The migrating snapshot routes with the new engine (never onto
        // the retiring shard) but keeps the full shard list so dual reads
        // still reach the retiring shard's keys.
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources,
                settle_len: retiring as usize,
                ae_dest: None,
            }),
            degraded: base.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Left(retiring),
            at: std::time::SystemTime::now(),
        });
        let mut shards = base.shards.clone();
        // Same hazard as scale-up: a reader still routing with the old
        // snapshot would miss keys whose source copy a batch just deleted.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        // Settle: drop the retiring shard handle.
        shards.truncate(retiring as usize);
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards,
            origin: None,
            degraded: migrating.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        // As in scale_up: drain dual-read holders, then purge the
        // tombstones their DELs may have written (best-effort — the op
        // has settled; the next scale op re-purges before publishing).
        // The retiring shard is included: a remote process outlives its
        // handle and could rejoin a later epoch carrying stale tombstones.
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(n_work - 1)
    }

    /// Fail shard `id` over: publish a degraded epoch whose engine has
    /// `remove_arbitrary(id)` applied to a fork of the live one, so no
    /// request ever routes to the dead shard again.  Returns the new
    /// *working* shard count.
    ///
    /// O(1) engine work and **zero shard I/O**: the shard is presumed
    /// dead, so nothing dials it — and unlike the scale ops there is no
    /// quiesce wait either (a reader already stuck on the dying shard
    /// must not delay the failover that routes around it; nothing here
    /// deletes data, so stale readers are safe).  The skipped quiesce
    /// narrows the "one live predecessor" chain the scale ops maintain:
    /// a pre-FAIL reader that somehow held its snapshot all the way into
    /// a *later* op's migration deletes could read a spurious miss — but
    /// that requires holding one snapshot across two admin ops, an
    /// extreme violation of the one-shard-call hold-time contract, and
    /// the window is memory-safe either way (the superseded `Arc` stays
    /// alive until its holders drop).  Keys whose data is on the dead
    /// shard become *marooned*: reads answer `UNAVAILABLE` until a
    /// RESTORE (or a re-PUT) supersedes them.
    ///
    /// Composes with an in-flight migration: the origin engine gets the
    /// same removal (dual-read keeps working, minus the dead shard) and
    /// the dead shard is dropped from the remaining migration sources —
    /// deliberately *without* resuming the sweep first, since the dead
    /// shard may be one of its sources.
    pub fn fail_shard(&self, id: u32) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.snapshot();
        let n_slots = base.shards.len() as u32;
        ensure!(id < n_slots, "shard {id} out of range (cluster has {n_slots} slots)");
        let ft_view = base.engine.as_fault_tolerant().ok_or_else(|| {
            anyhow!(
                "engine {:?} is not fault-tolerant (no arbitrary-removal support); \
                 FAIL/RESTORE need one of: anchor, dx, memento",
                base.engine.name()
            )
        })?;
        ensure!(
            ft_view.is_working(id),
            "shard {id} is not a working bucket of engine {:?} (failed buckets: {})",
            base.engine.name(),
            csv(&failed_buckets(&*base.engine, n_slots as usize))
        );
        ensure!(base.engine.len() > 1, "cannot fail the last working shard");

        let mut new_engine = base.engine.fork();
        new_engine
            .as_fault_tolerant_mut()
            .expect("fork keeps the fault-tolerant surface")
            .remove_arbitrary(id);
        let working = new_engine.len();

        // Compose with an in-flight migration (see doc comment).  The
        // origin engine may not know the bucket (interrupted scale-up of
        // the very shard that died) or may be down to one working bucket
        // — in both cases the removal is skipped and the data path's
        // `is_failed` check keeps the dead shard undialed.
        let origin = base.origin.as_ref().map(|o| {
            let mut old = o.engine.fork();
            if let Some(oft) = old.as_fault_tolerant_mut() {
                if oft.is_working(id) && old.len() > 1 {
                    oft.remove_arbitrary(id);
                }
            }
            MigrationOrigin {
                engine: old,
                sources: o.sources.iter().copied().filter(|&b| b != id).collect(),
                settle_len: o.settle_len,
                // An anti-entropy destination that died again cannot be
                // digest-polled; the resumed sweep falls back to full
                // streaming.
                ae_dest: o.ae_dest.filter(|&b| b != id),
            }
        });
        // The marooned record pairs this failure with the live engine as
        // of *just before* the removal — per-failure, so it stays
        // correct when the cluster scaled since an earlier failure (an
        // older engine could never name a bucket that joined after it).
        let degraded = Some(match &base.degraded {
            Some(d) => {
                let mut next = d.fork();
                next.failed.push(id);
                next.failed.sort_unstable();
                next.maroons.push((base.engine.fork(), id));
                next
            }
            None => DegradedState {
                failed: vec![id],
                maroons: vec![(base.engine.fork(), id)],
            },
        });

        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin,
            degraded,
            replicas: None,
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Failed(id),
            at: std::time::SystemTime::now(),
        });
        self.metrics.failovers.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(working)
    }

    /// Restore failed shard `id`: wipe it (it missed every write and
    /// delete while it was down — its contents are unreconcilable
    /// without replication), publish the restored epoch with the
    /// degraded engine as migration origin, and stream the keys written
    /// to survivors during the outage back onto it, serving reads and
    /// writes throughout.  Returns the new *working* shard count.
    ///
    /// Engines with restore-order constraints reject cleanly here
    /// ([`FaultTolerant::restore_blocked`](crate::algorithms::FaultTolerant::restore_blocked)
    /// — anchor restores in reverse removal order).
    pub fn restore_shard(&self, id: u32) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        // Unlike FAIL, a restore runs a migration, so an interrupted
        // sweep must settle first (its sources already exclude dead
        // shards, so the resume never dials one).
        let base = self.resume_interrupted(self.snapshot())?;
        let Some(deg) = &base.degraded else {
            bail!("no failed shards to restore (cluster is healthy)");
        };
        ensure!(
            deg.failed.binary_search(&id).is_ok(),
            "shard {id} is not failed (failed buckets: {})",
            deg.failed_csv()
        );

        let mut new_engine = base.engine.fork();
        {
            let ft = new_engine
                .as_fault_tolerant_mut()
                .expect("degraded engine must be fault-tolerant");
            if let Some(reason) = ft.restore_blocked(id) {
                bail!("cannot restore shard {id}: {reason}");
            }
            ft.restore(id);
        }
        let working = new_engine.len();

        // Pre-publish shard I/O, so a still-dead shard fails the RESTORE
        // cleanly before anything is mutated: wipe the rejoining shard,
        // then clear stale tombstones on every reachable survivor (the
        // restore migration's PUTNX copies must not be refused by
        // leftovers of an earlier sweep).
        base.shards[id as usize].wipe()?;
        Self::purge_tombstones(&base)?;

        let remaining: Vec<u32> = deg.failed.iter().copied().filter(|&b| b != id).collect();
        let degraded = if remaining.is_empty() {
            None
        } else {
            Some(DegradedState {
                failed: remaining,
                // Keys this failure marooned were wiped with the shard:
                // drop its marooned record, keep the other failures'.
                maroons: deg
                    .maroons
                    .iter()
                    .filter(|(_, b)| *b != id)
                    .map(|(e, b)| (e.fork(), *b))
                    .collect(),
            })
        };
        // Any reachable shard of the degraded topology may hold keys the
        // restored engine maps back to `id` (the replacement chains
        // scattered them); the rejoining shard itself is empty and the
        // still-failed ones cannot be scanned.
        let n_slots = base.shards.len() as u32;
        let sources: Vec<u32> =
            (0..n_slots).filter(|&b| b != id && !base.is_failed(b)).collect();

        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin: Some(MigrationOrigin {
                engine: base.engine.fork(),
                sources,
                settle_len: base.shards.len(),
                // The restore sweep converges on one wiped destination:
                // exactly the shape the per-stripe digest comparison
                // turns from a full survivor re-stream into round-trips
                // proportional to the divergent stripes.
                ae_dest: Some(id),
            }),
            degraded,
            replicas: None,
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Restored(id),
            at: std::time::SystemTime::now(),
        });
        // As in the scale ops: no reader may still route with the
        // pre-restore snapshot once batches start deleting survivor
        // copies (it would have no dual-read fallback onto `id`).
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards: migrating.shards.clone(),
            origin: None,
            degraded: migrating.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating);
        self.metrics.restores.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(working)
    }

    /// Change shard `id`'s weight on a weighted placement stack and
    /// incrementally migrate exactly the key share the reassignment
    /// moved, serving reads and writes throughout — the same publish →
    /// quiesce → sweep → settle machinery as a scale op (a weight
    /// change *is* a virtual-bucket add/remove on the inner engine).
    /// Returns the shard's new weight.
    ///
    /// Requires the cluster to have been built over
    /// [`Weighted`](crate::algorithms::weighted::Weighted) (reached via
    /// [`as_weighted_mut`](crate::algorithms::ConsistentHasher::as_weighted_mut),
    /// the hook that survives the type-erasing `fork`) and a healthy
    /// topology — the adapter rejects reweighting while shards are
    /// failed, with the failed buckets named in the error.
    pub fn set_weight(&self, id: u32, weight: u32) -> Result<u32> {
        let mut events = self
            .admin
            .try_lock()
            .map_err(|_| anyhow!("MIGRATING: a topology change is already in flight"))?;
        let base = self.resume_interrupted(self.snapshot())?;
        Self::purge_tombstones(&base)?;
        ensure!(
            base.engine.as_weighted().is_some(),
            "engine {:?} has no weight table; build the cluster with [placement] weights",
            base.engine.name()
        );
        let n_slots = base.shards.len() as u32;
        ensure!(id < n_slots, "shard {id} out of range (cluster has {n_slots} slots)");
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        new_engine
            .as_weighted_mut()
            .expect("fork keeps the weighted surface")
            .set_weight(id, weight)
            .map_err(|reason| {
                let failed = failed_buckets(&*base.engine, n_slots as usize);
                if failed.is_empty() {
                    anyhow!("cannot reweight shard {id}: {reason}")
                } else {
                    anyhow!(
                        "cannot reweight shard {id}: {reason} \
                         (failed buckets: {}; RESTORE them first)",
                        csv(&failed)
                    )
                }
            })?;
        // Unlike a LIFO scale, a weight change can hand virtual buckets
        // between *arbitrary* shards (the tail-reassignment trick), so
        // every reachable shard is a migration source.
        let sources: Vec<u32> = (0..n_slots).filter(|&b| !base.is_failed(b)).collect();
        let epoch = base.epoch + 1;
        self.publish(PlacementSnapshot {
            epoch,
            engine: new_engine,
            shards: base.shards.clone(),
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources,
                settle_len: base.shards.len(),
                ae_dest: None,
            }),
            degraded: base.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        events.push(TopologyEvent {
            epoch,
            kind: EventKind::Reweighted(id),
            at: std::time::SystemTime::now(),
        });
        // Same hazard as the scale ops: no reader may still route with
        // the pre-change snapshot once batches delete source copies.
        Self::quiesce(&base);
        drop(base);
        let migrating = self.snapshot();
        self.run_migration(&migrating)?;
        self.publish(PlacementSnapshot {
            epoch,
            engine: migrating.engine.fork(),
            shards: migrating.shards.clone(),
            origin: None,
            degraded: migrating.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        Self::quiesce(&migrating);
        let _ = Self::purge_tombstones(&migrating);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(weight)
    }

    /// Complete an interrupted migration: if a previous scale/restore op
    /// failed mid-sweep (e.g. a remote shard hiccup) the migrating
    /// snapshot is still published — dual-read keeps every key serveable
    /// — but the topology never settled.  Re-running the sweep is
    /// idempotent (PUTNX copies, source deletes of already-moved keys are
    /// no-ops), after which the snapshot settles normally.  Without this,
    /// a retried scale op would build a fresh origin from the stuck
    /// topology and strand never-migrated keys outside both routes.
    ///
    /// The settle shard count comes from the origin's recorded
    /// `settle_len`, *not* from `engine.len()`: on a degraded topology
    /// the working count is always below the slot count, and inferring
    /// the truncation from it would chop live shard handles (the
    /// resume-path twin of the scale paths' degraded guards — pinned by
    /// `resume_of_interrupted_degraded_migration_settles_safely`).
    fn resume_interrupted(
        &self,
        base: Arc<PlacementSnapshot>,
    ) -> Result<Arc<PlacementSnapshot>> {
        let Some(origin) = &base.origin else {
            return Ok(base);
        };
        let settle_len = origin.settle_len;
        debug_assert!(
            settle_len <= base.shards.len(),
            "settle_len beyond the migrating shard list"
        );
        self.run_migration(&base)?;
        let mut shards = base.shards.clone();
        shards.truncate(settle_len);
        self.publish(PlacementSnapshot {
            epoch: base.epoch,
            engine: base.engine.fork(),
            shards,
            origin: None,
            degraded: base.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });
        Self::quiesce(&base);
        drop(base);
        Ok(self.snapshot())
    }

    /// Stream-migrate everything the snapshot's origin still owns, in
    /// bounded batches, updating migration metrics.
    fn run_migration(&self, snap: &PlacementSnapshot) -> Result<MigrationStats> {
        let origin = snap.origin.as_ref().expect("run_migration needs a migrating snapshot");
        let stats = self.migrate_batches(snap, origin)?;
        self.metrics.migrated_keys.fetch_add(stats.moved, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.metrics.migration_batches.fetch_add(stats.batches, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.metrics.migration_round_trips.fetch_add(stats.round_trips, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.metrics.ae_stripes_skipped.fetch_add(stats.stripes_skipped, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        Ok(stats)
    }

    /// Place a whole digest column in one call, filling `out[i] =
    /// bucket(digests[i])`.
    ///
    /// Backend order: the PJRT bulk runtime when one is loaded, the
    /// batch is big enough to amortize the transfer ([`PJRT_BATCH_MIN`])
    /// and the active engine is the bare binomial (the compiled artifact
    /// computes BinomialHash placement — same gate as the migration
    /// planner's XLA path); otherwise the engine's own
    /// [`ConsistentHasher::bucket_batch`] (the lane-parallel kernel for
    /// binomial, the scalar loop elsewhere).  The PJRT call allocates
    /// its device output and is serialized behind the runtime mutex —
    /// fine for a bulk backend, which is why the offline default (no
    /// `bulk`) keeps the allocation-free in-process path.
    fn place_batch(&self, snap: &PlacementSnapshot, digests: &[u64], out: &mut Vec<u32>) {
        out.clear();
        out.resize(digests.len(), 0);
        if let (Some(bulk), "binomial") = (&self.bulk, snap.engine.name()) {
            if digests.len() >= PJRT_BATCH_MIN {
                let placed = bulk.lock().unwrap().lookup_batch(digests, snap.engine.len());
                if let Ok(buckets) = placed {
                    if buckets.len() == digests.len() {
                        out.copy_from_slice(&buckets);
                        return;
                    }
                }
                // Runtime hiccup: fall through to the in-process kernel.
            }
        }
        snap.engine.bucket_batch(digests, out);
    }

    fn migrate_batches(
        &self,
        snap: &PlacementSnapshot,
        origin: &MigrationOrigin,
    ) -> Result<MigrationStats> {
        // With replication on, a source that is itself one of the key's
        // replica holders under the *new* engine keeps its copy — the
        // move is a replication copy, not a relocation (the restore
        // sweep re-establishing coverage is the main beneficiary).
        let mark_replica_keeps = |plan: &mut rebalance::MigrationPlan| {
            if snap.replicas.is_none() {
                return;
            }
            let mut reps: Vec<u32> = Vec::new();
            for m in plan.moves.iter_mut() {
                reps.clear();
                snap.replicas_into(m.digest, m.to, &mut reps);
                if reps.contains(&m.from) {
                    m.keep_source = true;
                }
            }
        };
        // The XLA bulk path computes BinomialHash placement; use it only
        // when that is the active engine.
        if let (Some(bulk), "binomial") = (&self.bulk, snap.engine.name()) {
            let n_old = origin.engine.len();
            let n_new = snap.engine.len();
            let runtime = bulk.lock().unwrap();
            return rebalance::migrate_streaming(
                &snap.shards,
                &origin.sources,
                origin.ae_dest,
                MIGRATION_BATCH,
                |chunk| {
                    let mut plan =
                        rebalance::plan(chunk, PlanPath::Xla { runtime: &runtime, n_old, n_new })?;
                    mark_replica_keeps(&mut plan);
                    Ok(plan)
                },
            );
        }
        rebalance::migrate_streaming(
            &snap.shards,
            &origin.sources,
            origin.ae_dest,
            MIGRATION_BATCH,
            |chunk| {
                let mut plan = rebalance::plan(
                    chunk,
                    PlanPath::Engines { old: &*origin.engine, new: &*snap.engine },
                )?;
                mark_replica_keeps(&mut plan);
                Ok(plan)
            },
        )
    }

    /// Serve the router protocol on a TCP listener with the blocking
    /// personality (thread per connection) — the portable fallback; see
    /// [`Router::server`] for the epoll event server.
    pub fn serve(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        net::serve_blocking(self, listener)
    }

    /// Build a [`net::Server`] over this router: the readiness event
    /// server by default ([`ServerOpts::default`]), with the router's
    /// [`ConnMetrics`] attached so `STATS` reports connection counters.
    /// Call `.handle()` for graceful stop, then `.run()` (blocking) on a
    /// dedicated thread.
    pub fn server(self: Arc<Self>, listener: TcpListener, mut opts: ServerOpts) -> Result<Server<Router>> {
        opts.metrics = Some(Arc::clone(&self.conns));
        Server::new(self, listener, opts)
    }
}

/// Per-connection handler state for the router as a [`net::Service`]:
/// batch scratch plus the positional sub-response buffer — reused across
/// every request of one connection, never shared between connections.
#[derive(Debug, Default)]
pub struct RouterConnState {
    scratch: BatchScratch,
    subs: Vec<Response>,
}

impl Service for Router {
    type ConnState = RouterConnState;

    /// Borrowed parsing + coalesced responses; recoverable parse
    /// failures already answered `ERR` upstream (see `proto`).  Batches
    /// run through per-connection scratch, so a steady stream of
    /// MGET/MPUT frames reuses its buffers instead of allocating per
    /// batch.
    fn handle(&self, st: &mut RouterConnState, req: RequestRef<'_>, out: &mut Vec<u8>) -> Result<()> {
        match req.into_batch() {
            Ok((op, batch)) => {
                self.handle_batch(op, &batch, &mut st.scratch, &mut st.subs);
                proto::encode_multi_response(out, &st.subs)
            }
            Err(req) => proto::encode_response(out, &self.handle_ref(req)),
        }
    }
}

/// Build an in-process cluster: `n` local shards + the chosen engine.
pub fn local_cluster(algorithm: &str, n: u32) -> Result<Cluster> {
    let placement = crate::algorithms::by_name(algorithm, n)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algorithm:?}"))?;
    let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
    Ok(Cluster::new(placement, shards))
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    fn val(bytes: &[u8]) -> Value {
        bytes.to_vec().into()
    }

    #[test]
    fn put_get_del_roundtrip() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle(Request::Put { key: "a".into(), value: val(b"1") }),
            Response::Ok
        );
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Val(val(b"1")));
        assert_eq!(router.handle(Request::Del { key: "a".into() }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Nil);
    }

    #[test]
    fn borrowed_and_owned_paths_agree() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle_ref(RequestRef::Put { key: "b", value: val(b"2") }),
            Response::Ok
        );
        assert_eq!(router.handle(Request::Get { key: "b".into() }), Response::Val(val(b"2")));
        assert_eq!(router.handle_ref(RequestRef::Get { key: "b" }), Response::Val(val(b"2")));
        assert_eq!(router.handle_ref(RequestRef::Del { key: "b" }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "b".into() }), Response::Nil);
    }

    #[test]
    fn snapshot_swap_is_visible_and_refcounted() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let before = router.snapshot();
        assert_eq!(before.epoch, 0);
        // Publish a new snapshot while `before` is still held — exactly
        // what a scale op's publish phase does under in-flight readers.
        // (Not `scale_up()` here: that quiesces on outstanding handles
        // and would wait for `before`.)
        router.publish(PlacementSnapshot {
            epoch: before.epoch + 1,
            engine: before.engine.fork(),
            shards: before.shards.clone(),
            origin: None,
            degraded: None,
            replicas: None,
        });
        // The superseded handle stays valid after the swap...
        assert_eq!(before.epoch, 0);
        assert_eq!(before.engine.len(), 2);
        // ...and new loads see the published epoch.
        let after = router.snapshot();
        assert_eq!(after.epoch, 1);
        assert!(!Arc::ptr_eq(&before, &after));
        // Two loads of an unchanged snapshot share the allocation.
        assert!(Arc::ptr_eq(&after, &router.snapshot()));
        // `before` is now the only holder of the superseded snapshot.
        assert_eq!(Arc::strong_count(&before), 1);
    }

    #[test]
    fn scale_up_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Put { key: format!("k{i}"), value: val(&[i as u8]) }),
                Response::Ok
            );
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(val(&[i as u8])),
                "key k{i} lost after scale-up"
            );
        }
    }

    #[test]
    fn scale_down_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 5).unwrap());
        for i in 0..500 {
            router.handle(Request::Put { key: format!("k{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(val(&[i as u8])),
                "key k{i} lost after scale-down"
            );
        }
    }

    #[test]
    fn scale_cycle_with_jumpback_engine() {
        let router = Router::new(local_cluster("jumpback", 4).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("j{i}"), value: val(&[1]) });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("j{i}") }),
                Response::Val(val(&[1]))
            );
        }
    }

    #[test]
    fn scale_cycle_with_stateful_memento_engine() {
        let router = Router::new(local_cluster("memento", 3).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("s{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("s{i}") }),
                Response::Val(val(&[i as u8])),
                "key s{i} lost scaling a stateful engine"
            );
        }
    }

    #[test]
    fn maglev_scale_down_scans_all_shards() {
        // maglev lacks exact minimal disruption: on scale-down keys can
        // move between surviving shards, so the migration must scan every
        // shard, not just the retiring one.
        let router = Router::new(local_cluster("maglev", 4).unwrap());
        for i in 0..400 {
            router.handle(Request::Put { key: format!("m{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..400 {
            assert_eq!(
                router.handle(Request::Get { key: format!("m{i}") }),
                Response::Val(val(&[i as u8])),
                "key m{i} stranded after maglev scale-down"
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(400));
    }

    #[test]
    fn scaling_engine_at_capacity_is_rejected_without_mutation() {
        use crate::algorithms::anchor::AnchorHash;
        let shards = (0..3).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let cluster = Cluster::new(Box::new(AnchorHash::with_capacity(3, 3)), shards);
        let router = Router::new(cluster);
        let before = router.topology();
        assert!(matches!(router.handle(Request::ScaleUp), Response::Err(_)));
        assert_eq!(router.topology(), before, "failed scale must not mutate topology");
        assert_eq!(router.snapshot().shards.len(), 3);
    }

    #[test]
    fn scaling_with_outstanding_failures_is_rejected_without_mutation() {
        // Anchor's add_bucket would *restore* the failed bucket instead
        // of growing, and memento's asserts fire — for both, the router
        // must answer one clean ERR that names the engine and the failed
        // buckets, before mutating or publishing anything, and without
        // poisoning the admin mutex.  (dx is different: its growth
        // composes with failures — covered by
        // `dx_scales_while_degraded` in rust/tests/failover.rs.)
        use crate::algorithms::ConsistentHasher;
        use crate::algorithms::{anchor::AnchorHash, memento::MementoHash, FaultTolerant};
        let degraded: Vec<Box<dyn ConsistentHasher>> = vec![
            {
                let mut e = AnchorHash::with_capacity(4, 8);
                e.remove_arbitrary(1);
                Box::new(e)
            },
            {
                let mut e = MementoHash::new(4);
                e.remove_arbitrary(1);
                Box::new(e)
            },
        ];
        for engine in degraded {
            let name = engine.name();
            // `Cluster::new` pairs one handle per *working* bucket; a
            // directly-constructed degraded router is only used to probe
            // rejections, never to route.
            let shards = (0..engine.len()).map(|i| ShardClient::Local(Shard::new(i))).collect();
            let router = Router::new(Cluster::new(engine, shards));
            let before = router.topology();
            for req in [Request::ScaleUp, Request::ScaleDown] {
                match router.handle(req) {
                    Response::Err(msg) => {
                        assert!(
                            msg.contains(name),
                            "{name}: rejection must name the engine: {msg}"
                        );
                        assert!(
                            msg.contains("failed buckets: 1"),
                            "{name}: rejection must name the failed bucket: {msg}"
                        );
                        assert!(
                            msg.contains("RESTORE"),
                            "{name}: rejection must point at the fix: {msg}"
                        );
                    }
                    other => panic!("{name}: degraded scale must be rejected, got {other:?}"),
                }
            }
            assert_eq!(router.topology(), before, "{name}: failed scale mutated topology");
            // The admin mutex must not be poisoned by the rejection.
            assert!(router.events().is_empty(), "{name}: rejected scale logged an event");
        }
    }

    #[test]
    fn failover_on_non_fault_tolerant_engine_is_a_clean_err() {
        // The paper's core BinomialHash is LIFO-only; FAIL/RESTORE must
        // answer ERR without mutating or publishing anything.
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let before = router.topology();
        match router.handle(Request::Fail { shard: 1 }) {
            Response::Err(msg) => {
                assert!(msg.contains("not fault-tolerant"), "{msg}");
                assert!(msg.contains("binomial"), "{msg}");
            }
            other => panic!("expected ERR, got {other:?}"),
        }
        assert!(matches!(router.handle(Request::Restore { shard: 1 }), Response::Err(_)));
        assert_eq!(router.topology(), before);
        assert!(router.events().is_empty());
        assert_eq!(router.handle(Request::Count), Response::Num(0));
    }

    #[test]
    fn resume_of_interrupted_degraded_migration_settles_safely() {
        // A crash mid-sweep can leave a *degraded* migrating snapshot
        // (here: a dx scale-up composed with an outstanding failure).
        // The next admin op resumes it; the settle must truncate the
        // shard list to the origin's recorded `settle_len` — inferring
        // it from `engine.len()` (the working count, which sits below
        // the slot count while degraded) would chop the joining shard
        // right after the resumed sweep filled it.
        let router = Router::new(local_cluster("dx", 3).unwrap());
        for i in 0..200 {
            router.handle(Request::Put { key: format!("r{i}"), value: val(&[i as u8]) });
        }
        assert_eq!(router.handle(Request::Fail { shard: 1 }), Response::Num(2));

        // Freeze the moment mid-scale-up where the migrating epoch is
        // published but the sweep never ran (the "crash").
        let base = router.snapshot();
        let old_engine = base.engine.fork();
        let mut new_engine = base.engine.fork();
        assert_eq!(new_engine.add_bucket(), 3, "dx must grow at the frontier");
        let mut shards = base.shards.clone();
        shards.push(ShardClient::Local(Shard::new(3)));
        router.publish(PlacementSnapshot {
            epoch: base.epoch + 1,
            engine: new_engine,
            shards,
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources: vec![0, 2],
                settle_len: 4,
                ae_dest: None,
            }),
            degraded: base.degraded.as_ref().map(|d| d.fork()),
            replicas: None,
        });

        // The next admin op resumes the sweep, settles at 4 slots, then
        // performs its own change (retiring the joining bucket again).
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(2));
        let snap = router.snapshot();
        assert_eq!(snap.shards.len(), 3, "resume settled to the wrong shard list");
        assert!(!snap.is_migrating());
        assert!(snap.is_degraded());
        // Every key is either served correctly or marooned on the failed
        // shard — never silently lost by a mis-truncated settle.
        let mut marooned = 0;
        for i in 0..200 {
            match router.handle(Request::Get { key: format!("r{i}") }) {
                Response::Val(v) => assert_eq!(v, val(&[i as u8]), "r{i} corrupted"),
                Response::Err(msg) => {
                    assert!(msg.starts_with("UNAVAILABLE"), "r{i}: {msg}");
                    marooned += 1;
                }
                other => panic!("r{i}: {other:?}"),
            }
        }
        assert!(marooned > 0, "some keys must be marooned on failed shard 1");
        assert!(marooned < 200, "survivor keys must still be served");
    }

    #[test]
    fn del_during_migration_cannot_resurrect_key() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let old_engine = crate::algorithms::by_name("binomial", 2).unwrap();
        let new_engine = crate::algorithms::by_name("binomial", 3).unwrap();
        // A key that moves onto the joining bucket when scaling 2 -> 3.
        let key = (0..)
            .map(|i| format!("mv{i}"))
            .find(|k| {
                let d = crate::hashing::xxhash64(k.as_bytes(), 0);
                old_engine.bucket(d) != new_engine.bucket(d)
            })
            .unwrap();
        let d = crate::hashing::xxhash64(key.as_bytes(), 0);
        let (from, to) = (old_engine.bucket(d), new_engine.bucket(d));
        assert_eq!(
            router.handle(Request::Put { key: key.clone(), value: val(b"v") }),
            Response::Ok
        );

        // Freeze the moment mid-migration where the sweep has read the
        // source copy but not yet written it to the destination.
        let base = router.snapshot();
        let mut shards = base.shards.clone();
        shards.push(ShardClient::Local(Shard::new(2)));
        let copied = shards[from as usize].get(&key).unwrap().unwrap();
        router.publish(PlacementSnapshot {
            epoch: base.epoch + 1,
            engine: new_engine,
            shards: shards.clone(),
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources: vec![0, 1],
                settle_len: 3,
                ae_dest: None,
            }),
            degraded: None,
            replicas: None,
        });

        // The client DEL lands while the copy is in flight...
        assert_eq!(router.handle(Request::Del { key: key.clone() }), Response::Ok);
        // ...then the sweep's PUTNX arrives late and must be refused.
        assert!(!shards[to as usize].put_nx(&key, copied).unwrap());
        assert_eq!(
            router.handle(Request::Get { key: key.clone() }),
            Response::Nil,
            "DEL racing a migration copy resurrected the key"
        );
    }

    #[test]
    fn batched_ops_roundtrip_and_reassemble_in_order() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        let keys: Vec<String> = (0..96).map(|i| format!("mb{i}")).collect();
        let values: Vec<Value> = (0..96).map(|i| val(&[i as u8])).collect();
        match router.handle(Request::MPut { keys: keys.clone(), values }) {
            Response::Multi(subs) => {
                assert_eq!(subs.len(), 96);
                assert!(subs.iter().all(|r| *r == Response::Ok));
            }
            other => panic!("{other:?}"),
        }
        // Positional answers across every owner shard, misses included.
        let mut probe = keys.clone();
        probe.insert(40, "absent-a".into());
        probe.push("absent-b".into());
        match router.handle(Request::MGet { keys: probe.clone() }) {
            Response::Multi(subs) => {
                assert_eq!(subs.len(), 98);
                for (i, (k, sub)) in probe.iter().zip(&subs).enumerate() {
                    match k.strip_prefix("mb") {
                        Some(num) => assert_eq!(
                            *sub,
                            Response::Val(val(&[num.parse::<u8>().unwrap()])),
                            "position {i}"
                        ),
                        None => assert_eq!(*sub, Response::Nil, "position {i}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
        // Per-key invalid keys answer ERR without poisoning the batch.
        match router.handle(Request::MGet {
            keys: vec!["mb0".into(), "bad key".into(), "mb1".into()],
        }) {
            Response::Multi(subs) => {
                assert_eq!(subs[0], Response::Val(val(&[0])));
                assert!(matches!(subs[1], Response::Err(_)));
                assert_eq!(subs[2], Response::Val(val(&[1])));
            }
            other => panic!("{other:?}"),
        }
        // MDEL answers per key, and the batch path shows up in metrics.
        match router.handle(Request::MDel { keys: vec!["mb0".into(), "ghost".into()] }) {
            Response::Multi(subs) => assert_eq!(subs, vec![Response::Ok, Response::Nil]),
            other => panic!("{other:?}"),
        }
        assert!(router.metrics.mget_keys.load(Ordering::Relaxed) >= 98); // ord: test-only
        assert!(router.metrics.mput_keys.load(Ordering::Relaxed) == 96); // ord: test-only
        // 4 shards, several batches: at least one fan-out per owner
        // group, and never more than one per (batch, shard).
        let fanouts = router.metrics.batch_fanouts.load(Ordering::Relaxed); // ord: test-only
        assert!((1..=12).contains(&fanouts), "fanouts={fanouts}");
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("mget_keys="), "{s}");
                assert!(s.contains("batch_fanouts="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batched_shard_internal_ops_rejected_per_key() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        for req in [
            Request::MPutNx { keys: vec!["k".into()], values: vec![val(&[1])] },
            Request::MDelTomb { keys: vec!["k".into()] },
        ] {
            match router.handle(req) {
                Response::Multi(subs) => {
                    assert_eq!(subs.len(), 1);
                    assert!(matches!(subs[0], Response::Err(_)));
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn batched_gets_dual_read_mid_migration_keys() {
        // Freeze a mid-scale-up snapshot where nothing has migrated yet:
        // every key still sits on its old owner.  A batched GET must
        // dual-read exactly like singletons — every key readable.
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let keys: Vec<String> = (0..200).map(|i| format!("dm{i}")).collect();
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(
                router.handle(Request::Put { key: k.clone(), value: val(&[i as u8]) }),
                Response::Ok
            );
        }
        let base = router.snapshot();
        let old_engine = crate::algorithms::by_name("binomial", 2).unwrap();
        let new_engine = crate::algorithms::by_name("binomial", 3).unwrap();
        let mut shards = base.shards.clone();
        shards.push(ShardClient::Local(Shard::new(2)));
        router.publish(PlacementSnapshot {
            epoch: base.epoch + 1,
            engine: new_engine,
            shards,
            origin: Some(MigrationOrigin {
                engine: old_engine,
                sources: vec![0, 1],
                settle_len: 3,
                ae_dest: None,
            }),
            degraded: None,
            replicas: None,
        });
        match router.handle(Request::MGet { keys: keys.clone() }) {
            Response::Multi(subs) => {
                for (i, sub) in subs.iter().enumerate() {
                    assert_eq!(*sub, Response::Val(val(&[i as u8])), "dm{i} mid-migration");
                }
            }
            other => panic!("{other:?}"),
        }
        assert!(
            router.metrics.dual_reads.load(Ordering::Relaxed) > 0, // ord: test-only
            "no key exercised the dual-read fallback"
        );
        // Batched writes land on the new owner and batched deletes
        // tombstone it, so the migration sweep cannot resurrect them.
        match router.handle(Request::MDel { keys: keys.clone() }) {
            Response::Multi(subs) => {
                assert!(subs.iter().all(|r| *r == Response::Ok), "a delete missed");
            }
            other => panic!("{other:?}"),
        }
        match router.handle(Request::MGet { keys }) {
            Response::Multi(subs) => {
                assert!(subs.iter().all(|r| *r == Response::Nil));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batches_roundtrip_the_router_wire_mixed_with_singletons() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        // Pipeline: MPUT, singleton GET, MGET, bad frame, MDEL — one
        // burst, answered in order, connection kept alive throughout.
        let mut burst = Vec::new();
        proto::write_request(
            &mut burst,
            &Request::MPut {
                keys: vec!["w0".into(), "w1".into(), "w2".into()],
                values: vec![val(b"a"), val(b"b"), val(b"c")],
            },
        )
        .unwrap();
        proto::write_request(&mut burst, &Request::Get { key: "w1".into() }).unwrap();
        proto::write_request(
            &mut burst,
            &Request::MGet { keys: vec!["w2".into(), "nope".into(), "w0".into()] },
        )
        .unwrap();
        burst.extend_from_slice(b"MGET 99 onlyone\n");
        proto::write_request(&mut burst, &Request::MDel { keys: vec!["w0".into()] }).unwrap();
        wr.write_all(&burst).unwrap();
        wr.flush().unwrap();

        assert_eq!(
            proto::read_response(&mut rd).unwrap(),
            Response::Multi(vec![Response::Ok, Response::Ok, Response::Ok])
        );
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"b")));
        assert_eq!(
            proto::read_response(&mut rd).unwrap(),
            Response::Multi(vec![
                Response::Val(val(b"c")),
                Response::Nil,
                Response::Val(val(b"a"))
            ])
        );
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        assert_eq!(
            proto::read_response(&mut rd).unwrap(),
            Response::Multi(vec![Response::Ok])
        );
    }

    #[test]
    fn epochs_advance_and_settle() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert_eq!(router.topology().0, 0);
        router.scale_up().unwrap();
        assert_eq!(router.topology().0, 1);
        assert!(!router.snapshot().is_migrating(), "scale_up must settle before returning");
        router.scale_down().unwrap();
        assert_eq!(router.topology().0, 2);
        assert_eq!(router.events().len(), 2);
    }

    #[test]
    fn stats_reports_topology() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("n=2"));
                assert!(s.contains("algo=binomial"));
                assert!(s.contains("state=steady"));
                assert!(s.contains("epoch=0"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn invalid_key_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(
            router.handle(Request::Get { key: "bad key".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn shard_internal_commands_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(router.handle(Request::Scan), Response::Err(_)));
        assert!(matches!(
            router.handle(Request::ScanStripe { stripe: 0 }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::PutNx { key: "k".into(), value: val(&[1]) }),
            Response::Err(_)
        ));
        assert!(matches!(
            router.handle(Request::DelTomb { key: "k".into() }),
            Response::Err(_)
        ));
        assert!(matches!(router.handle(Request::PurgeTombs), Response::Err(_)));
        assert!(matches!(router.handle(Request::Wipe), Response::Err(_)));
        assert!(matches!(router.handle(Request::Digest), Response::Err(_)));
    }

    fn replicated_router(algorithm: &str, n: u32, factor: u32, write_all: bool) -> Arc<Router> {
        Router::with_replication(
            local_cluster(algorithm, n).unwrap(),
            Box::new(|id| ShardClient::Local(Shard::new(id))),
            None,
            factor,
            write_all,
        )
    }

    #[test]
    fn replicated_writes_fan_out_and_deletes_clear_replicas() {
        let router = replicated_router("memento", 4, 2, false);
        for i in 0..64 {
            assert_eq!(
                router.handle(Request::Put { key: format!("rw{i}"), value: val(&[i as u8]) }),
                Response::Ok
            );
        }
        // Every key is on exactly two shards — COUNT reports copies.
        assert_eq!(router.handle(Request::Count), Response::Num(128));
        assert_eq!(router.metrics.replica_writes.load(Ordering::Relaxed), 64); // ord: test-only
        assert_eq!(router.metrics.replica_write_failures.load(Ordering::Relaxed), 0); // ord: test-only
        // The copy sits exactly where the snapshot's replica map says.
        let snap = router.snapshot();
        for i in 0..64 {
            let key = format!("rw{i}");
            let d = crate::hashing::xxhash64(key.as_bytes(), 0);
            let p = snap.engine.bucket(d);
            let r = snap.first_replica(d, p).unwrap();
            assert_ne!(p, r, "{key}: replica collides with primary");
            assert!(
                snap.shards[r as usize].get(&key).unwrap().is_some(),
                "{key}: replica copy missing on {r}"
            );
        }
        drop(snap);
        // DEL fans out too: no stale replica copies survive.
        for i in 0..64 {
            assert_eq!(router.handle(Request::Del { key: format!("rw{i}") }), Response::Ok);
        }
        assert_eq!(router.handle(Request::Count), Response::Num(0));
        match router.handle(Request::Stats) {
            Response::Info(s) => assert!(s.contains("replica_writes="), "{s}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batched_replicated_writes_group_per_replica_shard() {
        let router = replicated_router("memento", 4, 2, false);
        let keys: Vec<String> = (0..80).map(|i| format!("br{i}")).collect();
        let values: Vec<Value> = (0..80).map(|i| val(&[i as u8])).collect();
        match router.handle(Request::MPut { keys: keys.clone(), values }) {
            Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
            other => panic!("{other:?}"),
        }
        assert_eq!(router.handle(Request::Count), Response::Num(160));
        assert_eq!(router.metrics.replica_writes.load(Ordering::Relaxed), 80); // ord: test-only
        match router.handle(Request::MDel { keys }) {
            Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
            other => panic!("{other:?}"),
        }
        assert_eq!(router.handle(Request::Count), Response::Num(0));
    }

    #[test]
    fn factor_one_routers_never_build_a_replica_map() {
        let router = Router::new(local_cluster("memento", 3).unwrap());
        assert!(router.snapshot().replicas.is_none());
        router.handle(Request::Put { key: "solo".into(), value: val(b"1") });
        assert_eq!(router.metrics.replica_writes.load(Ordering::Relaxed), 0); // ord: test-only
        assert_eq!(router.handle(Request::Count), Response::Num(1));
        // Topology changes keep it off.
        router.scale_up().unwrap();
        assert!(router.snapshot().replicas.is_none());
    }

    #[test]
    fn replica_map_tracks_topology_changes() {
        let router = replicated_router("memento", 3, 2, false);
        assert_eq!(router.snapshot().replicas.as_ref().map(ReplicaMap::factor), Some(2));
        router.scale_up().unwrap();
        let snap = router.snapshot();
        let map = snap.replicas.as_ref().expect("replica map after scale");
        assert_eq!(map.factor(), 2);
        // The rebuilt map derives from the scaled engine: replicas can
        // name the new bucket.
        let named: std::collections::BTreeSet<u32> = (0..512)
            .filter_map(|i| {
                let d = crate::hashing::splitmix64(i);
                snap.first_replica(d, snap.engine.bucket(d))
            })
            .collect();
        assert!(named.contains(&3), "new bucket never chosen as a replica: {named:?}");
    }

    #[test]
    fn empty_values_survive_routing_and_migration() {
        // The zero-length payload edge (`PUT k 0`) end to end: store,
        // read, migrate across a scale cycle, and read again — an empty
        // `Arc<[u8]>` must behave exactly like any other value.
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let empty: Value = Vec::new().into();
        for i in 0..64 {
            assert_eq!(
                router.handle(Request::Put { key: format!("ev{i}"), value: empty.clone() }),
                Response::Ok
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(3));
        for i in 0..64 {
            assert_eq!(
                router.handle(Request::Get { key: format!("ev{i}") }),
                Response::Val(empty.clone()),
                "empty value ev{i} lost in migration"
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
    }

    #[test]
    fn empty_values_roundtrip_the_router_wire() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        let empty: Value = Vec::new().into();
        proto::write_request(&mut wr, &Request::Put { key: "e".into(), value: empty.clone() })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "e".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(empty));
        // The connection stays framed after a zero-length payload.
        proto::write_request(&mut wr, &Request::Del { key: "e".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
    }

    #[test]
    fn count_sums_shards() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..64 {
            router.handle(Request::Put { key: format!("c{i}"), value: val(&[0]) });
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
    }

    #[test]
    fn count_does_not_hold_the_snapshot_across_shard_io() {
        // COUNT must clone the handles and release the snapshot before
        // summing — otherwise a slow shard would stall a concurrent scale
        // op's quiesce barrier.  With local shards "slow I/O" can't be
        // injected directly, so pin the observable contract: while a
        // COUNT's result is still being consumed, the router can publish
        // and fully settle a topology change.
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..100 {
            router.handle(Request::Put { key: format!("h{i}"), value: val(&[1]) });
        }
        let before = router.snapshot();
        let counted = router.handle(Request::Count);
        // The snapshot handle from before the COUNT is the only
        // outstanding one — COUNT itself left nothing pinned.
        assert_eq!(Arc::strong_count(&before), 2, "COUNT leaked a snapshot reference");
        drop(before);
        assert_eq!(counted, Response::Num(100));
        router.scale_up().unwrap();
        assert_eq!(router.handle(Request::Count), Response::Num(100));
    }

    #[test]
    fn tcp_end_to_end() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: val(b"yz") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "x".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"yz")));
    }

    #[test]
    fn router_malformed_command_keeps_the_connection() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        wr.write_all(b"FROB x\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        // The connection survived: a valid request still round-trips.
        proto::write_request(&mut wr, &Request::Put { key: "y".into(), value: val(b"1") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "y".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(val(b"1")));
    }

    fn cached_router(algorithm: &str, n: u32, hot_keys: usize) -> Arc<Router> {
        Router::with_placement(
            local_cluster(algorithm, n).unwrap(),
            Box::new(|id| ShardClient::Local(Shard::new(id))),
            None,
            1,
            false,
            hot_keys,
        )
    }

    #[test]
    fn hot_cache_serves_repeat_gets_and_writes_invalidate() {
        let router = cached_router("binomial", 4, 128);
        assert_eq!(
            router.handle(Request::Put { key: "hc".into(), value: val(b"v1") }),
            Response::Ok
        );
        // First GET misses (fills), second hits from the cache.
        assert_eq!(router.handle(Request::Get { key: "hc".into() }), Response::Val(val(b"v1")));
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 0); // ord: test-only
        assert_eq!(router.handle(Request::Get { key: "hc".into() }), Response::Val(val(b"v1")));
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 1); // ord: test-only
        // PUT invalidates: the next GET must see the new value, not the
        // cached one.
        assert_eq!(
            router.handle(Request::Put { key: "hc".into(), value: val(b"v2") }),
            Response::Ok
        );
        assert_eq!(router.handle(Request::Get { key: "hc".into() }), Response::Val(val(b"v2")));
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 1); // ord: test-only
        // DEL invalidates: no stale value can resurface.
        assert_eq!(router.handle(Request::Del { key: "hc".into() }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "hc".into() }), Response::Nil);
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 1); // ord: test-only
        // Batched writes invalidate too.
        router.handle(Request::Put { key: "hc".into(), value: val(b"v3") });
        router.handle(Request::Get { key: "hc".into() });
        router.handle(Request::Get { key: "hc".into() }); // cached
        match router.handle(Request::MPut { keys: vec!["hc".into()], values: vec![val(b"v4")] })
        {
            Response::Multi(subs) => assert_eq!(subs, vec![Response::Ok]),
            other => panic!("{other:?}"),
        }
        assert_eq!(router.handle(Request::Get { key: "hc".into() }), Response::Val(val(b"v4")));
        // STATS surfaces the cache and load-factor telemetry.
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("hot_hits="), "{s}");
                assert!(s.contains("load_factor="), "{s}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hot_cache_never_serves_across_an_epoch_publish() {
        let router = cached_router("binomial", 2, 64);
        router.handle(Request::Put { key: "ep".into(), value: val(b"e") });
        router.handle(Request::Get { key: "ep".into() }); // fill
        router.handle(Request::Get { key: "ep".into() }); // hit
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 1); // ord: test-only
        router.scale_up().unwrap();
        // The publish cleared the cache: the first post-epoch GET reads
        // the shard (no hit), the second hits the refilled entry.
        assert_eq!(router.handle(Request::Get { key: "ep".into() }), Response::Val(val(b"e")));
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 1); // ord: test-only
        assert_eq!(router.handle(Request::Get { key: "ep".into() }), Response::Val(val(b"e")));
        assert_eq!(router.metrics.hot_hits.load(Ordering::Relaxed), 2); // ord: test-only
    }

    #[test]
    fn set_weight_migrates_incrementally_and_preserves_keys() {
        use crate::algorithms::weighted::Weighted;
        let engine = Weighted::new("memento", &[1, 1, 1, 1], 1).unwrap();
        let shards = (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let router = Router::new(Cluster::new(Box::new(engine), shards));
        for i in 0..400 {
            assert_eq!(
                router.handle(Request::Put { key: format!("w{i}"), value: val(&[i as u8]) }),
                Response::Ok
            );
        }
        let epoch_before = router.topology().0;
        assert_eq!(router.set_weight(0, 3).unwrap(), 3);
        let snap = router.snapshot();
        assert!(!snap.is_migrating(), "set_weight must settle before returning");
        assert_eq!(snap.epoch, epoch_before + 1);
        assert_eq!(
            snap.engine.as_weighted().expect("weighted engine").weights(),
            &[3, 1, 1, 1]
        );
        drop(snap);
        assert!(matches!(
            router.events().last().map(|e| e.kind.clone()),
            Some(EventKind::Reweighted(0))
        ));
        for i in 0..400 {
            assert_eq!(
                router.handle(Request::Get { key: format!("w{i}") }),
                Response::Val(val(&[i as u8])),
                "key w{i} lost across the weight change"
            );
        }
        assert_eq!(router.handle(Request::Count), Response::Num(400));
        // The heavier shard now carries the larger key share.
        let n0 = match router.shard_count(0) {
            Ok(n) => n,
            Err(e) => panic!("{e}"),
        };
        assert!(n0 > 400 / 4, "shard 0 at weight 3 holds {n0} of 400 keys");
        // Scaling still composes: the stack grows at its frontier.
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        assert_eq!(router.handle(Request::Count), Response::Num(400));
    }

    #[test]
    fn set_weight_without_a_weight_table_is_a_clean_err() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        let before = router.topology();
        match router.set_weight(0, 2) {
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("weight table"), "{msg}");
                assert!(msg.contains("binomial"), "{msg}");
            }
            Ok(w) => panic!("set_weight on a bare engine succeeded: {w}"),
        }
        assert_eq!(router.topology(), before);
        assert!(router.events().is_empty());
    }
}
