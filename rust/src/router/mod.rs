//! Request router — the coordinator's front-end.
//!
//! Accepts client connections speaking the wire protocol, places each key
//! with the cluster's consistent-hashing engine (constant-time BinomialHash
//! by default), and forwards to the owning shard.  Admin commands scale the
//! cluster up/down with an integrated stop-the-world rebalance (scan →
//! plan → apply; the plan step optionally offloads to the PJRT bulk
//! artifacts).
//!
//! Concurrency model: thread-per-connection servers; the cluster sits
//! behind an `RwLock` — data requests take read locks (placement is a few
//! ns of integer arithmetic), topology changes take the write lock for the
//! duration of the migration.  A deliberate simplification documented in
//! DESIGN.md (production systems overlap migration behind an
//! epoch-forwarding proxy layer).

use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::cluster::Cluster;
use crate::metrics::RouterMetrics;
use crate::proto::{self, Request, Response};
use crate::rebalance::{self, PlanPath};
use crate::runtime::PlacementRuntime;
use crate::shard::{Shard, ShardClient};

/// Shard factory used on scale-up.
pub type ShardSpawner = Box<dyn Fn(u32) -> ShardClient + Send + Sync>;

/// The router: shared cluster + metrics + optional XLA bulk runtime.
pub struct Router {
    cluster: RwLock<Cluster>,
    /// Request/latency counters.
    pub metrics: RouterMetrics,
    /// Bulk placement runtime for rebalance planning (None = Rust path).
    /// Serialized behind a mutex — see the Send safety note in `runtime`.
    bulk: Option<std::sync::Mutex<PlacementRuntime>>,
    spawn_shard: ShardSpawner,
}

impl Router {
    /// Router over an existing cluster, spawning in-process shards on
    /// scale-up.
    pub fn new(cluster: Cluster) -> Arc<Self> {
        Self::with_options(cluster, Box::new(|id| ShardClient::Local(Shard::new(id))), None)
    }

    /// Router with a custom shard factory and/or bulk runtime.
    pub fn with_options(
        cluster: Cluster,
        spawn_shard: ShardSpawner,
        bulk: Option<PlacementRuntime>,
    ) -> Arc<Self> {
        Arc::new(Self {
            cluster: RwLock::new(cluster),
            metrics: RouterMetrics::new(),
            bulk: bulk.map(std::sync::Mutex::new),
            spawn_shard,
        })
    }

    /// Current `(epoch, n, algorithm)`.
    pub fn topology(&self) -> (u64, u32, &'static str) {
        let c = self.cluster.read().unwrap();
        (c.epoch, c.len(), c.algorithm())
    }

    /// Key count on one shard (telemetry; used by examples/benches).
    pub fn shard_count(&self, bucket: u32) -> Result<u64> {
        let c = self.cluster.read().unwrap();
        ensure!(bucket < c.len(), "bucket {bucket} out of range");
        c.shard(bucket).count()
    }

    /// Handle one data/admin request end-to-end.
    pub fn handle(self: &Arc<Self>, req: Request) -> Response {
        let start = Instant::now();
        let resp = match req {
            Request::Get { ref key } => self.forward(key, req.clone(), &self.metrics.gets),
            Request::Put { ref key, .. } => self.forward(key, req.clone(), &self.metrics.puts),
            Request::Del { ref key } => self.forward(key, req.clone(), &self.metrics.dels),
            Request::Count => {
                let c = self.cluster.read().unwrap();
                let mut total = 0u64;
                let mut err = None;
                for s in c.shards() {
                    match s.count() {
                        Ok(x) => total += x,
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                match err {
                    None => Response::Num(total),
                    Some(e) => Response::Err(e.to_string()),
                }
            }
            Request::Stats => {
                let c = self.cluster.read().unwrap();
                Response::Info(format!(
                    "epoch={} n={} algo={} {}",
                    c.epoch,
                    c.len(),
                    c.algorithm(),
                    self.metrics.summary()
                ))
            }
            Request::Scan => Response::Err("SCAN is shard-internal".into()),
            Request::ScaleUp => match self.scale_up() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
            Request::ScaleDown => match self.scale_down() {
                Ok(n) => Response::Num(n as u64),
                Err(e) => Response::Err(e.to_string()),
            },
        };
        if matches!(resp, Response::Err(_)) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.latency.record(start.elapsed());
        resp
    }

    fn forward(&self, key: &str, req: Request, counter: &std::sync::atomic::AtomicU64) -> Response {
        if !proto::valid_key(key) {
            return Response::Err(format!("invalid key {key:?}"));
        }
        counter.fetch_add(1, Ordering::Relaxed);
        let digest = crate::hashing::xxhash64(key.as_bytes(), 0);
        let t0 = Instant::now();
        let c = self.cluster.read().unwrap();
        let (_, shard) = c.route(digest);
        self.metrics.placement_latency.record(t0.elapsed());
        match shard.call(req) {
            Ok(resp) => resp,
            Err(e) => Response::Err(e.to_string()),
        }
    }

    /// Add a shard and migrate exactly the keys that now belong to it.
    /// Returns the new cluster size.
    pub fn scale_up(self: &Arc<Self>) -> Result<u32> {
        let mut c = self.cluster.write().unwrap();
        let n_old = c.len();
        let keys = rebalance::scan_cluster(c.shards())?;
        let new_id = c.join((self.spawn_shard)(n_old));
        let n_new = c.len();
        let plan = self.plan_migration(&c, &keys, n_old, n_new)?;
        let moved = rebalance::apply(&plan, c.shards())?;
        self.metrics.migrated_keys.fetch_add(moved, Ordering::Relaxed);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(new_id, n_old);
        Ok(n_new)
    }

    /// Remove the last shard after migrating its keys away.
    /// Returns the new cluster size.
    pub fn scale_down(self: &Arc<Self>) -> Result<u32> {
        let mut c = self.cluster.write().unwrap();
        let n_old = c.len();
        ensure!(n_old > 1, "cannot scale below one shard");
        let keys = rebalance::scan_cluster(c.shards())?;
        let n_new = n_old - 1;
        let plan = self.plan_migration(&c, &keys, n_old, n_new)?;
        // Migrate before dropping the shard handle.
        let moved = rebalance::apply(&plan, c.shards())?;
        let (removed, _handle) = c.leave();
        debug_assert_eq!(removed, n_new);
        self.metrics.migrated_keys.fetch_add(moved, Ordering::Relaxed);
        self.metrics.epochs.fetch_add(1, Ordering::Relaxed);
        Ok(n_new)
    }

    fn plan_migration(
        &self,
        c: &Cluster,
        keys: &[(String, u64)],
        n_old: u32,
        n_new: u32,
    ) -> Result<rebalance::MigrationPlan> {
        // The XLA bulk path computes BinomialHash placement; use it only
        // when that is the active engine.
        if let (Some(runtime), "binomial") = (&self.bulk, c.algorithm()) {
            let runtime = runtime.lock().unwrap();
            return rebalance::plan(keys, PlanPath::Xla { runtime: &runtime, n_old, n_new });
        }
        let omega = crate::algorithms::binomial::DEFAULT_OMEGA;
        match c.algorithm() {
            "binomial" => rebalance::plan(
                keys,
                PlanPath::Rust(
                    &|d| crate::algorithms::binomial::lookup(d, n_old, omega),
                    &|d| crate::algorithms::binomial::lookup(d, n_new, omega),
                ),
            ),
            "jump" => rebalance::plan(
                keys,
                PlanPath::Rust(
                    &|d| crate::algorithms::jump::jump_hash(d, n_old),
                    &|d| crate::algorithms::jump::jump_hash(d, n_new),
                ),
            ),
            "jumpback" => rebalance::plan(
                keys,
                PlanPath::Rust(
                    &|d| crate::algorithms::jumpback::jumpback(d, n_old),
                    &|d| crate::algorithms::jumpback::jumpback(d, n_new),
                ),
            ),
            "fliphash" => rebalance::plan(
                keys,
                PlanPath::Rust(
                    &|d| crate::algorithms::fliphash::fliphash(d, n_old, crate::algorithms::fliphash::DEFAULT_ATTEMPTS),
                    &|d| crate::algorithms::fliphash::fliphash(d, n_new, crate::algorithms::fliphash::DEFAULT_ATTEMPTS),
                ),
            ),
            "powerch" => rebalance::plan(
                keys,
                PlanPath::Rust(
                    &|d| crate::algorithms::powerch::powerch(d, n_old, crate::algorithms::powerch::ATTEMPTS),
                    &|d| crate::algorithms::powerch::powerch(d, n_new, crate::algorithms::powerch::ATTEMPTS),
                ),
            ),
            other => bail!(
                "scaling with engine {other:?} is not wired into plan_migration; \
                 use binomial/jump/jumpback/fliphash/powerch"
            ),
        }
    }

    /// Serve the router protocol on a TCP listener (thread per connection).
    pub fn serve(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        loop {
            let (sock, _) = listener.accept()?;
            let router = self.clone();
            std::thread::spawn(move || {
                let _ = router.serve_conn(sock);
            });
        }
    }

    fn serve_conn(self: Arc<Self>, sock: TcpStream) -> Result<()> {
        sock.set_nodelay(true)?;
        let mut rd = BufReader::new(sock.try_clone()?);
        let mut wr = sock;
        while let Some(req) = proto::read_request(&mut rd)? {
            let resp = self.handle(req);
            proto::write_response(&mut wr, &resp)?;
        }
        Ok(())
    }
}

/// Build an in-process cluster: `n` local shards + the chosen engine.
pub fn local_cluster(algorithm: &str, n: u32) -> Result<Cluster> {
    let placement = crate::algorithms::by_name(algorithm, n)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algorithm:?}"))?;
    let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
    Ok(Cluster::new(placement, shards))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_del_roundtrip() {
        let router = Router::new(local_cluster("binomial", 4).unwrap());
        assert_eq!(
            router.handle(Request::Put { key: "a".into(), value: b"1".to_vec() }),
            Response::Ok
        );
        assert_eq!(
            router.handle(Request::Get { key: "a".into() }),
            Response::Val(b"1".to_vec())
        );
        assert_eq!(router.handle(Request::Del { key: "a".into() }), Response::Ok);
        assert_eq!(router.handle(Request::Get { key: "a".into() }), Response::Nil);
    }

    #[test]
    fn scale_up_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Put { key: format!("k{i}"), value: vec![i as u8] }),
                Response::Ok
            );
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8]),
                "key k{i} lost after scale-up"
            );
        }
    }

    #[test]
    fn scale_down_preserves_all_keys() {
        let router = Router::new(local_cluster("binomial", 5).unwrap());
        for i in 0..500 {
            router.handle(Request::Put { key: format!("k{i}"), value: vec![i as u8] });
        }
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..500 {
            assert_eq!(
                router.handle(Request::Get { key: format!("k{i}") }),
                Response::Val(vec![i as u8]),
                "key k{i} lost after scale-down"
            );
        }
    }

    #[test]
    fn scale_cycle_with_jumpback_engine() {
        let router = Router::new(local_cluster("jumpback", 4).unwrap());
        for i in 0..300 {
            router.handle(Request::Put { key: format!("j{i}"), value: vec![1] });
        }
        assert_eq!(router.handle(Request::ScaleUp), Response::Num(5));
        assert_eq!(router.handle(Request::ScaleDown), Response::Num(4));
        for i in 0..300 {
            assert_eq!(
                router.handle(Request::Get { key: format!("j{i}") }),
                Response::Val(vec![1])
            );
        }
    }

    #[test]
    fn stats_reports_topology() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        match router.handle(Request::Stats) {
            Response::Info(s) => {
                assert!(s.contains("n=2"));
                assert!(s.contains("algo=binomial"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn invalid_key_rejected() {
        let router = Router::new(local_cluster("binomial", 2).unwrap());
        assert!(matches!(
            router.handle(Request::Get { key: "bad key".into() }),
            Response::Err(_)
        ));
    }

    #[test]
    fn count_sums_shards() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        for i in 0..64 {
            router.handle(Request::Put { key: format!("c{i}"), value: vec![0] });
        }
        assert_eq!(router.handle(Request::Count), Response::Num(64));
    }

    #[test]
    fn tcp_end_to_end() {
        let router = Router::new(local_cluster("binomial", 3).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = router.serve(listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: b"yz".to_vec() })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        proto::write_request(&mut wr, &Request::Get { key: "x".into() }).unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Val(b"yz".to_vec()));
    }
}
