//! Storage shard: the in-memory KV node the router places data on.
//!
//! A [`Shard`] is a striped-lock hash map with the operations the wire
//! protocol exposes.  It can be served over TCP ([`serve`], thread-per-
//! connection) for multi-process clusters, or driven in-process through
//! [`ShardClient`] — the router uses the same client type for both, so
//! the examples run a full cluster in one process while production
//! deploys one shard per host (`binhashd shard`).
//!
//! ## Zero-allocation steady state
//!
//! Values are stored as [`Value`] (`Arc<[u8]>`): a GET clones the `Arc`
//! (refcount bump, never a byte copy) and a PUT moves the caller's buffer
//! in; overwriting an existing key reuses the stored key `String`, so the
//! steady-state local GET/PUT/DEL path performs no heap allocation (pinned
//! by `rust/tests/zero_alloc.rs`).  The stripe maps hash with
//! [`XxBuildHasher`](crate::hashing::XxBuildHasher) instead of SipHash,
//! and every keyed operation takes the key's xxhash64 digest — the router
//! passes the digest it already computed for placement, so a local call
//! hashes the key exactly once end to end (remote shards recompute it
//! from the wire via [`key_digest`]).

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::hashing::XxBuildHasher;
use crate::proto::{self, Request, RequestRef, Response, Value};

/// Number of lock stripes (power of two). Public because the incremental
/// rebalancer iterates stripes (`SCANSTRIPE <i>` for `i < STRIPES`); both
/// ends of the wire share this constant.
pub const STRIPES: usize = 16;

/// Decorrelates stripe selection from the placement engine's use of the
/// same digest (otherwise low digest bits could bias both).
const STRIPE_SEED: u64 = 0x517;

/// The canonical key → digest map (xxhash64, seed 0).  Placement, stripe
/// selection and migration planning all derive from this one digest, so
/// both ends of the wire agree on stripe membership and a local call can
/// reuse the router's already-computed digest.
#[inline]
pub fn key_digest(key: &str) -> u64 {
    crate::hashing::xxhash64(key.as_bytes(), 0)
}

/// One lock stripe: live values plus migration tombstones.
#[derive(Debug, Default)]
struct Stripe {
    live: HashMap<String, Value, XxBuildHasher>,
    /// Keys deleted by `DELTOMB` while a migration was in flight. A
    /// tombstone bars `PUTNX` (the migration copy step) from
    /// resurrecting the deleted key; a client `PUT` clears it, and the
    /// router purges the whole set once the migration settles.
    tombs: HashSet<String, XxBuildHasher>,
}

/// An in-memory KV shard with striped locking.
#[derive(Debug)]
pub struct Shard {
    /// Shard id (equals its bucket index in the cluster).
    pub id: u32,
    stripes: Vec<Mutex<Stripe>>,
    ops: AtomicU64,
}

impl Shard {
    /// New empty shard.
    pub fn new(id: u32) -> Arc<Self> {
        Arc::new(Self {
            id,
            stripes: (0..STRIPES).map(|_| Mutex::new(Stripe::default())).collect(),
            ops: AtomicU64::new(0),
        })
    }

    fn stripe(&self, digest: u64) -> &Mutex<Stripe> {
        let h = crate::hashing::splitmix64(digest ^ STRIPE_SEED) as usize;
        &self.stripes[h & (STRIPES - 1)]
    }

    /// Fetch a value (a refcount bump of the stored buffer, never a copy).
    /// `digest` must be [`key_digest`]`(key)`.
    pub fn get(&self, key: &str, digest: u64) -> Option<Value> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(digest).lock().unwrap().live.get(key).cloned()
    }

    /// Store a value, moving the buffer in (clears any tombstone: a client
    /// write is always newer than the delete the tombstone recorded).
    /// Overwriting an existing key reuses its stored `String` — no
    /// allocation in steady state.
    pub fn put(&self, key: &str, value: Value, digest: u64) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(digest).lock().unwrap();
        s.tombs.remove(key);
        if let Some(slot) = s.live.get_mut(key) {
            *slot = value;
        } else {
            s.live.insert(key.to_owned(), value);
        }
    }

    /// Store a value only if the key is absent *and* not tombstoned;
    /// `true` if it was stored.
    ///
    /// The rebalancer's copy primitive: a migration batch must never
    /// overwrite a newer value a client already wrote to this shard, and
    /// must never resurrect a key a client deleted while the copy was in
    /// flight (the tombstone records that delete).
    pub fn put_nx(&self, key: &str, value: Value, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(digest).lock().unwrap();
        if s.live.contains_key(key) || s.tombs.contains(key) {
            false
        } else {
            s.live.insert(key.to_owned(), value);
            true
        }
    }

    /// Delete a key; `true` if it existed.
    pub fn del(&self, key: &str, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(digest).lock().unwrap().live.remove(key).is_some()
    }

    /// Delete a key and leave a tombstone; `true` if it existed.
    ///
    /// The router's mid-migration delete: the tombstone guarantees that a
    /// migration copy (`PUTNX`) holding the pre-delete value cannot bring
    /// the key back after this delete wins the race.
    pub fn del_tomb(&self, key: &str, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(digest).lock().unwrap();
        s.tombs.insert(key.to_string());
        s.live.remove(key).is_some()
    }

    /// Drop every tombstone (the migration they guarded has settled);
    /// returns how many were cleared.
    pub fn purge_tombstones(&self) -> u64 {
        let mut purged = 0u64;
        for s in &self.stripes {
            let mut s = s.lock().unwrap();
            purged += s.tombs.len() as u64;
            s.tombs.clear();
        }
        purged
    }

    /// Drop every stored key *and* tombstone; returns how many keys were
    /// cleared.
    ///
    /// The failover rejoin primitive: a shard that was failed missed
    /// every write and delete issued while it was down, so its contents
    /// are unreconcilable without versioning — the router wipes it before
    /// restoring it into the topology and migrates the authoritative
    /// copies (held by the survivors) back onto it.
    pub fn wipe(&self) -> u64 {
        let mut cleared = 0u64;
        for s in &self.stripes {
            let mut s = s.lock().unwrap();
            cleared += s.live.len() as u64;
            s.live.clear();
            s.tombs.clear();
        }
        cleared
    }

    /// All keys currently stored (rebalancer input).
    pub fn scan(&self) -> Vec<String> {
        let mut keys = Vec::new();
        for s in &self.stripes {
            keys.extend(s.lock().unwrap().live.keys().cloned());
        }
        keys
    }

    /// Keys of one lock stripe (`stripe < STRIPES`): the incremental
    /// rebalancer's unit of work — peak memory during a migration is one
    /// stripe, never the whole shard.
    pub fn scan_stripe(&self, stripe: usize) -> Vec<String> {
        self.stripes[stripe].lock().unwrap().live.keys().cloned().collect()
    }

    /// Number of keys stored.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.lock().unwrap().live.len() as u64).sum()
    }

    /// One-line stats.
    pub fn stats(&self) -> String {
        // One pass so keys= and tombs= come from the same instant per
        // stripe (and half the lock acquisitions of two sweeps).
        let (mut keys, mut tombs) = (0u64, 0usize);
        for s in &self.stripes {
            let s = s.lock().unwrap();
            keys += s.live.len() as u64;
            tombs += s.tombs.len();
        }
        format!(
            "shard={} keys={keys} tombs={tombs} ops={}",
            self.id,
            self.ops.load(Ordering::Relaxed)
        )
    }

    /// Handle one borrowed request.  `digest` is the key's [`key_digest`]
    /// when the caller already computed it (the router's local fast path);
    /// `None` makes the shard hash the key itself (the wire path).
    pub fn handle_ref(&self, req: RequestRef<'_>, digest: Option<u64>) -> Response {
        match req {
            RequestRef::Get { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                match self.get(key, d) {
                    Some(v) => Response::Val(v),
                    None => Response::Nil,
                }
            }
            RequestRef::Put { key, value } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                self.put(key, value, d);
                Response::Ok
            }
            RequestRef::PutNx { key, value } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.put_nx(key, value, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::Del { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.del(key, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::DelTomb { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.del_tomb(key, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::PurgeTombs => Response::Num(self.purge_tombstones()),
            RequestRef::Wipe => Response::Num(self.wipe()),
            RequestRef::Scan => Response::Keys(self.scan()),
            RequestRef::ScanStripe { stripe } => {
                if (stripe as usize) < STRIPES {
                    Response::Keys(self.scan_stripe(stripe as usize))
                } else {
                    Response::Err(format!("stripe {stripe} out of range (< {STRIPES})"))
                }
            }
            RequestRef::Count => Response::Num(self.count()),
            RequestRef::Stats => Response::Info(self.stats()),
            RequestRef::ScaleUp
            | RequestRef::ScaleDown
            | RequestRef::Fail { .. }
            | RequestRef::Restore { .. } => Response::Err("not a coordinator".into()),
        }
    }

    /// Handle one owned request (admin/test convenience).
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_ref(req.as_view(), None)
    }
}

/// Serve a shard over TCP (thread per connection) until the listener errors.
pub fn serve(shard: Arc<Shard>, listener: TcpListener) -> Result<()> {
    loop {
        let (sock, _) = listener.accept()?;
        let shard = shard.clone();
        std::thread::spawn(move || {
            let _ = serve_conn(shard, sock);
        });
    }
}

fn serve_conn(shard: Arc<Shard>, sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true)?;
    let mut rd = BufReader::new(sock.try_clone()?);
    let mut wr = sock;
    // Borrowed parsing + coalesced responses; recoverable parse failures
    // answer ERR and keep the connection (see `proto::serve_framed`).
    proto::serve_framed(&mut rd, &mut wr, |req| shard.handle_ref(req, None))
}

/// Client handle to a shard: in-process or remote TCP (pooled connections).
#[derive(Clone)]
pub enum ShardClient {
    /// Same-process shard (zero-copy dispatch).
    Local(Arc<Shard>),
    /// Remote shard over TCP.
    Remote(Arc<RemotePool>),
}

/// Fixed-size connection pool to a remote shard.
pub struct RemotePool {
    addr: SocketAddr,
    conns: Vec<Mutex<Option<ShardConn>>>,
    next: AtomicUsize,
}

struct ShardConn {
    rd: BufReader<TcpStream>,
    wr: TcpStream,
}

impl RemotePool {
    /// Pool with `size` lazily-established connections.
    pub fn new(addr: SocketAddr, size: usize) -> Arc<Self> {
        Arc::new(Self {
            addr,
            conns: (0..size.max(1)).map(|_| Mutex::new(None)).collect(),
            next: AtomicUsize::new(0),
        })
    }

    fn call(&self, req: &RequestRef<'_>) -> Result<Response> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.conns.len();
        let mut slot = self.conns[i].lock().unwrap();
        if slot.is_none() {
            let sock = TcpStream::connect(self.addr)?;
            sock.set_nodelay(true)?;
            let rd = BufReader::new(sock.try_clone()?);
            *slot = Some(ShardConn { rd, wr: sock });
        }
        let conn = slot.as_mut().unwrap();
        let result = (|| {
            proto::write_request_ref(&mut conn.wr, req)?;
            proto::read_response(&mut conn.rd)
        })();
        if result.is_err() {
            *slot = None; // drop broken connection; next call reconnects
        }
        result
    }
}

impl ShardClient {
    /// Issue a borrowed request.  `digest` is the key's [`key_digest`]
    /// when already computed: a local shard reuses it (no re-hash); a
    /// remote shard serializes the request and hashes from the wire.
    pub fn call_ref(&self, req: RequestRef<'_>, digest: Option<u64>) -> Result<Response> {
        match self {
            ShardClient::Local(shard) => Ok(shard.handle_ref(req, digest)),
            ShardClient::Remote(pool) => pool.call(&req),
        }
    }

    /// Issue an owned request and await the response.
    pub fn call(&self, req: &Request) -> Result<Response> {
        self.call_ref(req.as_view(), None)
    }

    /// Typed GET.
    pub fn get(&self, key: &str) -> Result<Option<Value>> {
        match self.call_ref(RequestRef::Get { key }, None)? {
            Response::Val(v) => Ok(Some(v)),
            Response::Nil => Ok(None),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUT (the value buffer is moved/shared, never copied locally).
    pub fn put(&self, key: &str, value: Value) -> Result<()> {
        match self.call_ref(RequestRef::Put { key, value }, None)? {
            Response::Ok => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUTNX; `true` if the value was stored (key was absent).
    pub fn put_nx(&self, key: &str, value: Value) -> Result<bool> {
        match self.call_ref(RequestRef::PutNx { key, value }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DEL; `true` if the key existed.
    pub fn del(&self, key: &str) -> Result<bool> {
        match self.call_ref(RequestRef::Del { key }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DELTOMB: delete and leave a migration tombstone; `true` if
    /// the key existed.
    pub fn del_tomb(&self, key: &str) -> Result<bool> {
        match self.call_ref(RequestRef::DelTomb { key }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PURGETOMBS; returns how many tombstones were cleared.
    pub fn purge_tombstones(&self) -> Result<u64> {
        match self.call_ref(RequestRef::PurgeTombs, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed WIPE: drop every key and tombstone (failover rejoin);
    /// returns how many keys were cleared.
    pub fn wipe(&self) -> Result<u64> {
        match self.call_ref(RequestRef::Wipe, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCAN.
    pub fn scan(&self) -> Result<Vec<String>> {
        match self.call_ref(RequestRef::Scan, None)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCANSTRIPE.
    pub fn scan_stripe(&self, stripe: u32) -> Result<Vec<String>> {
        match self.call_ref(RequestRef::ScanStripe { stripe }, None)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed COUNT.
    pub fn count(&self) -> Result<u64> {
        match self.call_ref(RequestRef::Count, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    /// Digest shorthand for direct `Shard` calls.
    fn kd(key: &str) -> u64 {
        key_digest(key)
    }

    fn val(bytes: &[u8]) -> Value {
        bytes.to_vec().into()
    }

    #[test]
    fn shard_basic_ops() {
        let s = Shard::new(0);
        assert_eq!(s.get("a", kd("a")), None);
        s.put("a", val(b"1"), kd("a"));
        s.put("b", val(b"2"), kd("b"));
        assert_eq!(s.get("a", kd("a")).as_deref(), Some(&b"1"[..]));
        assert_eq!(s.count(), 2);
        assert!(s.del("a", kd("a")));
        assert!(!s.del("a", kd("a")));
        assert_eq!(s.count(), 1);
        assert_eq!(s.scan(), vec!["b".to_string()]);
    }

    #[test]
    fn overwrite_reuses_the_stored_key() {
        let s = Shard::new(11);
        s.put("k", val(b"old"), kd("k"));
        s.put("k", val(b"new"), kd("k"));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"new"[..]));
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn get_shares_the_stored_buffer() {
        // The zero-copy contract: two GETs of one key return the same
        // allocation, not two copies.
        let s = Shard::new(12);
        s.put("k", val(b"payload"), kd("k"));
        let a = s.get("k", kd("k")).unwrap();
        let b = s.get("k", kd("k")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "GET must bump a refcount, not copy");
    }

    #[test]
    fn local_client_roundtrip() {
        let c = ShardClient::Local(Shard::new(1));
        c.put("k", val(b"v")).unwrap();
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(c.count().unwrap(), 1);
        assert!(c.del("k").unwrap());
        assert_eq!(c.get("k").unwrap(), None);
    }

    #[test]
    fn tcp_client_roundtrip() {
        let s = Shard::new(2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 2));
        c.put("x", vec![9u8; 1000].into()).unwrap();
        assert_eq!(c.get("x").unwrap().as_deref(), Some(&vec![9u8; 1000][..]));
        assert_eq!(c.count().unwrap(), 1);
        assert_eq!(c.scan().unwrap(), vec!["x".to_string()]);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let s = Shard::new(3);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let pool = RemotePool::new(addr, 4);
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let c = ShardClient::Remote(pool.clone());
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(&format!("k-{t}-{i}"), vec![t].into()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 400);
    }

    #[test]
    fn malformed_command_answers_err_and_keeps_the_connection() {
        // A typo'd command must not tear down the TCP session: the server
        // answers ERR and the next (valid) request still works.
        let s = Shard::new(13);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        wr.write_all(b"BOGUS x\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        wr.write_all(b"SCANSTRIPE notanumber\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: val(b"1") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn pipelined_burst_is_answered_in_order() {
        // The server coalesces responses and flushes once per drained
        // burst; the client must still see every response, in order.
        let s = Shard::new(14);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        let mut burst = Vec::new();
        for i in 0..32 {
            proto::write_request(
                &mut burst,
                &Request::Put { key: format!("p{i}"), value: val(&[i as u8]) },
            )
            .unwrap();
        }
        for i in 0..32 {
            proto::write_request(&mut burst, &Request::Get { key: format!("p{i}") }).unwrap();
        }
        wr.write_all(&burst).unwrap();
        wr.flush().unwrap();
        for _ in 0..32 {
            assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        }
        for i in 0..32 {
            assert_eq!(
                proto::read_response(&mut rd).unwrap(),
                Response::Val(val(&[i as u8]))
            );
        }
    }

    #[test]
    fn shard_rejects_admin_commands() {
        let s = Shard::new(4);
        assert!(matches!(s.handle(&Request::ScaleUp), Response::Err(_)));
    }

    #[test]
    fn put_nx_never_overwrites() {
        let s = Shard::new(5);
        assert!(s.put_nx("k", val(b"old"), kd("k")));
        assert!(!s.put_nx("k", val(b"new"), kd("k")));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"old"[..]));
        let c = ShardClient::Local(s);
        assert!(!c.put_nx("k", val(b"newer")).unwrap());
        assert!(c.put_nx("fresh", val(b"v")).unwrap());
    }

    #[test]
    fn tombstone_bars_put_nx_until_purged() {
        let s = Shard::new(7);
        s.put("k", val(b"v"), kd("k"));
        assert!(s.del_tomb("k", kd("k")));
        assert_eq!(s.get("k", kd("k")), None);
        assert_eq!(s.count(), 0);
        // The migration copy must be refused: the delete won the race.
        assert!(!s.put_nx("k", val(b"stale"), kd("k")));
        assert_eq!(s.get("k", kd("k")), None);
        // A tombstone for a never-stored key works the same way.
        assert!(!s.del_tomb("ghost", kd("ghost")));
        assert!(!s.put_nx("ghost", val(b"stale"), kd("ghost")));
        // A client PUT is newer than the tombstoned delete and clears it.
        s.put("k", val(b"fresh"), kd("k"));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"fresh"[..]));
        // Settling purges the remaining tombstone and re-enables PUTNX.
        assert_eq!(s.purge_tombstones(), 1);
        assert!(s.put_nx("ghost", val(b"reborn"), kd("ghost")));
        assert!(s.stats().contains("tombs=0"));
    }

    #[test]
    fn del_racing_migration_copy_cannot_resurrect() {
        // The exact interleaving of the former "known anomaly": the
        // migration sweep reads the source copy, the client DEL lands on
        // both owners, then the sweep's PUTNX arrives at the destination.
        let src = Shard::new(8);
        let dst = Shard::new(9);
        src.put("k", val(b"v"), kd("k"));
        let copied = src.get("k", kd("k")).unwrap(); // sweep reads the source
        assert!(!dst.del_tomb("k", kd("k"))); // client DEL, new owner first (no copy there yet)
        assert!(src.del("k", kd("k"))); // ... then old owner
        assert!(!dst.put_nx("k", copied, kd("k"))); // sweep copy refused
        assert_eq!(
            dst.get("k", kd("k")),
            None,
            "DEL racing the migration copy resurrected the key"
        );
        assert_eq!(src.get("k", kd("k")), None);
    }

    #[test]
    fn del_tomb_and_purge_over_the_wire() {
        let s = Shard::new(10);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        c.put("x", val(b"1")).unwrap();
        assert!(c.del_tomb("x").unwrap());
        assert!(!c.put_nx("x", val(b"stale")).unwrap());
        assert_eq!(c.get("x").unwrap(), None);
        assert_eq!(c.purge_tombstones().unwrap(), 1);
        assert!(c.put_nx("x", val(b"new")).unwrap());
    }

    #[test]
    fn wipe_clears_keys_and_tombstones() {
        let s = Shard::new(16);
        for i in 0..20 {
            let k = format!("w{i}");
            s.put(&k, val(&[i as u8]), kd(&k));
        }
        s.del_tomb("w0", kd("w0"));
        assert_eq!(s.wipe(), 19);
        assert_eq!(s.count(), 0);
        assert!(s.stats().contains("tombs=0"));
        // The tombstone went with the wipe: PUTNX works again.
        assert!(s.put_nx("w0", val(b"fresh"), kd("w0")));

        // And over the wire.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        assert_eq!(c.wipe().unwrap(), 1);
        assert_eq!(c.count().unwrap(), 0);
    }

    #[test]
    fn empty_values_store_and_roundtrip_the_wire() {
        // Zero-length payload edge (`PUT k 0`): store, share, and serve
        // an empty `Arc<[u8]>` locally and over TCP.
        let s = Shard::new(17);
        let empty: Value = Vec::new().into();
        s.put("e", empty.clone(), kd("e"));
        let got = s.get("e", kd("e")).unwrap();
        assert!(got.is_empty());
        assert!(Arc::ptr_eq(&got, &empty), "empty GET must share the buffer too");

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        assert_eq!(c.get("e").unwrap().as_deref(), Some(&b""[..]));
        c.put("e2", Vec::new().into()).unwrap();
        assert_eq!(c.get("e2").unwrap().as_deref(), Some(&b""[..]));
        assert!(!c.put_nx("e2", val(b"x")).unwrap(), "empty value must count as present");
        assert_eq!(c.count().unwrap(), 2);
    }

    #[test]
    fn stripe_scans_partition_the_keyset() {
        let s = Shard::new(6);
        for i in 0..64 {
            let k = format!("key-{i}");
            s.put(&k, val(&[i as u8]), kd(&k));
        }
        let mut all: Vec<String> = (0..STRIPES).flat_map(|i| s.scan_stripe(i)).collect();
        all.sort();
        let mut want = s.scan();
        want.sort();
        assert_eq!(all, want);
        assert_eq!(all.len(), 64);
        assert!(matches!(
            s.handle(&Request::ScanStripe { stripe: STRIPES as u32 }),
            Response::Err(_)
        ));
    }

    #[test]
    fn local_and_wire_paths_agree_on_stripes() {
        // A key written through the digest-threaded local path must be
        // visible to the wire path (which recomputes the digest), i.e.
        // both must select the same stripe.
        let s = Shard::new(15);
        s.put("agree", val(b"1"), kd("agree"));
        assert_eq!(
            s.handle_ref(RequestRef::Get { key: "agree" }, None),
            Response::Val(val(b"1"))
        );
    }
}
