//! Storage shard: the in-memory KV node the router places data on.
//!
//! A [`Shard`] is a striped-lock hash map with the operations the wire
//! protocol exposes.  It can be served over TCP ([`serve`], thread-per-
//! connection) for multi-process clusters, or driven in-process through
//! [`ShardClient`] — the router uses the same client type for both, so
//! the examples run a full cluster in one process while production
//! deploys one shard per host (`binhashd shard`).
//!
//! ## Zero-allocation steady state
//!
//! Values are stored as [`Value`] (`Arc<[u8]>`): a GET clones the `Arc`
//! (refcount bump, never a byte copy) and a PUT moves the caller's buffer
//! in; overwriting an existing key reuses the stored key `String`, so the
//! steady-state local GET/PUT/DEL path performs no heap allocation (pinned
//! by `rust/tests/zero_alloc.rs`).  The stripe maps hash with
//! [`XxBuildHasher`](crate::hashing::XxBuildHasher) instead of SipHash,
//! and every keyed operation takes the key's xxhash64 digest — the router
//! passes the digest it already computed for placement, so a local call
//! hashes the key exactly once end to end (remote shards recompute it
//! from the wire via [`key_digest`]).
//!
//! ## Batched execution
//!
//! [`Shard::run_batch`] executes one `MGET`/`MPUT`/`MPUTNX`/`MDEL`/
//! `MDELTOMB` keybatch under **one lock acquisition per occupied
//! stripe** instead of one per key: it builds a stripe-occupancy mask
//! from the digests, then walks each occupied stripe once, applying that
//! stripe's keys in request order under a single guard.  Results are
//! positional (`out[i]` answers key `i`), which is what lets the router
//! hand one response array to several shards' fan-outs and get the
//! request-order reassembly for free.  [`ShardClient::call_batch`] is the
//! transport-agnostic entry: in-process it is the stripe-grouped run,
//! remote it is one `MULTI`-answered round-trip per shard.

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::hashing::XxBuildHasher;
use crate::proto::{self, BatchOp, BatchSource, Request, RequestRef, Response, Value, MAX_BATCH};
use crate::sync::{Arc, AtomicU64, AtomicUsize, Backoff, Mutex, Ordering};

/// Number of lock stripes (power of two). Public because the incremental
/// rebalancer iterates stripes (`SCANSTRIPE <i>` for `i < STRIPES`); both
/// ends of the wire share this constant.
pub const STRIPES: usize = 16;

/// Decorrelates stripe selection from the placement engine's use of the
/// same digest (otherwise low digest bits could bias both).
const STRIPE_SEED: u64 = 0x517;

/// Seed for hashing stored *values* into the per-stripe content digest
/// (`DIGEST`), distinct from the key-digest seed so `entry(k, v)` never
/// degenerates when a value happens to equal its key's bytes.
const DIGEST_VALUE_SEED: u64 = 0xD16E_5701;

/// Default remote-call deadline (connect, read, and write) for
/// [`RemotePool`].  Generous — it exists to bound a *hung* peer, not to
/// race healthy ones.
pub const DEFAULT_REMOTE_TIMEOUT: Duration = Duration::from_secs(5);

/// Default bounded retry count for [`RemotePool`] calls (fresh pooled
/// connection per attempt).
pub const DEFAULT_REMOTE_RETRIES: u32 = 2;

/// The canonical key → digest map (xxhash64, seed 0).  Placement, stripe
/// selection and migration planning all derive from this one digest, so
/// both ends of the wire agree on stripe membership and a local call can
/// reuse the router's already-computed digest.
#[inline]
pub fn key_digest(key: &str) -> u64 {
    crate::hashing::xxhash64(key.as_bytes(), 0)
}

/// One lock stripe: live values plus migration tombstones.
#[derive(Debug, Default)]
struct Stripe {
    live: HashMap<String, Value, XxBuildHasher>,
    /// Keys deleted by `DELTOMB` while a migration was in flight. A
    /// tombstone bars `PUTNX` (the migration copy step) from
    /// resurrecting the deleted key; a client `PUT` clears it, and the
    /// router purges the whole set once the migration settles.
    tombs: HashSet<String, XxBuildHasher>,
}

// The per-key operations, factored onto the locked stripe so the
// singleton path (one lock per op) and the batch path (one lock per
// occupied stripe) share one implementation of the semantics.
impl Stripe {
    fn get(&self, key: &str) -> Option<Value> {
        self.live.get(key).cloned()
    }

    fn put(&mut self, key: &str, value: Value) {
        self.tombs.remove(key);
        if let Some(slot) = self.live.get_mut(key) {
            *slot = value;
        } else {
            self.live.insert(key.to_owned(), value);
        }
    }

    fn put_nx(&mut self, key: &str, value: Value) -> bool {
        if self.live.contains_key(key) || self.tombs.contains(key) {
            false
        } else {
            self.live.insert(key.to_owned(), value);
            true
        }
    }

    fn del(&mut self, key: &str) -> bool {
        self.live.remove(key).is_some()
    }

    fn del_tomb(&mut self, key: &str) -> bool {
        self.tombs.insert(key.to_string());
        self.live.remove(key).is_some()
    }
}

/// Index of the lock stripe owning a key digest (`splitmix64`-mixed so it
/// decorrelates from the placement engine's use of the same digest).
#[inline]
fn stripe_index(digest: u64) -> usize {
    crate::hashing::splitmix64(digest ^ STRIPE_SEED) as usize & (STRIPES - 1)
}

/// Reusable scratch for [`Shard::handle_batch`]: the digest table and the
/// identity selection, allocated once per connection (or per caller), not
/// once per batch.
#[derive(Debug, Default)]
pub struct BatchScratch {
    sel: Vec<u32>,
    digests: Vec<u64>,
}

impl BatchScratch {
    /// New empty scratch.
    pub fn new() -> Self {
        Self::default()
    }
}

/// An in-memory KV shard with striped locking.
#[derive(Debug)]
pub struct Shard {
    /// Shard id (equals its bucket index in the cluster).
    pub id: u32,
    stripes: Vec<Mutex<Stripe>>,
    ops: AtomicU64,
}

impl Shard {
    /// New empty shard.
    pub fn new(id: u32) -> Arc<Self> {
        Arc::new(Self {
            id,
            stripes: (0..STRIPES).map(|_| Mutex::new(Stripe::default())).collect(),
            ops: AtomicU64::new(0),
        })
    }

    fn stripe(&self, digest: u64) -> &Mutex<Stripe> {
        &self.stripes[stripe_index(digest)]
    }

    /// Fetch a value (a refcount bump of the stored buffer, never a copy).
    /// `digest` must be [`key_digest`]`(key)`.
    pub fn get(&self, key: &str, digest: u64) -> Option<Value> {
        self.ops.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.stripe(digest).lock().unwrap().get(key)
    }

    /// Store a value, moving the buffer in (clears any tombstone: a client
    /// write is always newer than the delete the tombstone recorded).
    /// Overwriting an existing key reuses its stored `String` — no
    /// allocation in steady state.
    pub fn put(&self, key: &str, value: Value, digest: u64) {
        self.ops.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.stripe(digest).lock().unwrap().put(key, value);
    }

    /// Store a value only if the key is absent *and* not tombstoned;
    /// `true` if it was stored.
    ///
    /// The rebalancer's copy primitive: a migration batch must never
    /// overwrite a newer value a client already wrote to this shard, and
    /// must never resurrect a key a client deleted while the copy was in
    /// flight (the tombstone records that delete).
    pub fn put_nx(&self, key: &str, value: Value, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.stripe(digest).lock().unwrap().put_nx(key, value)
    }

    /// Delete a key; `true` if it existed.
    pub fn del(&self, key: &str, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.stripe(digest).lock().unwrap().del(key)
    }

    /// Delete a key and leave a tombstone; `true` if it existed.
    ///
    /// The router's mid-migration delete: the tombstone guarantees that a
    /// migration copy (`PUTNX`) holding the pre-delete value cannot bring
    /// the key back after this delete wins the race.
    pub fn del_tomb(&self, key: &str, digest: u64) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        self.stripe(digest).lock().unwrap().del_tomb(key)
    }

    /// Execute one batch op for the keys selected by `sel` (dense indices
    /// into `src`/`digests`/`out`), acquiring each *occupied* stripe's
    /// lock once instead of once per key — the lock cost of a batch is
    /// `min(batch, STRIPES)` acquisitions, not `batch`.
    ///
    /// Results land positionally: `out[i]` answers key `i` for each `i`
    /// in `sel` (untouched slots keep their previous contents, which is
    /// what lets the router fan one `out` across several shards).
    /// `digests[i]` must be [`key_digest`]`(src.key(i))`.  Duplicate keys
    /// within a batch apply in ascending-`sel` order (they share a
    /// stripe, and each stripe pass walks `sel` in order).  Allocates
    /// nothing beyond what the per-key ops themselves do.
    pub fn run_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        sel: &[u32],
        src: &S,
        digests: &[u64],
        out: &mut [Response],
    ) {
        self.ops.fetch_add(sel.len() as u64, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        // Grouping is a linear re-scan of `sel` per occupied stripe (one
        // splitmix64 each) rather than a sort or per-stripe sublists: for
        // the wire-capped batch sizes that is a handful of cache-friendly
        // passes over a contiguous u32 slice — cheaper than the
        // allocation or scratch plumbing an index would cost, and it
        // keeps this entry allocation-free for any `BatchSource`.
        let mut mask: u32 = 0;
        for &i in sel {
            mask |= 1 << stripe_index(digests[i as usize]);
        }
        for s in 0..STRIPES {
            if mask & (1 << s) == 0 {
                continue;
            }
            let mut stripe = self.stripes[s].lock().unwrap();
            for &i in sel {
                let i = i as usize;
                if stripe_index(digests[i]) != s {
                    continue;
                }
                let key = src.key(i);
                out[i] = match op {
                    BatchOp::Get => match stripe.get(key) {
                        Some(v) => Response::Val(v),
                        None => Response::Nil,
                    },
                    BatchOp::Put => {
                        stripe.put(key, src.value(i));
                        Response::Ok
                    }
                    BatchOp::PutNx => {
                        if stripe.put_nx(key, src.value(i)) {
                            Response::Ok
                        } else {
                            Response::Nil
                        }
                    }
                    BatchOp::Del => {
                        if stripe.del(key) {
                            Response::Ok
                        } else {
                            Response::Nil
                        }
                    }
                    BatchOp::DelTomb => {
                        if stripe.del_tomb(key) {
                            Response::Ok
                        } else {
                            Response::Nil
                        }
                    }
                };
            }
        }
    }

    /// Handle one whole batch (identity selection) with caller-reused
    /// scratch, leaving the positional sub-responses in `out` — the shard
    /// server's per-connection batch path (zero allocation beyond the
    /// per-key ops once the scratch is warm).
    pub fn handle_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        src: &S,
        scratch: &mut BatchScratch,
        out: &mut Vec<Response>,
    ) {
        let n = src.len();
        scratch.digests.clear();
        scratch.digests.extend((0..n).map(|i| key_digest(src.key(i))));
        scratch.sel.clear();
        scratch.sel.extend(0..n as u32);
        out.clear();
        out.resize(n, Response::Nil);
        self.run_batch(op, &scratch.sel, src, &scratch.digests, out);
    }

    /// Drop every tombstone (the migration they guarded has settled);
    /// returns how many were cleared.
    pub fn purge_tombstones(&self) -> u64 {
        let mut purged = 0u64;
        for s in &self.stripes {
            let mut s = s.lock().unwrap();
            purged += s.tombs.len() as u64;
            s.tombs.clear();
        }
        purged
    }

    /// Drop every stored key *and* tombstone; returns how many keys were
    /// cleared.
    ///
    /// The failover rejoin primitive: a shard that was failed missed
    /// every write and delete issued while it was down, so its contents
    /// are unreconcilable without versioning — the router wipes it before
    /// restoring it into the topology and migrates the authoritative
    /// copies (held by the survivors) back onto it.
    pub fn wipe(&self) -> u64 {
        let mut cleared = 0u64;
        for s in &self.stripes {
            let mut s = s.lock().unwrap();
            cleared += s.live.len() as u64;
            s.live.clear();
            s.tombs.clear();
        }
        cleared
    }

    /// Per-stripe content digests: an order-independent XOR fold of
    /// `splitmix64(key_digest ^ xxhash64(value))` over each stripe's live
    /// entries (an empty stripe digests to 0; tombstones are transient
    /// migration state and excluded).  Because stripe membership is a
    /// pure function of the key digest, the *same* key set with the same
    /// values digests identically on any shard — which is what lets the
    /// anti-entropy restore sweep compare a survivor's stripe against
    /// the restored shard's and skip streaming it when they already
    /// agree.
    pub fn stripe_digests(&self) -> [u64; STRIPES] {
        let mut out = [0u64; STRIPES];
        for (i, s) in self.stripes.iter().enumerate() {
            let s = s.lock().unwrap();
            let mut acc = 0u64;
            for (k, v) in &s.live {
                acc ^= crate::hashing::splitmix64(
                    key_digest(k) ^ crate::hashing::xxhash64(v, DIGEST_VALUE_SEED),
                );
            }
            out[i] = acc;
        }
        out
    }

    /// All keys currently stored (rebalancer input).
    pub fn scan(&self) -> Vec<String> {
        let mut keys = Vec::new();
        for s in &self.stripes {
            keys.extend(s.lock().unwrap().live.keys().cloned());
        }
        keys
    }

    /// Keys of one lock stripe (`stripe < STRIPES`): the incremental
    /// rebalancer's unit of work — peak memory during a migration is one
    /// stripe, never the whole shard.
    pub fn scan_stripe(&self, stripe: usize) -> Vec<String> {
        self.stripes[stripe].lock().unwrap().live.keys().cloned().collect()
    }

    /// Number of keys stored.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.lock().unwrap().live.len() as u64).sum()
    }

    /// One-line stats.
    pub fn stats(&self) -> String {
        // One pass so keys= and tombs= come from the same instant per
        // stripe (and half the lock acquisitions of two sweeps).
        let (mut keys, mut tombs) = (0u64, 0usize);
        for s in &self.stripes {
            let s = s.lock().unwrap();
            keys += s.live.len() as u64;
            tombs += s.tombs.len();
        }
        format!(
            "shard={} keys={keys} tombs={tombs} ops={}",
            self.id,
            self.ops.load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
        )
    }

    /// Handle one borrowed request.  `digest` is the key's [`key_digest`]
    /// when the caller already computed it (the router's local fast path);
    /// `None` makes the shard hash the key itself (the wire path).
    ///
    /// Batch requests answer [`Response::Multi`] through transient
    /// scratch; the server loop instead calls
    /// [`handle_batch`](Self::handle_batch) with per-connection scratch.
    pub fn handle_ref(&self, req: RequestRef<'_>, digest: Option<u64>) -> Response {
        let req = match req.into_batch() {
            Ok((op, batch)) => {
                let mut out = Vec::new();
                self.handle_batch(op, &batch, &mut BatchScratch::new(), &mut out);
                return Response::Multi(out);
            }
            Err(req) => req,
        };
        match req {
            RequestRef::Get { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                match self.get(key, d) {
                    Some(v) => Response::Val(v),
                    None => Response::Nil,
                }
            }
            RequestRef::Put { key, value } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                self.put(key, value, d);
                Response::Ok
            }
            RequestRef::PutNx { key, value } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.put_nx(key, value, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::Del { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.del(key, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::DelTomb { key } => {
                let d = digest.unwrap_or_else(|| key_digest(key));
                if self.del_tomb(key, d) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            RequestRef::PurgeTombs => Response::Num(self.purge_tombstones()),
            RequestRef::Wipe => Response::Num(self.wipe()),
            RequestRef::Digest => Response::Nums(self.stripe_digests().to_vec()),
            RequestRef::Scan => Response::Keys(self.scan()),
            RequestRef::ScanStripe { stripe } => {
                if (stripe as usize) < STRIPES {
                    Response::Keys(self.scan_stripe(stripe as usize))
                } else {
                    Response::Err(format!("stripe {stripe} out of range (< {STRIPES})"))
                }
            }
            RequestRef::Count => Response::Num(self.count()),
            RequestRef::Stats => Response::Info(self.stats()),
            RequestRef::ScaleUp
            | RequestRef::ScaleDown
            | RequestRef::Fail { .. }
            | RequestRef::Restore { .. } => Response::Err("not a coordinator".into()),
            RequestRef::MGet { .. }
            | RequestRef::MPut { .. }
            | RequestRef::MPutNx { .. }
            | RequestRef::MDel { .. }
            | RequestRef::MDelTomb { .. } => unreachable!("batches split off above"),
        }
    }

    /// Handle one owned request (admin/test convenience).
    pub fn handle(&self, req: &Request) -> Response {
        self.handle_ref(req.as_view(), None)
    }
}

/// Serve a shard over TCP with the blocking personality (thread per
/// connection) until the listener errors — the portable fallback; see
/// [`server`] for the epoll event server.
pub fn serve(shard: Arc<Shard>, listener: TcpListener) -> Result<()> {
    crate::net::serve_blocking(shard, listener)
}

/// Build a [`crate::net::Server`] over this shard: the readiness event
/// server by default.  Call `.handle()` for graceful stop, then `.run()`
/// (blocking) on a dedicated thread.
pub fn server(
    shard: Arc<Shard>,
    listener: TcpListener,
    opts: crate::net::ServerOpts,
) -> Result<crate::net::Server<Shard>> {
    crate::net::Server::new(shard, listener, opts)
}

/// Per-connection handler state for the shard as a
/// [`Service`](crate::net::Service): batch scratch plus the positional
/// sub-response buffer — reused across every request of one connection.
#[derive(Debug, Default)]
pub struct ShardConnState {
    scratch: BatchScratch,
    subs: Vec<Response>,
}

impl crate::net::Service for Shard {
    type ConnState = ShardConnState;

    /// Borrowed parsing + coalesced responses; recoverable parse
    /// failures already answered `ERR` upstream (see `proto`).  Batches
    /// run through per-connection scratch so a steady stream of
    /// MGET/MPUT frames reuses its buffers instead of allocating per
    /// batch.
    fn handle(&self, st: &mut ShardConnState, req: RequestRef<'_>, out: &mut Vec<u8>) -> Result<()> {
        match req.into_batch() {
            Ok((op, batch)) => {
                self.handle_batch(op, &batch, &mut st.scratch, &mut st.subs);
                proto::encode_multi_response(out, &st.subs)
            }
            Err(req) => proto::encode_response(out, &self.handle_ref(req, None)),
        }
    }
}

/// Client handle to a shard: in-process or remote TCP (pooled connections).
#[derive(Clone)]
pub enum ShardClient {
    /// Same-process shard (zero-copy dispatch).
    Local(Arc<Shard>),
    /// Remote shard over TCP.
    Remote(Arc<RemotePool>),
    /// Fault-injecting wrapper around another client (test harness for
    /// partial-write and torn-fan-out schedules; never constructed by
    /// production wiring).
    Flaky(Arc<FlakyShard>),
}

/// What a [`FlakyShard`] does to a call selected for fault injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlakyMode {
    /// Drop the request before it reaches the shard and answer `Err` —
    /// the write never happened anywhere.
    Drop,
    /// Forward the request, then lose the acknowledgement — the write
    /// *landed* but the caller sees `Err` (the classic torn fan-out:
    /// state diverges from what the writer believes).
    AckLost,
    /// Forward the request after a bounded busy-wait — exercises slow
    /// peers without failing anything.
    Delay,
}

/// Deterministic fault injector around a [`ShardClient`].
///
/// Selection is a pure function of a seed and a relaxed call counter
/// (`splitmix64(seed ^ call#) % 100 < percent`), so a schedule is
/// reproducible run to run without wall-clock or RNG state, and a test
/// can compute exactly which calls will fault.
pub struct FlakyShard {
    inner: ShardClient,
    mode: FlakyMode,
    /// Percentage of calls faulted (0–100).
    percent: u64,
    seed: u64,
    calls: AtomicU64,
    injected: AtomicU64,
}

impl FlakyShard {
    /// Wrap `inner`, faulting `percent`% of calls with `mode`.
    pub fn wrap(inner: ShardClient, mode: FlakyMode, percent: u64, seed: u64) -> Arc<Self> {
        Arc::new(Self {
            inner,
            mode,
            percent: percent.min(100),
            seed,
            calls: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        })
    }

    /// Calls seen so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
    }

    /// The wrapped client (tests reach through to assert shard state).
    pub fn inner(&self) -> &ShardClient {
        &self.inner
    }

    fn fault_now(&self) -> bool {
        let n = self.calls.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — deterministic schedule counter, no memory published through it
        let hit = crate::hashing::splitmix64(self.seed ^ n) % 100 < self.percent;
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
        }
        hit
    }

    fn delay(&self) {
        let mut backoff = Backoff::new();
        for _ in 0..16 {
            backoff.snooze();
        }
    }

    fn call_ref(&self, req: RequestRef<'_>, digest: Option<u64>) -> Result<Response> {
        if self.fault_now() {
            match self.mode {
                FlakyMode::Drop => bail!("injected fault: request dropped"),
                FlakyMode::AckLost => {
                    let _ = self.inner.call_ref(req, digest);
                    bail!("injected fault: ack lost");
                }
                FlakyMode::Delay => self.delay(),
            }
        }
        self.inner.call_ref(req, digest)
    }

    fn call_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        sel: &[u32],
        src: &S,
        digests: &[u64],
        out: &mut [Response],
    ) -> Result<()> {
        if self.fault_now() {
            match self.mode {
                FlakyMode::Drop => bail!("injected fault: batch dropped"),
                FlakyMode::AckLost => {
                    let _ = self.inner.call_batch(op, sel, src, digests, out);
                    bail!("injected fault: batch ack lost");
                }
                FlakyMode::Delay => self.delay(),
            }
        }
        self.inner.call_batch(op, sel, src, digests, out)
    }
}

/// Fixed-size connection pool to a remote shard, with per-call connect/
/// read/write deadlines and bounded retry — one hung peer stalls a call
/// for at most `(retries + 1) × timeout`, never indefinitely.
///
/// Retries re-issue the *whole* request on a fresh pooled connection,
/// so a write whose acknowledgement was lost may apply twice
/// (at-least-once semantics — PUT/DEL are idempotent per key, and the
/// refused-`PUTNX` migration machinery tolerates replay).
pub struct RemotePool {
    addr: SocketAddr,
    conns: Vec<Mutex<Option<ShardConn>>>,
    next: AtomicUsize,
    timeout: Duration,
    retries: u32,
    timeouts: AtomicU64,
}

struct ShardConn {
    rd: BufReader<TcpStream>,
    wr: TcpStream,
}

impl RemotePool {
    /// Pool with `size` lazily-established connections and the default
    /// deadline/retry limits.
    pub fn new(addr: SocketAddr, size: usize) -> Arc<Self> {
        Self::with_limits(addr, size, DEFAULT_REMOTE_TIMEOUT, DEFAULT_REMOTE_RETRIES)
    }

    /// Pool with explicit per-call deadline and retry budget.  A zero
    /// `timeout` disables deadlines (blocking calls, as before the
    /// limits existed).
    pub fn with_limits(
        addr: SocketAddr,
        size: usize,
        timeout: Duration,
        retries: u32,
    ) -> Arc<Self> {
        Arc::new(Self {
            addr,
            conns: (0..size.max(1)).map(|_| Mutex::new(None)).collect(),
            next: AtomicUsize::new(0),
            timeout,
            retries,
            timeouts: AtomicU64::new(0),
        })
    }

    /// Calls that hit the connect/read/write deadline so far (surfaced
    /// as `remote_timeouts=` in the router's STATS).
    pub fn timeouts(&self) -> u64 {
        self.timeouts.load(Ordering::Relaxed) // ord: Relaxed — independent telemetry counter
    }

    /// Run `f` on one pooled connection (lazily established), dropping
    /// the connection on any error so the next call reconnects.
    fn with_conn<T>(&self, f: impl FnOnce(&mut ShardConn) -> Result<T>) -> Result<T> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.conns.len(); // ord: Relaxed — round-robin cursor; no memory is published through it
        let mut slot = self.conns[i].lock().unwrap();
        if slot.is_none() {
            let sock = if self.timeout.is_zero() {
                TcpStream::connect(self.addr)?
            } else {
                TcpStream::connect_timeout(&self.addr, self.timeout)?
            };
            sock.set_nodelay(true)?;
            if !self.timeout.is_zero() {
                sock.set_read_timeout(Some(self.timeout))?;
                sock.set_write_timeout(Some(self.timeout))?;
            }
            let rd = BufReader::new(sock.try_clone()?);
            *slot = Some(ShardConn { rd, wr: sock });
        }
        let result = f(slot.as_mut().unwrap());
        if result.is_err() {
            *slot = None; // drop broken connection; next call reconnects
        }
        result
    }

    /// `true` when `e` is an I/O deadline expiry (`TimedOut` from
    /// `connect_timeout`, `WouldBlock` from `set_read_timeout`-style
    /// deadlines — platform-dependent which one a blocked socket op
    /// reports).
    fn is_timeout(e: &anyhow::Error) -> bool {
        e.chain().any(|cause| {
            cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
                matches!(
                    io.kind(),
                    std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
                )
            })
        })
    }

    /// Bounded retry: re-run `attempt` up to `retries` extra times with
    /// `Backoff` between attempts (each on a fresh connection — the
    /// failed one was dropped), counting deadline expiries.
    fn retrying<T>(&self, mut attempt: impl FnMut() -> Result<T>) -> Result<T> {
        let mut backoff = Backoff::new();
        let mut tries = 0u32;
        loop {
            match attempt() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if Self::is_timeout(&e) {
                        self.timeouts.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — independent telemetry counter
                    }
                    tries += 1;
                    if tries > self.retries {
                        return Err(e);
                    }
                    backoff.snooze();
                }
            }
        }
    }

    fn call(&self, req: &RequestRef<'_>) -> Result<Response> {
        self.retrying(|| {
            self.with_conn(|conn| {
                proto::write_request_ref(&mut conn.wr, req)?;
                proto::read_response(&mut conn.rd)
            })
        })
    }

    /// One batch round-trip for the subset of `src` selected by `sel`;
    /// the positional answers land in `out[sel[j]]`.
    fn call_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        sel: &[u32],
        src: &S,
        out: &mut [Response],
    ) -> Result<()> {
        self.retrying(|| {
            self.with_conn(|conn| {
                proto::write_batch_request(&mut conn.wr, op, sel, src)?;
                match proto::read_response(&mut conn.rd)? {
                    Response::Multi(subs) => {
                        ensure!(
                            subs.len() == sel.len(),
                            "batch answered {} of {} keys",
                            subs.len(),
                            sel.len()
                        );
                        for (j, sub) in subs.into_iter().enumerate() {
                            out[sel[j] as usize] = sub;
                        }
                        Ok(())
                    }
                    Response::Err(m) => bail!("shard refused batch: {m}"),
                    other => bail!("unexpected batch response {other:?}"),
                }
            })
        })
    }
}

impl ShardClient {
    /// Issue a borrowed request.  `digest` is the key's [`key_digest`]
    /// when already computed: a local shard reuses it (no re-hash); a
    /// remote shard serializes the request and hashes from the wire.
    pub fn call_ref(&self, req: RequestRef<'_>, digest: Option<u64>) -> Result<Response> {
        match self {
            ShardClient::Local(shard) => Ok(shard.handle_ref(req, digest)),
            ShardClient::Remote(pool) => pool.call(&req),
            ShardClient::Flaky(flaky) => flaky.call_ref(req, digest),
        }
    }

    /// Issue an owned request and await the response.
    pub fn call(&self, req: &Request) -> Result<Response> {
        self.call_ref(req.as_view(), None)
    }

    /// Issue one batch op for the keys selected by `sel` (dense indices
    /// into `src`/`digests`/`out`); the positional answers land in
    /// `out[sel[j]]`, untouched slots keep their contents.  A local shard
    /// reuses `digests[i]` (= [`key_digest`]`(src.key(i))`, required to
    /// cover every selected index) and executes under one lock
    /// acquisition per occupied stripe; a remote shard serializes the
    /// subset as **one round-trip** and re-derives digests from the wire.
    pub fn call_batch<S: BatchSource + ?Sized>(
        &self,
        op: BatchOp,
        sel: &[u32],
        src: &S,
        digests: &[u64],
        out: &mut [Response],
    ) -> Result<()> {
        match self {
            ShardClient::Local(shard) => {
                shard.run_batch(op, sel, src, digests, out);
                Ok(())
            }
            ShardClient::Remote(pool) => {
                // The wire caps a frame at MAX_BATCH keys; a larger
                // selection (owned-API batches and migration plans are
                // not parser-bounded) degrades to more round-trips, never
                // to a refused frame that would drop a healthy pooled
                // connection.
                for chunk in sel.chunks(MAX_BATCH) {
                    pool.call_batch(op, chunk, src, out)?;
                }
                Ok(())
            }
            ShardClient::Flaky(flaky) => flaky.call_batch(op, sel, src, digests, out),
        }
    }

    /// Typed GET.
    pub fn get(&self, key: &str) -> Result<Option<Value>> {
        match self.call_ref(RequestRef::Get { key }, None)? {
            Response::Val(v) => Ok(Some(v)),
            Response::Nil => Ok(None),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUT (the value buffer is moved/shared, never copied locally).
    pub fn put(&self, key: &str, value: Value) -> Result<()> {
        match self.call_ref(RequestRef::Put { key, value }, None)? {
            Response::Ok => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUTNX; `true` if the value was stored (key was absent).
    pub fn put_nx(&self, key: &str, value: Value) -> Result<bool> {
        match self.call_ref(RequestRef::PutNx { key, value }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DEL; `true` if the key existed.
    pub fn del(&self, key: &str) -> Result<bool> {
        match self.call_ref(RequestRef::Del { key }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DELTOMB: delete and leave a migration tombstone; `true` if
    /// the key existed.
    pub fn del_tomb(&self, key: &str) -> Result<bool> {
        match self.call_ref(RequestRef::DelTomb { key }, None)? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PURGETOMBS; returns how many tombstones were cleared.
    pub fn purge_tombstones(&self) -> Result<u64> {
        match self.call_ref(RequestRef::PurgeTombs, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed WIPE: drop every key and tombstone (failover rejoin);
    /// returns how many keys were cleared.
    pub fn wipe(&self) -> Result<u64> {
        match self.call_ref(RequestRef::Wipe, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DIGEST: the shard's per-stripe content digests (anti-
    /// entropy input).
    pub fn stripe_digests(&self) -> Result<Vec<u64>> {
        match self.call_ref(RequestRef::Digest, None)? {
            Response::Nums(xs) => {
                ensure!(
                    xs.len() == STRIPES,
                    "DIGEST answered {} stripes (want {STRIPES})",
                    xs.len()
                );
                Ok(xs)
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCAN.
    pub fn scan(&self) -> Result<Vec<String>> {
        match self.call_ref(RequestRef::Scan, None)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCANSTRIPE.
    pub fn scan_stripe(&self, stripe: u32) -> Result<Vec<String>> {
        match self.call_ref(RequestRef::ScanStripe { stripe }, None)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed COUNT.
    pub fn count(&self) -> Result<u64> {
        match self.call_ref(RequestRef::Count, None)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::io::Write;

    use super::*;

    /// Digest shorthand for direct `Shard` calls.
    fn kd(key: &str) -> u64 {
        key_digest(key)
    }

    fn val(bytes: &[u8]) -> Value {
        bytes.to_vec().into()
    }

    #[test]
    fn shard_basic_ops() {
        let s = Shard::new(0);
        assert_eq!(s.get("a", kd("a")), None);
        s.put("a", val(b"1"), kd("a"));
        s.put("b", val(b"2"), kd("b"));
        assert_eq!(s.get("a", kd("a")).as_deref(), Some(&b"1"[..]));
        assert_eq!(s.count(), 2);
        assert!(s.del("a", kd("a")));
        assert!(!s.del("a", kd("a")));
        assert_eq!(s.count(), 1);
        assert_eq!(s.scan(), vec!["b".to_string()]);
    }

    #[test]
    fn overwrite_reuses_the_stored_key() {
        let s = Shard::new(11);
        s.put("k", val(b"old"), kd("k"));
        s.put("k", val(b"new"), kd("k"));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"new"[..]));
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn get_shares_the_stored_buffer() {
        // The zero-copy contract: two GETs of one key return the same
        // allocation, not two copies.
        let s = Shard::new(12);
        s.put("k", val(b"payload"), kd("k"));
        let a = s.get("k", kd("k")).unwrap();
        let b = s.get("k", kd("k")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "GET must bump a refcount, not copy");
    }

    #[test]
    fn local_client_roundtrip() {
        let c = ShardClient::Local(Shard::new(1));
        c.put("k", val(b"v")).unwrap();
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(c.count().unwrap(), 1);
        assert!(c.del("k").unwrap());
        assert_eq!(c.get("k").unwrap(), None);
    }

    #[test]
    fn tcp_client_roundtrip() {
        let s = Shard::new(2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 2));
        c.put("x", vec![9u8; 1000].into()).unwrap();
        assert_eq!(c.get("x").unwrap().as_deref(), Some(&vec![9u8; 1000][..]));
        assert_eq!(c.count().unwrap(), 1);
        assert_eq!(c.scan().unwrap(), vec!["x".to_string()]);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let s = Shard::new(3);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let pool = RemotePool::new(addr, 4);
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let c = ShardClient::Remote(pool.clone());
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(&format!("k-{t}-{i}"), vec![t].into()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 400);
    }

    #[test]
    fn malformed_command_answers_err_and_keeps_the_connection() {
        // A typo'd command must not tear down the TCP session: the server
        // answers ERR and the next (valid) request still works.
        let s = Shard::new(13);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        wr.write_all(b"BOGUS x\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        wr.write_all(b"SCANSTRIPE notanumber\n").unwrap();
        wr.flush().unwrap();
        assert!(matches!(proto::read_response(&mut rd).unwrap(), Response::Err(_)));
        proto::write_request(&mut wr, &Request::Put { key: "x".into(), value: val(b"1") })
            .unwrap();
        assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        assert_eq!(s.count(), 1);
    }

    #[test]
    fn pipelined_burst_is_answered_in_order() {
        // The server coalesces responses and flushes once per drained
        // burst; the client must still see every response, in order.
        let s = Shard::new(14);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let sock = TcpStream::connect(addr).unwrap();
        let mut rd = BufReader::new(sock.try_clone().unwrap());
        let mut wr = sock;
        let mut burst = Vec::new();
        for i in 0..32 {
            proto::write_request(
                &mut burst,
                &Request::Put { key: format!("p{i}"), value: val(&[i as u8]) },
            )
            .unwrap();
        }
        for i in 0..32 {
            proto::write_request(&mut burst, &Request::Get { key: format!("p{i}") }).unwrap();
        }
        wr.write_all(&burst).unwrap();
        wr.flush().unwrap();
        for _ in 0..32 {
            assert_eq!(proto::read_response(&mut rd).unwrap(), Response::Ok);
        }
        for i in 0..32 {
            assert_eq!(
                proto::read_response(&mut rd).unwrap(),
                Response::Val(val(&[i as u8]))
            );
        }
    }

    #[test]
    fn shard_rejects_admin_commands() {
        let s = Shard::new(4);
        assert!(matches!(s.handle(&Request::ScaleUp), Response::Err(_)));
    }

    #[test]
    fn put_nx_never_overwrites() {
        let s = Shard::new(5);
        assert!(s.put_nx("k", val(b"old"), kd("k")));
        assert!(!s.put_nx("k", val(b"new"), kd("k")));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"old"[..]));
        let c = ShardClient::Local(s);
        assert!(!c.put_nx("k", val(b"newer")).unwrap());
        assert!(c.put_nx("fresh", val(b"v")).unwrap());
    }

    #[test]
    fn tombstone_bars_put_nx_until_purged() {
        let s = Shard::new(7);
        s.put("k", val(b"v"), kd("k"));
        assert!(s.del_tomb("k", kd("k")));
        assert_eq!(s.get("k", kd("k")), None);
        assert_eq!(s.count(), 0);
        // The migration copy must be refused: the delete won the race.
        assert!(!s.put_nx("k", val(b"stale"), kd("k")));
        assert_eq!(s.get("k", kd("k")), None);
        // A tombstone for a never-stored key works the same way.
        assert!(!s.del_tomb("ghost", kd("ghost")));
        assert!(!s.put_nx("ghost", val(b"stale"), kd("ghost")));
        // A client PUT is newer than the tombstoned delete and clears it.
        s.put("k", val(b"fresh"), kd("k"));
        assert_eq!(s.get("k", kd("k")).as_deref(), Some(&b"fresh"[..]));
        // Settling purges the remaining tombstone and re-enables PUTNX.
        assert_eq!(s.purge_tombstones(), 1);
        assert!(s.put_nx("ghost", val(b"reborn"), kd("ghost")));
        assert!(s.stats().contains("tombs=0"));
    }

    #[test]
    fn del_racing_migration_copy_cannot_resurrect() {
        // The exact interleaving of the former "known anomaly": the
        // migration sweep reads the source copy, the client DEL lands on
        // both owners, then the sweep's PUTNX arrives at the destination.
        let src = Shard::new(8);
        let dst = Shard::new(9);
        src.put("k", val(b"v"), kd("k"));
        let copied = src.get("k", kd("k")).unwrap(); // sweep reads the source
        assert!(!dst.del_tomb("k", kd("k"))); // client DEL, new owner first (no copy there yet)
        assert!(src.del("k", kd("k"))); // ... then old owner
        assert!(!dst.put_nx("k", copied, kd("k"))); // sweep copy refused
        assert_eq!(
            dst.get("k", kd("k")),
            None,
            "DEL racing the migration copy resurrected the key"
        );
        assert_eq!(src.get("k", kd("k")), None);
    }

    #[test]
    fn del_tomb_and_purge_over_the_wire() {
        let s = Shard::new(10);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        c.put("x", val(b"1")).unwrap();
        assert!(c.del_tomb("x").unwrap());
        assert!(!c.put_nx("x", val(b"stale")).unwrap());
        assert_eq!(c.get("x").unwrap(), None);
        assert_eq!(c.purge_tombstones().unwrap(), 1);
        assert!(c.put_nx("x", val(b"new")).unwrap());
    }

    #[test]
    fn wipe_clears_keys_and_tombstones() {
        let s = Shard::new(16);
        for i in 0..20 {
            let k = format!("w{i}");
            s.put(&k, val(&[i as u8]), kd(&k));
        }
        s.del_tomb("w0", kd("w0"));
        assert_eq!(s.wipe(), 19);
        assert_eq!(s.count(), 0);
        assert!(s.stats().contains("tombs=0"));
        // The tombstone went with the wipe: PUTNX works again.
        assert!(s.put_nx("w0", val(b"fresh"), kd("w0")));

        // And over the wire.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        assert_eq!(c.wipe().unwrap(), 1);
        assert_eq!(c.count().unwrap(), 0);
    }

    #[test]
    fn empty_values_store_and_roundtrip_the_wire() {
        // Zero-length payload edge (`PUT k 0`): store, share, and serve
        // an empty `Arc<[u8]>` locally and over TCP.
        let s = Shard::new(17);
        let empty: Value = Vec::new().into();
        s.put("e", empty.clone(), kd("e"));
        let got = s.get("e", kd("e")).unwrap();
        assert!(got.is_empty());
        assert!(Arc::ptr_eq(&got, &empty), "empty GET must share the buffer too");

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        assert_eq!(c.get("e").unwrap().as_deref(), Some(&b""[..]));
        c.put("e2", Vec::new().into()).unwrap();
        assert_eq!(c.get("e2").unwrap().as_deref(), Some(&b""[..]));
        assert!(!c.put_nx("e2", val(b"x")).unwrap(), "empty value must count as present");
        assert_eq!(c.count().unwrap(), 2);
    }

    #[test]
    fn stripe_scans_partition_the_keyset() {
        let s = Shard::new(6);
        for i in 0..64 {
            let k = format!("key-{i}");
            s.put(&k, val(&[i as u8]), kd(&k));
        }
        let mut all: Vec<String> = (0..STRIPES).flat_map(|i| s.scan_stripe(i)).collect();
        all.sort();
        let mut want = s.scan();
        want.sort();
        assert_eq!(all, want);
        assert_eq!(all.len(), 64);
        assert!(matches!(
            s.handle(&Request::ScanStripe { stripe: STRIPES as u32 }),
            Response::Err(_)
        ));
    }

    #[test]
    fn batch_ops_match_singleton_semantics() {
        let s = Shard::new(20);
        let keys: Vec<String> = (0..64).map(|i| format!("bk{i}")).collect();
        let values: Vec<Value> = (0..64).map(|i| val(&[i as u8])).collect();
        // MPUT stores everything...
        match s.handle(&Request::MPut { keys: keys.clone(), values: values.clone() }) {
            Response::Multi(subs) => {
                assert_eq!(subs.len(), 64);
                assert!(subs.iter().all(|r| *r == Response::Ok));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.count(), 64);
        // ...MGET answers positionally, including misses...
        let mut probe = keys.clone();
        probe.push("absent".into());
        match s.handle(&Request::MGet { keys: probe }) {
            Response::Multi(subs) => {
                for (i, sub) in subs.iter().take(64).enumerate() {
                    assert_eq!(*sub, Response::Val(val(&[i as u8])), "key bk{i}");
                }
                assert_eq!(subs[64], Response::Nil);
            }
            other => panic!("{other:?}"),
        }
        // ...and MDEL reports existence per key, like singleton DEL.
        match s.handle(&Request::MDel { keys: vec!["bk0".into(), "ghost".into()] }) {
            Response::Multi(subs) => {
                assert_eq!(subs, vec![Response::Ok, Response::Nil]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.count(), 63);
    }

    #[test]
    fn batch_duplicates_apply_in_request_order() {
        // Two writes of one key in a single MPUT: the later one wins,
        // exactly as if the client had pipelined two singleton PUTs.
        let s = Shard::new(21);
        match s.handle(&Request::MPut {
            keys: vec!["dup".into(), "dup".into()],
            values: vec![val(b"first"), val(b"second")],
        }) {
            Response::Multi(subs) => assert_eq!(subs, vec![Response::Ok, Response::Ok]),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.get("dup", kd("dup")).as_deref(), Some(&b"second"[..]));
    }

    #[test]
    fn batch_putnx_and_deltomb_keep_migration_semantics() {
        let s = Shard::new(22);
        s.put("held", val(b"newer"), kd("held"));
        s.put("doomed", val(b"x"), kd("doomed"));
        // MDELTOMB removes and tombstones per key.
        match s.handle(&Request::MDelTomb { keys: vec!["doomed".into(), "ghost".into()] }) {
            Response::Multi(subs) => assert_eq!(subs, vec![Response::Ok, Response::Nil]),
            other => panic!("{other:?}"),
        }
        // MPUTNX: refused where a value is held, refused where a
        // tombstone bars it, stored where free.
        match s.handle(&Request::MPutNx {
            keys: vec!["held".into(), "doomed".into(), "free".into()],
            values: vec![val(b"stale"), val(b"stale"), val(b"fresh")],
        }) {
            Response::Multi(subs) => {
                assert_eq!(subs, vec![Response::Nil, Response::Nil, Response::Ok]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.get("held", kd("held")).as_deref(), Some(&b"newer"[..]));
        assert_eq!(s.get("doomed", kd("doomed")), None);
        assert_eq!(s.get("free", kd("free")).as_deref(), Some(&b"fresh"[..]));
    }

    #[test]
    fn batches_roundtrip_the_wire_with_subset_selection() {
        let s = Shard::new(23);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 2));

        // Whole-batch MPUT over the wire.
        let keys: Vec<String> = (0..10).map(|i| format!("wk{i}")).collect();
        let values: Vec<Value> = (0..10).map(|i| val(&[i as u8, 0xAB])).collect();
        match c.call(&Request::MPut { keys: keys.clone(), values }).unwrap() {
            Response::Multi(subs) => assert!(subs.iter().all(|r| *r == Response::Ok)),
            other => panic!("{other:?}"),
        }

        // Subset selection through call_batch: only indices 2, 5 and 7
        // travel; their answers land back at those indices.
        let probe = crate::proto::Request::MGet { keys };
        let view = probe.as_view();
        let (_, batch) = view.into_batch().unwrap();
        let sel = [2u32, 5, 7];
        let mut out = vec![Response::Err("untouched".into()); 10];
        c.call_batch(BatchOp::Get, &sel, &batch, &[], &mut out).unwrap();
        for i in 0..10u8 {
            let idx = i as usize;
            if sel.contains(&(i as u32)) {
                assert_eq!(out[idx], Response::Val(val(&[i, 0xAB])), "index {i}");
            } else {
                assert_eq!(out[idx], Response::Err("untouched".into()), "index {i}");
            }
        }
    }

    #[test]
    fn empty_batches_answer_empty_multi() {
        let s = Shard::new(24);
        match s.handle(&Request::MGet { keys: Vec::new() }) {
            Response::Multi(subs) => assert!(subs.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stripe_digests_track_content_not_history() {
        let a = Shard::new(30);
        let b = Shard::new(31);
        assert_eq!(a.stripe_digests(), [0u64; STRIPES], "empty shard digests to zero");
        // Same (key, value) set inserted in different orders, with
        // detours, digests identically — the fold is order-independent
        // and content-addressed.
        for i in 0..64 {
            let k = format!("dg{i}");
            a.put(&k, val(&[i as u8]), kd(&k));
        }
        b.put("detour", val(b"x"), kd("detour"));
        for i in (0..64).rev() {
            let k = format!("dg{i}");
            b.put(&k, val(&[i as u8]), kd(&k));
        }
        assert!(b.del("detour", kd("detour")));
        assert_eq!(a.stripe_digests(), b.stripe_digests());
        // A differing value shows up in exactly its key's stripe.
        b.put("dg0", val(b"changed"), kd("dg0"));
        let (da, db) = (a.stripe_digests(), b.stripe_digests());
        let diverged: Vec<usize> = (0..STRIPES).filter(|&i| da[i] != db[i]).collect();
        assert_eq!(diverged, vec![stripe_index(kd("dg0"))]);
        // Tombstones are invisible to the digest (transient state).
        let before = a.stripe_digests();
        a.del_tomb("ghost-key", kd("ghost-key"));
        assert_eq!(a.stripe_digests(), before);
    }

    #[test]
    fn digest_roundtrips_the_wire() {
        let s = Shard::new(32);
        s.put("wired", val(b"v"), kd("wired"));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });
        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        assert_eq!(c.stripe_digests().unwrap(), s.stripe_digests().to_vec());
        assert_eq!(
            ShardClient::Local(s.clone()).stripe_digests().unwrap(),
            s.stripe_digests().to_vec()
        );
    }

    #[test]
    fn remote_pool_counts_timeouts_on_a_hung_peer() {
        // A listener that accepts and never answers: the read deadline
        // must fire (bounded stall), be counted, and surface an error
        // after the bounded retries — not hang the caller forever.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let mut held = Vec::new();
            while let Ok((sock, _)) = listener.accept() {
                held.push(sock); // hold open, never respond
            }
        });
        let pool = RemotePool::with_limits(addr, 1, Duration::from_millis(50), 1);
        let c = ShardClient::Remote(pool.clone());
        assert!(c.get("k").is_err());
        assert!(
            pool.timeouts() >= 1,
            "deadline expiries must be counted (got {})",
            pool.timeouts()
        );
    }

    #[test]
    fn flaky_shard_injects_deterministically() {
        let inner = Shard::new(33);
        // Drop mode: the faulted call never reaches the shard.
        let flaky = FlakyShard::wrap(
            ShardClient::Local(inner.clone()),
            FlakyMode::Drop,
            100,
            7,
        );
        let c = ShardClient::Flaky(flaky.clone());
        assert!(c.put("k", val(b"v")).is_err());
        assert_eq!(inner.count(), 0);
        assert_eq!((flaky.calls(), flaky.injected()), (1, 1));

        // AckLost mode: the write lands but the caller sees an error —
        // the torn-fan-out primitive.
        let torn = FlakyShard::wrap(
            ShardClient::Local(inner.clone()),
            FlakyMode::AckLost,
            100,
            7,
        );
        let c = ShardClient::Flaky(torn.clone());
        assert!(c.put("k", val(b"v")).is_err());
        assert_eq!(inner.count(), 1, "AckLost must apply the write");

        // 0% never faults; Delay always forwards.
        let clean =
            FlakyShard::wrap(ShardClient::Local(inner.clone()), FlakyMode::Drop, 0, 7);
        let c = ShardClient::Flaky(clean.clone());
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!((clean.calls(), clean.injected()), (1, 0));
        let slow =
            FlakyShard::wrap(ShardClient::Local(inner), FlakyMode::Delay, 100, 7);
        let c = ShardClient::Flaky(slow);
        assert_eq!(c.get("k").unwrap().as_deref(), Some(&b"v"[..]));
    }

    #[test]
    fn local_and_wire_paths_agree_on_stripes() {
        // A key written through the digest-threaded local path must be
        // visible to the wire path (which recomputes the digest), i.e.
        // both must select the same stripe.
        let s = Shard::new(15);
        s.put("agree", val(b"1"), kd("agree"));
        assert_eq!(
            s.handle_ref(RequestRef::Get { key: "agree" }, None),
            Response::Val(val(b"1"))
        );
    }
}
