//! Storage shard: the in-memory KV node the router places data on.
//!
//! A [`Shard`] is a striped-lock hash map with the operations the wire
//! protocol exposes.  It can be served over TCP ([`serve`], thread-per-
//! connection) for multi-process clusters, or driven in-process through
//! [`ShardClient`] — the router uses the same client type for both, so
//! the examples run a full cluster in one process while production
//! deploys one shard per host (`binhashd shard`).

use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::proto::{self, Request, Response};

/// Number of lock stripes (power of two). Public because the incremental
/// rebalancer iterates stripes (`SCANSTRIPE <i>` for `i < STRIPES`); both
/// ends of the wire share this constant.
pub const STRIPES: usize = 16;

/// One lock stripe: live values plus migration tombstones.
#[derive(Debug, Default)]
struct Stripe {
    live: HashMap<String, Vec<u8>>,
    /// Keys deleted by `DELTOMB` while a migration was in flight. A
    /// tombstone bars `PUTNX` (the migration copy step) from
    /// resurrecting the deleted key; a client `PUT` clears it, and the
    /// router purges the whole set once the migration settles.
    tombs: HashSet<String>,
}

/// An in-memory KV shard with striped locking.
#[derive(Debug)]
pub struct Shard {
    /// Shard id (equals its bucket index in the cluster).
    pub id: u32,
    stripes: Vec<Mutex<Stripe>>,
    ops: AtomicU64,
}

impl Shard {
    /// New empty shard.
    pub fn new(id: u32) -> Arc<Self> {
        Arc::new(Self {
            id,
            stripes: (0..STRIPES).map(|_| Mutex::new(Stripe::default())).collect(),
            ops: AtomicU64::new(0),
        })
    }

    fn stripe(&self, key: &str) -> &Mutex<Stripe> {
        let h = crate::hashing::xxhash64(key.as_bytes(), 0x517) as usize;
        &self.stripes[h & (STRIPES - 1)]
    }

    /// Fetch a value.
    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(key).lock().unwrap().live.get(key).cloned()
    }

    /// Store a value (clears any tombstone: a client write is always
    /// newer than the delete the tombstone recorded).
    pub fn put(&self, key: String, value: Vec<u8>) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(&key).lock().unwrap();
        s.tombs.remove(&key);
        s.live.insert(key, value);
    }

    /// Store a value only if the key is absent *and* not tombstoned;
    /// `true` if it was stored.
    ///
    /// The rebalancer's copy primitive: a migration batch must never
    /// overwrite a newer value a client already wrote to this shard, and
    /// must never resurrect a key a client deleted while the copy was in
    /// flight (the tombstone records that delete).
    pub fn put_nx(&self, key: String, value: Vec<u8>) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(&key).lock().unwrap();
        if s.live.contains_key(&key) || s.tombs.contains(&key) {
            false
        } else {
            s.live.insert(key, value);
            true
        }
    }

    /// Delete a key; `true` if it existed.
    pub fn del(&self, key: &str) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(key).lock().unwrap().live.remove(key).is_some()
    }

    /// Delete a key and leave a tombstone; `true` if it existed.
    ///
    /// The router's mid-migration delete: the tombstone guarantees that a
    /// migration copy (`PUTNX`) holding the pre-delete value cannot bring
    /// the key back after this delete wins the race.
    pub fn del_tomb(&self, key: &str) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut s = self.stripe(key).lock().unwrap();
        s.tombs.insert(key.to_string());
        s.live.remove(key).is_some()
    }

    /// Drop every tombstone (the migration they guarded has settled);
    /// returns how many were cleared.
    pub fn purge_tombstones(&self) -> u64 {
        let mut purged = 0u64;
        for s in &self.stripes {
            let mut s = s.lock().unwrap();
            purged += s.tombs.len() as u64;
            s.tombs.clear();
        }
        purged
    }

    /// All keys currently stored (rebalancer input).
    pub fn scan(&self) -> Vec<String> {
        let mut keys = Vec::new();
        for s in &self.stripes {
            keys.extend(s.lock().unwrap().live.keys().cloned());
        }
        keys
    }

    /// Keys of one lock stripe (`stripe < STRIPES`): the incremental
    /// rebalancer's unit of work — peak memory during a migration is one
    /// stripe, never the whole shard.
    pub fn scan_stripe(&self, stripe: usize) -> Vec<String> {
        self.stripes[stripe].lock().unwrap().live.keys().cloned().collect()
    }

    /// Number of keys stored.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.lock().unwrap().live.len() as u64).sum()
    }

    /// One-line stats.
    pub fn stats(&self) -> String {
        // One pass so keys= and tombs= come from the same instant per
        // stripe (and half the lock acquisitions of two sweeps).
        let (mut keys, mut tombs) = (0u64, 0usize);
        for s in &self.stripes {
            let s = s.lock().unwrap();
            keys += s.live.len() as u64;
            tombs += s.tombs.len();
        }
        format!(
            "shard={} keys={keys} tombs={tombs} ops={}",
            self.id,
            self.ops.load(Ordering::Relaxed)
        )
    }

    /// Handle one parsed request (shared by TCP and in-process paths).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Get { key } => match self.get(&key) {
                Some(v) => Response::Val(v),
                None => Response::Nil,
            },
            Request::Put { key, value } => {
                self.put(key, value);
                Response::Ok
            }
            Request::PutNx { key, value } => {
                if self.put_nx(key, value) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            Request::Del { key } => {
                if self.del(&key) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            Request::DelTomb { key } => {
                if self.del_tomb(&key) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            Request::PurgeTombs => Response::Num(self.purge_tombstones()),
            Request::Scan => Response::Keys(self.scan()),
            Request::ScanStripe { stripe } => {
                if (stripe as usize) < STRIPES {
                    Response::Keys(self.scan_stripe(stripe as usize))
                } else {
                    Response::Err(format!("stripe {stripe} out of range (< {STRIPES})"))
                }
            }
            Request::Count => Response::Num(self.count()),
            Request::Stats => Response::Info(self.stats()),
            Request::ScaleUp | Request::ScaleDown => Response::Err("not a coordinator".into()),
        }
    }
}

/// Serve a shard over TCP (thread per connection) until the listener errors.
pub fn serve(shard: Arc<Shard>, listener: TcpListener) -> Result<()> {
    loop {
        let (sock, _) = listener.accept()?;
        let shard = shard.clone();
        std::thread::spawn(move || {
            let _ = serve_conn(shard, sock);
        });
    }
}

fn serve_conn(shard: Arc<Shard>, sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true)?;
    let mut rd = BufReader::new(sock.try_clone()?);
    let mut wr = sock;
    while let Some(req) = proto::read_request(&mut rd)? {
        let resp = shard.handle(req);
        proto::write_response(&mut wr, &resp)?;
    }
    Ok(())
}

/// Client handle to a shard: in-process or remote TCP (pooled connections).
#[derive(Clone)]
pub enum ShardClient {
    /// Same-process shard (zero-copy dispatch).
    Local(Arc<Shard>),
    /// Remote shard over TCP.
    Remote(Arc<RemotePool>),
}

/// Fixed-size connection pool to a remote shard.
pub struct RemotePool {
    addr: SocketAddr,
    conns: Vec<Mutex<Option<ShardConn>>>,
    next: AtomicUsize,
}

struct ShardConn {
    rd: BufReader<TcpStream>,
    wr: TcpStream,
}

impl RemotePool {
    /// Pool with `size` lazily-established connections.
    pub fn new(addr: SocketAddr, size: usize) -> Arc<Self> {
        Arc::new(Self {
            addr,
            conns: (0..size.max(1)).map(|_| Mutex::new(None)).collect(),
            next: AtomicUsize::new(0),
        })
    }

    fn call(&self, req: &Request) -> Result<Response> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.conns.len();
        let mut slot = self.conns[i].lock().unwrap();
        if slot.is_none() {
            let sock = TcpStream::connect(self.addr)?;
            sock.set_nodelay(true)?;
            let rd = BufReader::new(sock.try_clone()?);
            *slot = Some(ShardConn { rd, wr: sock });
        }
        let conn = slot.as_mut().unwrap();
        let result = (|| {
            proto::write_request(&mut conn.wr, req)?;
            proto::read_response(&mut conn.rd)
        })();
        if result.is_err() {
            *slot = None; // drop broken connection; next call reconnects
        }
        result
    }
}

impl ShardClient {
    /// Issue a request and await the response.
    pub fn call(&self, req: Request) -> Result<Response> {
        match self {
            ShardClient::Local(shard) => Ok(shard.handle(req)),
            ShardClient::Remote(pool) => pool.call(&req),
        }
    }

    /// Typed GET.
    pub fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        match self.call(Request::Get { key: key.into() })? {
            Response::Val(v) => Ok(Some(v)),
            Response::Nil => Ok(None),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUT.
    pub fn put(&self, key: &str, value: Vec<u8>) -> Result<()> {
        match self.call(Request::Put { key: key.into(), value })? {
            Response::Ok => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUTNX; `true` if the value was stored (key was absent).
    pub fn put_nx(&self, key: &str, value: Vec<u8>) -> Result<bool> {
        match self.call(Request::PutNx { key: key.into(), value })? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DEL; `true` if the key existed.
    pub fn del(&self, key: &str) -> Result<bool> {
        match self.call(Request::Del { key: key.into() })? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DELTOMB: delete and leave a migration tombstone; `true` if
    /// the key existed.
    pub fn del_tomb(&self, key: &str) -> Result<bool> {
        match self.call(Request::DelTomb { key: key.into() })? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PURGETOMBS; returns how many tombstones were cleared.
    pub fn purge_tombstones(&self) -> Result<u64> {
        match self.call(Request::PurgeTombs)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCAN.
    pub fn scan(&self) -> Result<Vec<String>> {
        match self.call(Request::Scan)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCANSTRIPE.
    pub fn scan_stripe(&self, stripe: u32) -> Result<Vec<String>> {
        match self.call(Request::ScanStripe { stripe })? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed COUNT.
    pub fn count(&self) -> Result<u64> {
        match self.call(Request::Count)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_basic_ops() {
        let s = Shard::new(0);
        assert_eq!(s.get("a"), None);
        s.put("a".into(), b"1".to_vec());
        s.put("b".into(), b"2".to_vec());
        assert_eq!(s.get("a"), Some(b"1".to_vec()));
        assert_eq!(s.count(), 2);
        assert!(s.del("a"));
        assert!(!s.del("a"));
        assert_eq!(s.count(), 1);
        assert_eq!(s.scan(), vec!["b".to_string()]);
    }

    #[test]
    fn local_client_roundtrip() {
        let c = ShardClient::Local(Shard::new(1));
        c.put("k", b"v".to_vec()).unwrap();
        assert_eq!(c.get("k").unwrap(), Some(b"v".to_vec()));
        assert_eq!(c.count().unwrap(), 1);
        assert!(c.del("k").unwrap());
        assert_eq!(c.get("k").unwrap(), None);
    }

    #[test]
    fn tcp_client_roundtrip() {
        let s = Shard::new(2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 2));
        c.put("x", vec![9u8; 1000]).unwrap();
        assert_eq!(c.get("x").unwrap(), Some(vec![9u8; 1000]));
        assert_eq!(c.count().unwrap(), 1);
        assert_eq!(c.scan().unwrap(), vec!["x".to_string()]);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let s = Shard::new(3);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let pool = RemotePool::new(addr, 4);
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let c = ShardClient::Remote(pool.clone());
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(&format!("k-{t}-{i}"), vec![t]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 400);
    }

    #[test]
    fn shard_rejects_admin_commands() {
        let s = Shard::new(4);
        assert!(matches!(s.handle(Request::ScaleUp), Response::Err(_)));
    }

    #[test]
    fn put_nx_never_overwrites() {
        let s = Shard::new(5);
        assert!(s.put_nx("k".into(), b"old".to_vec()));
        assert!(!s.put_nx("k".into(), b"new".to_vec()));
        assert_eq!(s.get("k"), Some(b"old".to_vec()));
        let c = ShardClient::Local(s);
        assert!(!c.put_nx("k", b"newer".to_vec()).unwrap());
        assert!(c.put_nx("fresh", b"v".to_vec()).unwrap());
    }

    #[test]
    fn tombstone_bars_put_nx_until_purged() {
        let s = Shard::new(7);
        s.put("k".into(), b"v".to_vec());
        assert!(s.del_tomb("k"));
        assert_eq!(s.get("k"), None);
        assert_eq!(s.count(), 0);
        // The migration copy must be refused: the delete won the race.
        assert!(!s.put_nx("k".into(), b"stale".to_vec()));
        assert_eq!(s.get("k"), None);
        // A tombstone for a never-stored key works the same way.
        assert!(!s.del_tomb("ghost"));
        assert!(!s.put_nx("ghost".into(), b"stale".to_vec()));
        // A client PUT is newer than the tombstoned delete and clears it.
        s.put("k".into(), b"fresh".to_vec());
        assert_eq!(s.get("k"), Some(b"fresh".to_vec()));
        // Settling purges the remaining tombstone and re-enables PUTNX.
        assert_eq!(s.purge_tombstones(), 1);
        assert!(s.put_nx("ghost".into(), b"reborn".to_vec()));
        assert!(s.stats().contains("tombs=0"));
    }

    #[test]
    fn del_racing_migration_copy_cannot_resurrect() {
        // The exact interleaving of the former "known anomaly": the
        // migration sweep reads the source copy, the client DEL lands on
        // both owners, then the sweep's PUTNX arrives at the destination.
        let src = Shard::new(8);
        let dst = Shard::new(9);
        src.put("k".into(), b"v".to_vec());
        let copied = src.get("k").unwrap(); // sweep reads the source
        assert!(!dst.del_tomb("k")); // client DEL, new owner first (no copy there yet)
        assert!(src.del("k")); // ... then old owner
        assert!(!dst.put_nx("k".into(), copied)); // sweep copy refused
        assert_eq!(dst.get("k"), None, "DEL racing the migration copy resurrected the key");
        assert_eq!(src.get("k"), None);
    }

    #[test]
    fn del_tomb_and_purge_over_the_wire() {
        let s = Shard::new(10);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 1));
        c.put("x", b"1".to_vec()).unwrap();
        assert!(c.del_tomb("x").unwrap());
        assert!(!c.put_nx("x", b"stale".to_vec()).unwrap());
        assert_eq!(c.get("x").unwrap(), None);
        assert_eq!(c.purge_tombstones().unwrap(), 1);
        assert!(c.put_nx("x", b"new".to_vec()).unwrap());
    }

    #[test]
    fn stripe_scans_partition_the_keyset() {
        let s = Shard::new(6);
        for i in 0..64 {
            s.put(format!("key-{i}"), vec![i as u8]);
        }
        let mut all: Vec<String> = (0..STRIPES).flat_map(|i| s.scan_stripe(i)).collect();
        all.sort();
        let mut want = s.scan();
        want.sort();
        assert_eq!(all, want);
        assert_eq!(all.len(), 64);
        assert!(matches!(
            s.handle(Request::ScanStripe { stripe: STRIPES as u32 }),
            Response::Err(_)
        ));
    }
}
