//! Storage shard: the in-memory KV node the router places data on.
//!
//! A [`Shard`] is a striped-lock hash map with the operations the wire
//! protocol exposes.  It can be served over TCP ([`serve`], thread-per-
//! connection) for multi-process clusters, or driven in-process through
//! [`ShardClient`] — the router uses the same client type for both, so
//! the examples run a full cluster in one process while production
//! deploys one shard per host (`binhashd shard`).

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::proto::{self, Request, Response};

/// Number of lock stripes (power of two). Public because the incremental
/// rebalancer iterates stripes (`SCANSTRIPE <i>` for `i < STRIPES`); both
/// ends of the wire share this constant.
pub const STRIPES: usize = 16;

/// An in-memory KV shard with striped locking.
#[derive(Debug)]
pub struct Shard {
    /// Shard id (equals its bucket index in the cluster).
    pub id: u32,
    stripes: Vec<Mutex<HashMap<String, Vec<u8>>>>,
    ops: AtomicU64,
}

impl Shard {
    /// New empty shard.
    pub fn new(id: u32) -> Arc<Self> {
        Arc::new(Self {
            id,
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            ops: AtomicU64::new(0),
        })
    }

    fn stripe(&self, key: &str) -> &Mutex<HashMap<String, Vec<u8>>> {
        let h = crate::hashing::xxhash64(key.as_bytes(), 0x517) as usize;
        &self.stripes[h & (STRIPES - 1)]
    }

    /// Fetch a value.
    pub fn get(&self, key: &str) -> Option<Vec<u8>> {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(key).lock().unwrap().get(key).cloned()
    }

    /// Store a value.
    pub fn put(&self, key: String, value: Vec<u8>) {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(&key).lock().unwrap().insert(key, value);
    }

    /// Store a value only if the key is absent; `true` if it was stored.
    ///
    /// The rebalancer's copy primitive: a migration batch must never
    /// overwrite a newer value a client already wrote to this shard.
    pub fn put_nx(&self, key: String, value: Vec<u8>) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        let mut map = self.stripe(&key).lock().unwrap();
        if map.contains_key(&key) {
            false
        } else {
            map.insert(key, value);
            true
        }
    }

    /// Delete a key; `true` if it existed.
    pub fn del(&self, key: &str) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed);
        self.stripe(key).lock().unwrap().remove(key).is_some()
    }

    /// All keys currently stored (rebalancer input).
    pub fn scan(&self) -> Vec<String> {
        let mut keys = Vec::new();
        for s in &self.stripes {
            keys.extend(s.lock().unwrap().keys().cloned());
        }
        keys
    }

    /// Keys of one lock stripe (`stripe < STRIPES`): the incremental
    /// rebalancer's unit of work — peak memory during a migration is one
    /// stripe, never the whole shard.
    pub fn scan_stripe(&self, stripe: usize) -> Vec<String> {
        self.stripes[stripe].lock().unwrap().keys().cloned().collect()
    }

    /// Number of keys stored.
    pub fn count(&self) -> u64 {
        self.stripes.iter().map(|s| s.lock().unwrap().len() as u64).sum()
    }

    /// One-line stats.
    pub fn stats(&self) -> String {
        format!("shard={} keys={} ops={}", self.id, self.count(), self.ops.load(Ordering::Relaxed))
    }

    /// Handle one parsed request (shared by TCP and in-process paths).
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Get { key } => match self.get(&key) {
                Some(v) => Response::Val(v),
                None => Response::Nil,
            },
            Request::Put { key, value } => {
                self.put(key, value);
                Response::Ok
            }
            Request::PutNx { key, value } => {
                if self.put_nx(key, value) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            Request::Del { key } => {
                if self.del(&key) {
                    Response::Ok
                } else {
                    Response::Nil
                }
            }
            Request::Scan => Response::Keys(self.scan()),
            Request::ScanStripe { stripe } => {
                if (stripe as usize) < STRIPES {
                    Response::Keys(self.scan_stripe(stripe as usize))
                } else {
                    Response::Err(format!("stripe {stripe} out of range (< {STRIPES})"))
                }
            }
            Request::Count => Response::Num(self.count()),
            Request::Stats => Response::Info(self.stats()),
            Request::ScaleUp | Request::ScaleDown => Response::Err("not a coordinator".into()),
        }
    }
}

/// Serve a shard over TCP (thread per connection) until the listener errors.
pub fn serve(shard: Arc<Shard>, listener: TcpListener) -> Result<()> {
    loop {
        let (sock, _) = listener.accept()?;
        let shard = shard.clone();
        std::thread::spawn(move || {
            let _ = serve_conn(shard, sock);
        });
    }
}

fn serve_conn(shard: Arc<Shard>, sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true)?;
    let mut rd = BufReader::new(sock.try_clone()?);
    let mut wr = sock;
    while let Some(req) = proto::read_request(&mut rd)? {
        let resp = shard.handle(req);
        proto::write_response(&mut wr, &resp)?;
    }
    Ok(())
}

/// Client handle to a shard: in-process or remote TCP (pooled connections).
#[derive(Clone)]
pub enum ShardClient {
    /// Same-process shard (zero-copy dispatch).
    Local(Arc<Shard>),
    /// Remote shard over TCP.
    Remote(Arc<RemotePool>),
}

/// Fixed-size connection pool to a remote shard.
pub struct RemotePool {
    addr: SocketAddr,
    conns: Vec<Mutex<Option<ShardConn>>>,
    next: AtomicUsize,
}

struct ShardConn {
    rd: BufReader<TcpStream>,
    wr: TcpStream,
}

impl RemotePool {
    /// Pool with `size` lazily-established connections.
    pub fn new(addr: SocketAddr, size: usize) -> Arc<Self> {
        Arc::new(Self {
            addr,
            conns: (0..size.max(1)).map(|_| Mutex::new(None)).collect(),
            next: AtomicUsize::new(0),
        })
    }

    fn call(&self, req: &Request) -> Result<Response> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.conns.len();
        let mut slot = self.conns[i].lock().unwrap();
        if slot.is_none() {
            let sock = TcpStream::connect(self.addr)?;
            sock.set_nodelay(true)?;
            let rd = BufReader::new(sock.try_clone()?);
            *slot = Some(ShardConn { rd, wr: sock });
        }
        let conn = slot.as_mut().unwrap();
        let result = (|| {
            proto::write_request(&mut conn.wr, req)?;
            proto::read_response(&mut conn.rd)
        })();
        if result.is_err() {
            *slot = None; // drop broken connection; next call reconnects
        }
        result
    }
}

impl ShardClient {
    /// Issue a request and await the response.
    pub fn call(&self, req: Request) -> Result<Response> {
        match self {
            ShardClient::Local(shard) => Ok(shard.handle(req)),
            ShardClient::Remote(pool) => pool.call(&req),
        }
    }

    /// Typed GET.
    pub fn get(&self, key: &str) -> Result<Option<Vec<u8>>> {
        match self.call(Request::Get { key: key.into() })? {
            Response::Val(v) => Ok(Some(v)),
            Response::Nil => Ok(None),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUT.
    pub fn put(&self, key: &str, value: Vec<u8>) -> Result<()> {
        match self.call(Request::Put { key: key.into(), value })? {
            Response::Ok => Ok(()),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed PUTNX; `true` if the value was stored (key was absent).
    pub fn put_nx(&self, key: &str, value: Vec<u8>) -> Result<bool> {
        match self.call(Request::PutNx { key: key.into(), value })? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed DEL; `true` if the key existed.
    pub fn del(&self, key: &str) -> Result<bool> {
        match self.call(Request::Del { key: key.into() })? {
            Response::Ok => Ok(true),
            Response::Nil => Ok(false),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCAN.
    pub fn scan(&self) -> Result<Vec<String>> {
        match self.call(Request::Scan)? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed SCANSTRIPE.
    pub fn scan_stripe(&self, stripe: u32) -> Result<Vec<String>> {
        match self.call(Request::ScanStripe { stripe })? {
            Response::Keys(k) => Ok(k),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Typed COUNT.
    pub fn count(&self) -> Result<u64> {
        match self.call(Request::Count)? {
            Response::Num(x) => Ok(x),
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_basic_ops() {
        let s = Shard::new(0);
        assert_eq!(s.get("a"), None);
        s.put("a".into(), b"1".to_vec());
        s.put("b".into(), b"2".to_vec());
        assert_eq!(s.get("a"), Some(b"1".to_vec()));
        assert_eq!(s.count(), 2);
        assert!(s.del("a"));
        assert!(!s.del("a"));
        assert_eq!(s.count(), 1);
        assert_eq!(s.scan(), vec!["b".to_string()]);
    }

    #[test]
    fn local_client_roundtrip() {
        let c = ShardClient::Local(Shard::new(1));
        c.put("k", b"v".to_vec()).unwrap();
        assert_eq!(c.get("k").unwrap(), Some(b"v".to_vec()));
        assert_eq!(c.count().unwrap(), 1);
        assert!(c.del("k").unwrap());
        assert_eq!(c.get("k").unwrap(), None);
    }

    #[test]
    fn tcp_client_roundtrip() {
        let s = Shard::new(2);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let c = ShardClient::Remote(RemotePool::new(addr, 2));
        c.put("x", vec![9u8; 1000]).unwrap();
        assert_eq!(c.get("x").unwrap(), Some(vec![9u8; 1000]));
        assert_eq!(c.count().unwrap(), 1);
        assert_eq!(c.scan().unwrap(), vec!["x".to_string()]);
    }

    #[test]
    fn concurrent_tcp_clients() {
        let s = Shard::new(3);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = s.clone();
        std::thread::spawn(move || {
            let _ = serve(srv, listener);
        });

        let pool = RemotePool::new(addr, 4);
        let mut handles = Vec::new();
        for t in 0..8u8 {
            let c = ShardClient::Remote(pool.clone());
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    c.put(&format!("k-{t}-{i}"), vec![t]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.count(), 400);
    }

    #[test]
    fn shard_rejects_admin_commands() {
        let s = Shard::new(4);
        assert!(matches!(s.handle(Request::ScaleUp), Response::Err(_)));
    }

    #[test]
    fn put_nx_never_overwrites() {
        let s = Shard::new(5);
        assert!(s.put_nx("k".into(), b"old".to_vec()));
        assert!(!s.put_nx("k".into(), b"new".to_vec()));
        assert_eq!(s.get("k"), Some(b"old".to_vec()));
        let c = ShardClient::Local(s);
        assert!(!c.put_nx("k", b"newer".to_vec()).unwrap());
        assert!(c.put_nx("fresh", b"v".to_vec()).unwrap());
    }

    #[test]
    fn stripe_scans_partition_the_keyset() {
        let s = Shard::new(6);
        for i in 0..64 {
            s.put(format!("key-{i}"), vec![i as u8]);
        }
        let mut all: Vec<String> = (0..STRIPES).flat_map(|i| s.scan_stripe(i)).collect();
        all.sort();
        let mut want = s.scan();
        want.sort();
        assert_eq!(all, want);
        assert_eq!(all.len(), 64);
        assert!(matches!(
            s.handle(Request::ScanStripe { stripe: STRIPES as u32 }),
            Response::Err(_)
        ));
    }
}
