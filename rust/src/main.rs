//! `binhashd` — the cluster launcher and operator CLI.
//!
//! ```text
//! binhashd router [--config <file>]        run the request router
//! binhashd shard --id <n> [--listen <addr>] [--serve event|blocking] [--loops <n>]
//! binhashd lookup --key <k> --n <n> [--algorithm <name>]
//! binhashd init-config                      print a default config
//! ```
//!
//! Both servers default to the epoll readiness event loops on Linux
//! (`binhash::net`); `--serve blocking` / `router.serve = "blocking"`
//! selects the thread-per-connection fallback.
//!
//! Argument parsing is in-tree (`--flag value` pairs) — the build is fully
//! offline, so no clap.

use std::collections::HashMap;
use std::net::TcpListener;

use anyhow::{anyhow, bail, Result};

use binhash::algorithms;
use binhash::config::Config;
use binhash::net::{ServeMode, ServerOpts};
use binhash::router::Router;
use binhash::runtime::PlacementRuntime;
use binhash::shard::{RemotePool, Shard, ShardClient};

const USAGE: &str = "usage:
  binhashd router [--config <file>]
  binhashd shard --id <n> [--listen <addr>] [--serve event|blocking] [--loops <n>]
  binhashd lookup --key <key> --n <n> [--algorithm <name>]
  binhashd init-config";

/// `"event"`/`"blocking"` → [`ServeMode`].
fn parse_serve_mode(s: &str) -> Result<ServeMode> {
    match s {
        "event" => Ok(ServeMode::Event),
        "blocking" => Ok(ServeMode::Blocking),
        other => bail!("serve mode must be \"event\" or \"blocking\", got {other:?}"),
    }
}

/// Parse `--flag value` pairs into a map.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let name = a
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got {a:?}\n{USAGE}"))?;
        let value = it.next().ok_or_else(|| anyhow!("--{name} missing value"))?;
        flags.insert(name.to_string(), value.clone());
    }
    Ok(flags)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        bail!("{USAGE}");
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "router" => {
            let cfg = match flags.get("config") {
                Some(path) => Config::load(path)?,
                None => Config::default(),
            };
            cfg.validate()?;
            run_router(cfg)
        }
        "shard" => {
            let id: u32 = flags
                .get("id")
                .ok_or_else(|| anyhow!("--id required"))?
                .parse()?;
            let listen = flags
                .get("listen")
                .cloned()
                .unwrap_or_else(|| "127.0.0.1:7700".to_string());
            let mode = parse_serve_mode(flags.get("serve").map_or("event", String::as_str))?;
            let loops = flags.get("loops").map_or(Ok(0), |s| s.parse())?;
            let shard = Shard::new(id);
            let listener = TcpListener::bind(&listen)?;
            eprintln!("shard {id} listening on {listen} ({mode:?} mode)");
            let opts = ServerOpts { mode, loops, ..ServerOpts::default() };
            binhash::shard::server(shard, listener, opts)?.run()
        }
        "lookup" => {
            let key = flags.get("key").ok_or_else(|| anyhow!("--key required"))?;
            let n: u32 = flags.get("n").ok_or_else(|| anyhow!("--n required"))?.parse()?;
            let algorithm = flags.get("algorithm").map(String::as_str).unwrap_or("binomial");
            let engine = algorithms::by_name(algorithm, n)
                .ok_or_else(|| anyhow!("unknown algorithm {algorithm:?}"))?;
            println!("{}", engine.bucket_for_key(key.as_bytes()));
            Ok(())
        }
        "init-config" => {
            print!("{}", Config::default().to_toml());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Build the configured placement engine: the bare algorithm, or a
/// [`Weighted`](algorithms::weighted::Weighted) stack over it when
/// `[placement] weights` is set (validated to match `initial_shards`).
fn build_engine(cfg: &Config) -> Result<Box<dyn algorithms::ConsistentHasher>> {
    let n = cfg.cluster.initial_shards;
    if cfg.placement.weights.is_empty() {
        return algorithms::by_name(&cfg.cluster.algorithm, n)
            .ok_or_else(|| anyhow!("unknown algorithm {:?}", cfg.cluster.algorithm));
    }
    let weighted =
        algorithms::weighted::Weighted::new(&cfg.cluster.algorithm, &cfg.placement.weights, 1)
            .ok_or_else(|| anyhow!("unknown algorithm {:?}", cfg.cluster.algorithm))?;
    Ok(Box::new(weighted))
}

fn run_router(cfg: Config) -> Result<()> {
    let n = cfg.cluster.initial_shards;
    let placement = build_engine(&cfg)?;
    let cluster = if cfg.router.shard_addrs.is_empty() {
        let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
        binhash::cluster::Cluster::new(placement, shards)
    } else {
        let shards = cfg
            .router
            .shard_addrs
            .iter()
            .map(|a| Ok(ShardClient::Remote(RemotePool::new(a.parse()?, cfg.router.pool))))
            .collect::<Result<Vec<_>>>()?;
        binhash::cluster::Cluster::new(placement, shards)
    };

    let bulk = if cfg.artifacts.enable_bulk {
        let runtime = PlacementRuntime::load(&cfg.artifacts.dir)?;
        eprintln!("bulk runtime loaded from {} (omega={})", cfg.artifacts.dir, runtime.omega);
        Some(runtime)
    } else {
        None
    };

    let router = Router::with_placement(
        cluster,
        Box::new(|id| ShardClient::Local(Shard::new(id))),
        bulk,
        cfg.replication.factor,
        cfg.replication.write_mode == "all",
        cfg.placement.hot_cache_keys,
    );
    let listener = TcpListener::bind(&cfg.router.listen)?;
    let opts = ServerOpts {
        mode: parse_serve_mode(&cfg.router.serve)?,
        loops: cfg.router.event_loops,
        max_conns: cfg.router.max_conns,
        ..ServerOpts::default()
    };
    eprintln!(
        "router listening on {} (algo={}, n={}, serve={}, max_conns={}, replication={}x/{}, \
         weighted={}, hot_cache_keys={})",
        cfg.router.listen,
        cfg.cluster.algorithm,
        n,
        cfg.router.serve,
        cfg.router.max_conns,
        cfg.replication.factor,
        cfg.replication.write_mode,
        !cfg.placement.weights.is_empty(),
        cfg.placement.hot_cache_keys
    );
    router.server(listener, opts)?.run()
}
