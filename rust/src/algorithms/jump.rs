//! **JumpHash** (Lamping & Veach, 2014) — the classic O(log n) minimal-
//! memory consistent hash, implemented exactly per the published
//! pseudocode (including its 64-bit LCG and floating-point jump step).
//!
//! Included as the non-constant-time reference point the constant-time
//! family (BinomialHash, JumpBackHash, PowerCH, FlipHash) is measured
//! against.

use super::ConsistentHasher;

const LCG_MUL: u64 = 2862933555777941757;

/// Lamping–Veach jump consistent hash: digest × n → bucket.
#[inline]
pub fn jump_hash(mut key: u64, n: u32) -> u32 {
    debug_assert!(n >= 1);
    let mut b: i64 = -1;
    let mut j: i64 = 0;
    while j < n as i64 {
        b = j;
        key = key.wrapping_mul(LCG_MUL).wrapping_add(1);
        j = (((b + 1) as f64) * ((1u64 << 31) as f64 / ((key >> 33) as f64 + 1.0))) as i64;
    }
    b as u32
}

/// JumpHash wrapped in the [`ConsistentHasher`] interface.
#[derive(Debug, Clone, Copy)]
pub struct JumpHash {
    n: u32,
}

impl JumpHash {
    /// Create with `n` buckets.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n }
    }
}

impl ConsistentHasher for JumpHash {
    fn name(&self) -> &'static str {
        "jump"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        jump_hash(digest, self.n)
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let mut rng = SplitMix64Rng::new(3);
        for n in [1u32, 2, 3, 17, 100, 4096] {
            for _ in 0..300 {
                assert!(jump_hash(rng.next_u64(), n) < n);
            }
        }
    }

    #[test]
    fn monotone_single_step() {
        let mut rng = SplitMix64Rng::new(8);
        for _ in 0..3_000 {
            let h = rng.next_u64();
            let n = 1 + (rng.next_below(500) as u32);
            let before = jump_hash(h, n);
            let after = jump_hash(h, n + 1);
            assert!(after == before || after == n, "h={h} n={n}");
        }
    }

    #[test]
    fn balanced_rough() {
        let n = 10u32;
        let k = 100_000;
        let mut counts = vec![0u32; n as usize];
        let mut rng = SplitMix64Rng::new(77);
        for _ in 0..k {
            counts[jump_hash(rng.next_u64(), n) as usize] += 1;
        }
        let mean = k as f64 / n as f64;
        for c in counts {
            assert!((c as f64 - mean).abs() < 0.1 * mean, "c={c} mean={mean}");
        }
    }
}
