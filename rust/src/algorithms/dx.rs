//! **DxHash** (Dong & Wang, 2021) — per the published design: an *NSArray*
//! (node-state bitmap) of capacity `2^t ≥ n` plus a per-key pseudo-random
//! probe sequence; the lookup walks the key's sequence until it hits a
//! working slot.  Expected O(capacity/n) = O(1) probes while the array is
//! at most ~2× over-provisioned.
//!
//! The capacity is fixed at construction (the paper's NSArray resize is a
//! stop-the-world rebuild that remaps ~half the keys — the same documented
//! trade-off as AnchorHash's anchor set, so this implementation exposes it
//! the same way: pre-provision capacity, panic past it).  Supports
//! arbitrary removals natively (flip the slot's bit); state is
//! O(capacity) bits.

use crate::hashing::{hash2, next_pow2};

use super::{ConsistentHasher, FaultTolerant};

/// Default capacity headroom multiplier over `next_pow2(n)`.
const HEADROOM: u64 = 2;

/// Minimum capacity (gives small clusters room to grow in tests/examples).
const MIN_CAPACITY: u64 = 64;

/// DxHash state: node-state bitmap + working count.
#[derive(Debug, Clone)]
pub struct DxHash {
    /// `true` = slot is a working bucket.
    active: Vec<bool>,
    /// Number of working buckets.
    n: u32,
    /// Highest bucket id ever assigned (LIFO add frontier).
    frontier: u32,
}

impl DxHash {
    /// Create with buckets `0..n` working and default capacity headroom.
    pub fn new(n: u32) -> Self {
        Self::with_capacity(n, (next_pow2(n as u64) * HEADROOM).max(MIN_CAPACITY) as u32)
    }

    /// Create with an explicit power-of-two capacity `>= n`.
    pub fn with_capacity(n: u32, capacity: u32) -> Self {
        assert!(n >= 1);
        assert!(capacity >= n && (capacity as u64).is_power_of_two());
        let mut active = vec![false; capacity as usize];
        active[..n as usize].fill(true);
        Self { active, n, frontier: n }
    }

    /// NSArray capacity.
    pub fn capacity(&self) -> u32 {
        self.active.len() as u32
    }
}

impl ConsistentHasher for DxHash {
    fn name(&self) -> &'static str {
        "dx"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        let mask = self.active.len() as u64 - 1;
        // Pseudo-random probe sequence R_i(key); expected O(cap/n) probes.
        let mut h = digest;
        loop {
            let c = (h & mask) as usize;
            if self.active[c] {
                return c as u32;
            }
            h = hash2(h, 0xD0_0D);
        }
    }

    fn add_bucket(&mut self) -> u32 {
        assert!(
            (self.frontier as usize) < self.active.len(),
            "NSArray capacity exhausted (construct with more headroom; a \
             resize is a stop-the-world rebuild in the published design)"
        );
        let b = self.frontier;
        self.active[b as usize] = true;
        self.frontier += 1;
        self.n += 1;
        b
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.frontier -= 1;
        let b = self.frontier;
        assert!(self.active[b as usize], "LIFO remove expects last-added working");
        self.active[b as usize] = false;
        self.n -= 1;
        b
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }

    // `add_bucket` assigns at the frontier, so growth headroom is the
    // slots above it; holes below it (arbitrary removals) are not
    // reusable by LIFO scaling.
    fn max_buckets(&self) -> Option<u32> {
        Some(self.active.len() as u32 - self.frontier + self.n)
    }

    // LIFO-ready iff there are no holes below the frontier.
    fn lifo_ready(&self) -> bool {
        self.frontier == self.n
    }

    // Growth *composes* with outstanding failures: `add_bucket` assigns
    // at the frontier, which is disjoint from any holes below it, so a
    // degraded dx cluster can still scale up (capacity headroom is
    // reported via `max_buckets`).
    fn grow_ready(&self) -> Result<(), String> {
        Ok(())
    }

    // Shrink retires the frontier bucket, so it composes with failures
    // exactly when that bucket is itself still working.
    fn shrink_ready(&self) -> Result<(), String> {
        let tail = self.frontier - 1;
        if self.active[tail as usize] {
            Ok(())
        } else {
            Err(format!(
                "the LIFO tail bucket {tail} is itself failed; restore it before \
                 scaling down"
            ))
        }
    }

    fn as_fault_tolerant(&self) -> Option<&dyn FaultTolerant> {
        Some(self)
    }

    fn as_fault_tolerant_mut(&mut self) -> Option<&mut dyn FaultTolerant> {
        Some(self)
    }
}

impl FaultTolerant for DxHash {
    fn remove_arbitrary(&mut self, b: u32) {
        assert!(self.is_working(b));
        assert!(self.n > 1);
        self.active[b as usize] = false;
        self.n -= 1;
    }

    fn restore(&mut self, b: u32) {
        assert!((b as usize) < self.active.len() && !self.active[b as usize]);
        self.active[b as usize] = true;
        self.n += 1;
    }

    fn is_working(&self, b: u32) -> bool {
        (b as usize) < self.active.len() && self.active[b as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range_and_active() {
        let mut h = DxHash::new(11);
        h.remove_arbitrary(3);
        let mut rng = SplitMix64Rng::new(5);
        for _ in 0..3_000 {
            let b = h.bucket(rng.next_u64());
            assert!(h.is_working(b));
        }
    }

    #[test]
    fn arbitrary_removal_minimal_disruption() {
        let mut h = DxHash::new(12);
        let mut rng = SplitMix64Rng::new(6);
        let digests: Vec<u64> = (0..5_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        h.remove_arbitrary(5);
        for (&d, &b) in digests.iter().zip(&before) {
            let after = h.bucket(d);
            if b != 5 {
                assert_eq!(after, b);
            }
        }
        h.restore(5);
        let restored: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, restored);
    }

    #[test]
    fn add_monotone_within_capacity() {
        let mut h = DxHash::new(8);
        let mut rng = SplitMix64Rng::new(7);
        let digests: Vec<u64> = (0..4_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        let added = h.add_bucket();
        for (&d, &b) in digests.iter().zip(&before) {
            let after = h.bucket(d);
            assert!(after == b || after == added, "{b} -> {after}");
        }
    }

    #[test]
    fn grow_and_shrink_roundtrip() {
        let mut h = DxHash::new(2);
        let ids: Vec<u32> = (0..30).map(|_| h.add_bucket()).collect();
        assert_eq!(h.len(), 32);
        assert_eq!(ids, (2..32).collect::<Vec<_>>());
        for _ in 0..30 {
            h.remove_bucket();
        }
        assert_eq!(h.len(), 2);
        let mut rng = SplitMix64Rng::new(7);
        for _ in 0..500 {
            assert!(h.bucket(rng.next_u64()) < 2);
        }
    }

    #[test]
    #[should_panic(expected = "capacity exhausted")]
    fn capacity_exhaustion_panics() {
        let mut h = DxHash::with_capacity(4, 4);
        h.add_bucket();
    }

    #[test]
    fn degraded_growth_composes_but_failed_tail_blocks_shrink() {
        let mut h = DxHash::new(4);
        h.remove_arbitrary(1);
        // A hole below the frontier never blocks growth: the next bucket
        // is assigned at the frontier (id 4 here), not in the hole.
        assert!(h.grow_ready().is_ok());
        assert!(!h.lifo_ready());
        assert_eq!(h.add_bucket(), 4);
        assert_eq!(h.len(), 4);
        // The frontier bucket is working: shrink composes too.
        assert!(h.shrink_ready().is_ok());
        assert_eq!(h.remove_bucket(), 4);
        // Fail the tail itself: shrink must report it, not panic.
        h.remove_arbitrary(3);
        assert!(h.shrink_ready().unwrap_err().contains('3'));
        h.restore(3);
        assert!(h.shrink_ready().is_ok());
    }

    #[test]
    fn balanced_rough() {
        let h = DxHash::new(11);
        let k = 110_000u32;
        let mut counts = vec![0u32; 11];
        let mut rng = SplitMix64Rng::new(8);
        for _ in 0..k {
            counts[h.bucket(rng.next_u64()) as usize] += 1;
        }
        let mean = k as f64 / 11.0;
        for c in counts {
            assert!((c as f64 - mean).abs() < 0.08 * mean);
        }
    }
}
