//! **MementoHash**-style arbitrary-removal extension (Coluzzi et al., ToN
//! 2024) — the mechanism the BinomialHash paper's §7 points to for
//! handling random node failures on top of a LIFO constant-time algorithm.
//!
//! Design (documented reconstruction of the published semantics): a LIFO
//! base algorithm (BinomialHash here) maps the digest over the *total*
//! bucket range `[0, size)`; a compact *memento* — the set of removed
//! buckets — redirects keys that land on a failed bucket along a per-key
//! deterministic replacement chain (`b → hash(digest, b) mod size → …`)
//! until a working bucket is found.  Because the chain is a fixed per-key
//! sequence, removing a bucket relocates exactly the keys resting on it,
//! and restoring it brings exactly those keys back: minimal disruption and
//! monotonicity under arbitrary failures.  Expected lookup cost is
//! `size/working` chain steps — O(1) while failures are a bounded
//! fraction, the published regime.
//!
//! LIFO scaling (add/remove of the *last* bucket) is delegated to the base
//! algorithm and is only permitted while no arbitrary removals are
//! outstanding (same restriction as the published evaluation, which
//! benchmarks the failure and scaling regimes separately).

use std::collections::HashSet;

use crate::hashing::hash2;

use super::{binomial::BinomialHash, ConsistentHasher, FaultTolerant};

/// BinomialHash wrapped with a Memento-style failure table.
#[derive(Debug, Clone)]
pub struct MementoHash {
    base: BinomialHash,
    /// Removed (failed) buckets — the "memento".
    removed: HashSet<u32>,
}

impl MementoHash {
    /// Create with `n` working buckets and no failures.
    pub fn new(n: u32) -> Self {
        Self { base: BinomialHash::new(n), removed: HashSet::new() }
    }

    /// Number of failed buckets currently tracked.
    pub fn failed(&self) -> usize {
        self.removed.len()
    }

    /// Total bucket range (working + failed).
    pub fn size(&self) -> u32 {
        self.base.len()
    }
}

impl ConsistentHasher for MementoHash {
    fn name(&self) -> &'static str {
        "memento"
    }

    fn len(&self) -> u32 {
        self.base.len() - self.removed.len() as u32
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        let mut b = self.base.bucket(digest);
        if self.removed.is_empty() {
            return b;
        }
        // Replacement chain: deterministic per-key walk over [0, size).
        let size = self.base.len() as u64;
        let mut h = digest;
        while self.removed.contains(&b) {
            h = hash2(h, b as u64);
            b = ((h as u128 * size as u128) >> 64) as u32;
        }
        b
    }

    fn add_bucket(&mut self) -> u32 {
        assert!(
            self.removed.is_empty(),
            "LIFO scaling requires all failed buckets to be restored first"
        );
        self.base.add_bucket()
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(
            self.removed.is_empty(),
            "LIFO scaling requires all failed buckets to be restored first"
        );
        self.base.remove_bucket()
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }

    // LIFO scaling is only defined while the failure table is empty
    // (`add_bucket`/`remove_bucket` assert this).
    fn lifo_ready(&self) -> bool {
        self.removed.is_empty()
    }

    // Resizing the base changes every replacement chain's modulus, which
    // would silently remap keys resting on failed buckets — the published
    // design (and this implementation's asserts) therefore forbids
    // resizing until the failure table is empty.
    fn grow_ready(&self) -> Result<(), String> {
        if self.removed.is_empty() {
            Ok(())
        } else {
            Err("resizing would change the replacement-chain modulus while the \
                 failure table is non-empty; restore the failed buckets first"
                .to_string())
        }
    }

    fn shrink_ready(&self) -> Result<(), String> {
        self.grow_ready()
    }

    fn as_fault_tolerant(&self) -> Option<&dyn FaultTolerant> {
        Some(self)
    }

    fn as_fault_tolerant_mut(&mut self) -> Option<&mut dyn FaultTolerant> {
        Some(self)
    }
}

impl FaultTolerant for MementoHash {
    fn remove_arbitrary(&mut self, b: u32) {
        assert!(b < self.base.len(), "bucket {b} out of range");
        assert!(self.len() > 1, "cannot fail the last working bucket");
        assert!(self.removed.insert(b), "bucket {b} already failed");
    }

    fn restore(&mut self, b: u32) {
        assert!(self.removed.remove(&b), "bucket {b} was not failed");
    }

    fn is_working(&self, b: u32) -> bool {
        b < self.base.len() && !self.removed.contains(&b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn no_failures_equals_base() {
        let m = MementoHash::new(13);
        let base = BinomialHash::new(13);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..2_000 {
            let d = rng.next_u64();
            assert_eq!(m.bucket(d), base.bucket(d));
        }
    }

    #[test]
    fn failure_minimal_disruption() {
        let mut m = MementoHash::new(16);
        let mut rng = SplitMix64Rng::new(2);
        let digests: Vec<u64> = (0..5_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
        m.remove_arbitrary(6);
        for (&d, &b) in digests.iter().zip(&before) {
            let after = m.bucket(d);
            if b != 6 {
                assert_eq!(after, b);
            } else {
                assert_ne!(after, 6);
            }
        }
    }

    #[test]
    fn restore_is_exact_inverse() {
        let mut m = MementoHash::new(16);
        let mut rng = SplitMix64Rng::new(3);
        let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
        m.remove_arbitrary(2);
        m.remove_arbitrary(11);
        m.restore(2);
        m.restore(11);
        let after: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn cascading_failures_stay_working() {
        let mut m = MementoHash::new(20);
        for b in [3u32, 7, 12, 13, 19, 0, 5] {
            m.remove_arbitrary(b);
        }
        assert_eq!(m.len(), 13);
        let mut rng = SplitMix64Rng::new(4);
        for _ in 0..3_000 {
            let b = m.bucket(rng.next_u64());
            assert!(m.is_working(b), "landed on failed bucket {b}");
        }
    }

    #[test]
    fn failed_keys_redistribute_uniformly() {
        let mut m = MementoHash::new(8);
        m.remove_arbitrary(7);
        let k = 80_000u32;
        let mut counts = vec![0u32; 8];
        let mut rng = SplitMix64Rng::new(5);
        for _ in 0..k {
            counts[m.bucket(rng.next_u64()) as usize] += 1;
        }
        assert_eq!(counts[7], 0);
        let mean = k as f64 / 7.0;
        for &c in &counts[..7] {
            assert!((c as f64 - mean).abs() < 0.08 * mean, "c={c} mean={mean}");
        }
    }

    #[test]
    #[should_panic(expected = "LIFO scaling")]
    fn scaling_with_outstanding_failures_panics() {
        let mut m = MementoHash::new(8);
        m.remove_arbitrary(3);
        m.add_bucket();
    }

    #[test]
    fn degraded_scaling_reports_instead_of_panicking() {
        let mut m = MementoHash::new(8);
        assert!(m.grow_ready().is_ok());
        m.remove_arbitrary(3);
        assert!(m.grow_ready().unwrap_err().contains("restore"));
        assert!(m.shrink_ready().is_err());
        // Restore order is unconstrained for memento.
        assert!(m.restore_blocked(3).is_none());
        m.restore(3);
        assert!(m.grow_ready().is_ok() && m.shrink_ready().is_ok());
    }
}
