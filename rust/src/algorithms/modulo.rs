//! **Naive modulo hashing** — the anti-baseline (paper §3).
//!
//! `bucket = digest mod n` is perfectly balanced and O(1) but *not
//! consistent*: changing `n` remaps ~`1 − 1/max(n, n′)`… in practice about
//! half of all keys, versus `1/(n+1)` for every consistent algorithm in
//! this suite.  Included so the disruption benches quantify exactly what
//! consistent hashing buys (the paper's §3 motivation).

use super::ConsistentHasher;

/// `digest mod n` (Lemire multiply-shift; no modulo on the hot path).
#[derive(Debug, Clone, Copy)]
pub struct ModuloHash {
    n: u32,
}

impl ModuloHash {
    /// Create with `n` buckets.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n }
    }
}

impl ConsistentHasher for ModuloHash {
    fn name(&self) -> &'static str {
        "modulo"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        ((digest as u128 * self.n as u128) >> 64) as u32
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }

    // Resizing reshuffles ~half the keyset between surviving buckets (the
    // whole point of the anti-baseline), so every shard is a migration
    // source on scale-down.
    fn minimal_disruption(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range_and_balanced() {
        let h = ModuloHash::new(10);
        let mut counts = vec![0u32; 10];
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..100_000 {
            counts[h.bucket(rng.next_u64()) as usize] += 1;
        }
        let mean = 10_000.0;
        for c in counts {
            assert!((c as f64 - mean).abs() < 0.05 * mean);
        }
    }

    #[test]
    fn demonstrates_non_consistency() {
        // The whole point: n -> n+1 moves ~n/(n+1) of keys, not 1/(n+1).
        let a = ModuloHash::new(10);
        let b = ModuloHash::new(11);
        let mut rng = SplitMix64Rng::new(2);
        let moved = (0..50_000)
            .filter(|_| {
                let d = rng.next_u64();
                a.bucket(d) != b.bucket(d)
            })
            .count();
        let frac = moved as f64 / 50_000.0;
        // Range-partition reduction moves exactly 1/2 asymptotically
        // (true `% n` moves 1 - 1/n — even worse).
        assert!(frac > 0.4, "naive modulo moved only {frac}");
    }
}
