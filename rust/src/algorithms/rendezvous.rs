//! **Rendezvous / HRW hashing** (Thaler & Ravishankar, 1996): a key maps
//! to the bucket maximizing `hash(key, bucket)`.  O(n) per lookup, zero
//! state beyond `n`, perfect minimal disruption and monotonicity — the
//! simplicity baseline in the survey comparison.

use crate::hashing::hash2;

use super::ConsistentHasher;

/// Highest-random-weight hashing.
#[derive(Debug, Clone, Copy)]
pub struct Rendezvous {
    n: u32,
}

impl Rendezvous {
    /// Create with `n` buckets.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n }
    }
}

impl ConsistentHasher for Rendezvous {
    fn name(&self) -> &'static str {
        "rendezvous"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        let mut best = 0u32;
        let mut best_w = hash2(digest, 0);
        for b in 1..self.n {
            let w = hash2(digest, b as u64);
            if w > best_w {
                best_w = w;
                best = b;
            }
        }
        best
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn monotone_exact() {
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..3_000 {
            let d = rng.next_u64();
            let n = 1 + rng.next_below(100) as u32;
            let before = Rendezvous::new(n).bucket(d);
            let after = Rendezvous::new(n + 1).bucket(d);
            assert!(after == before || after == n);
        }
    }

    #[test]
    fn balanced_rough() {
        let h = Rendezvous::new(10);
        let k = 100_000u32;
        let mut counts = vec![0u32; 10];
        let mut rng = SplitMix64Rng::new(2);
        for _ in 0..k {
            counts[h.bucket(rng.next_u64()) as usize] += 1;
        }
        let mean = k as f64 / 10.0;
        for c in counts {
            assert!((c as f64 - mean).abs() < 0.06 * mean);
        }
    }
}
