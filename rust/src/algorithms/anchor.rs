//! **AnchorHash** (Mendelson et al., ToN 2020) — per the published
//! pseudocode (Algorithm 2 of the paper, the array-based implementation).
//!
//! AnchorHash pre-allocates an *anchor set* of `a` buckets and keeps a
//! *working set* of `w ≤ a`; lookups hash into the anchor and follow the
//! removal metadata (`A`, `K`, `W`, `L` arrays) to the working bucket a
//! removed anchor position delegates to.  O(1) amortized lookups (expected
//! ≤ 1/(1−w/a) hash evaluations), supports arbitrary removals natively,
//! state is O(a).
//!
//! The anchor capacity bounds the maximum cluster size; choose it with
//! headroom (the registry uses `2 · next_pow2(n)`).

use crate::hashing::hash2;

use super::{ConsistentHasher, FaultTolerant};

/// AnchorHash state (arrays `A`, `K`, `W`, `L` + removal stack `R`).
#[derive(Debug, Clone)]
pub struct AnchorHash {
    /// `A[b]` = size of the working set at the moment `b` was removed
    /// (0 while `b` is working).
    a: Vec<u32>,
    /// `K[b]` = successor bucket `b` delegates to.
    k: Vec<u32>,
    /// `W[l]` = the working bucket currently at logical position `l`.
    w: Vec<u32>,
    /// `L[b]` = logical position of working bucket `b`.
    l: Vec<u32>,
    /// Stack of removed buckets (LIFO restore order).
    r: Vec<u32>,
    /// Current working-set size.
    n: u32,
}

impl AnchorHash {
    /// Create with `w` working buckets in an anchor of `capacity` buckets.
    ///
    /// # Panics
    /// Panics if `w == 0` or `w > capacity`.
    pub fn with_capacity(w: u32, capacity: u32) -> Self {
        assert!(w >= 1 && w <= capacity);
        let cap = capacity as usize;
        let mut this = Self {
            a: vec![0; cap],
            k: (0..capacity).collect(),
            w: (0..capacity).collect(),
            l: (0..capacity).collect(),
            r: Vec::with_capacity(cap),
            n: capacity,
        };
        // Remove buckets capacity-1 .. w to shrink the working set to w.
        for b in (w..capacity).rev() {
            this.remove_arbitrary(b);
        }
        this
    }

    /// Anchor capacity `a`.
    pub fn capacity(&self) -> u32 {
        self.a.len() as u32
    }
}

impl ConsistentHasher for AnchorHash {
    fn name(&self) -> &'static str {
        "anchor"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        let cap = self.a.len() as u64;
        // Initial anchor position.
        let mut b = (hash2(digest, 0xA_C0FFEE) % cap) as u32;
        while self.a[b as usize] > 0 {
            // b was removed when the working set had size A[b]; re-hash
            // into [0, A[b]) and walk the K chain past buckets removed
            // at-or-after b's removal.
            let mut h = (hash2(digest, b as u64) % self.a[b as usize] as u64) as u32;
            while self.a[h as usize] >= self.a[b as usize] && self.a[h as usize] > 0 {
                h = self.k[h as usize];
            }
            b = h;
        }
        b
    }

    fn add_bucket(&mut self) -> u32 {
        let b = self.r.pop().expect("anchor capacity exhausted");
        self.restore_internal(b);
        b
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        // LIFO interface: remove the working bucket at the top logical
        // position, which for LIFO usage is the last added.
        let b = self.w[(self.n - 1) as usize];
        self.remove_arbitrary(b);
        b
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }

    fn max_buckets(&self) -> Option<u32> {
        Some(self.a.len() as u32)
    }

    // LIFO-ready iff the working set is exactly `0..n`: the removal
    // stack, top-down, must hold precisely `n, n+1, …, capacity-1`
    // (construction/LIFO order).  Checking only the top is not enough —
    // an arbitrary removal of bucket `n` itself would look LIFO while
    // holes remain below it and working buckets sit above it.
    fn lifo_ready(&self) -> bool {
        self.r.iter().rev().copied().eq(self.n..self.capacity())
    }

    // `add_bucket` pops the removal stack, so while arbitrary removals
    // are outstanding it would *restore* the most recent failure instead
    // of growing at the tail — restore-then-resize is the only legal
    // order for anchor.
    fn grow_ready(&self) -> Result<(), String> {
        if self.lifo_ready() {
            return Ok(());
        }
        let top = self.r.last().copied().expect("degraded anchor has a removal stack");
        Err(format!(
            "add_bucket would restore failed bucket {top} instead of growing at the \
             tail; restore the failed buckets (in reverse removal order) before resizing"
        ))
    }

    fn shrink_ready(&self) -> Result<(), String> {
        if self.lifo_ready() {
            return Ok(());
        }
        Err("remove_bucket would retire a bucket out of LIFO order while failed \
             buckets are outstanding; restore them (in reverse removal order) before \
             resizing"
            .to_string())
    }

    fn as_fault_tolerant(&self) -> Option<&dyn FaultTolerant> {
        Some(self)
    }

    fn as_fault_tolerant_mut(&mut self) -> Option<&mut dyn FaultTolerant> {
        Some(self)
    }
}

impl AnchorHash {
    fn restore_internal(&mut self, b: u32) {
        let n = self.n as usize;
        self.a[b as usize] = 0;
        self.l[self.w[n] as usize] = n as u32;
        self.w[self.l[b as usize] as usize] = b;
        self.k[b as usize] = b;
        self.n += 1;
    }
}

impl FaultTolerant for AnchorHash {
    fn remove_arbitrary(&mut self, b: u32) {
        assert!(self.is_working(b), "bucket {b} is not working");
        assert!(self.n > 1);
        self.r.push(b);
        self.n -= 1;
        let n = self.n as usize;
        self.a[b as usize] = self.n; // working size after removal
        self.w[self.l[b as usize] as usize] = self.w[n];
        self.l[self.w[n] as usize] = self.l[b as usize];
        self.k[b as usize] = self.w[n];
    }

    fn restore(&mut self, b: u32) {
        let top = self.r.pop().expect("nothing to restore");
        assert_eq!(top, b, "AnchorHash restores in reverse removal order");
        self.restore_internal(b);
    }

    fn is_working(&self, b: u32) -> bool {
        (b as usize) < self.a.len() && self.a[b as usize] == 0 && !self.r.contains(&b)
    }

    // The removal metadata (`A[b]` = working-set size at removal time)
    // only unwinds in reverse order, so `restore` is stack-disciplined;
    // report the required order instead of letting `restore` assert.
    fn restore_blocked(&self, b: u32) -> Option<String> {
        match self.r.last() {
            Some(&top) if top == b => None,
            Some(&top) => Some(format!(
                "anchor restores in reverse removal order; restore bucket {top} first"
            )),
            None => Some("anchor has no removed bucket to restore".to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    fn working_set(h: &AnchorHash) -> Vec<u32> {
        (0..h.capacity()).filter(|&b| h.a[b as usize] == 0).collect()
    }

    #[test]
    fn lookup_hits_working_buckets_only() {
        let h = AnchorHash::with_capacity(7, 32);
        let ws = working_set(&h);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..3_000 {
            let b = h.bucket(rng.next_u64());
            assert!(ws.contains(&b), "b={b} ws={ws:?}");
        }
    }

    #[test]
    fn arbitrary_removal_minimal_disruption() {
        let mut h = AnchorHash::with_capacity(10, 32);
        let mut rng = SplitMix64Rng::new(2);
        let digests: Vec<u64> = (0..5_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        h.remove_arbitrary(4);
        for (&d, &b) in digests.iter().zip(&before) {
            let after = h.bucket(d);
            if b != 4 {
                assert_eq!(after, b, "key moved off a surviving bucket");
            } else {
                assert_ne!(after, 4);
            }
        }
    }

    #[test]
    fn restore_returns_exact_prior_mapping() {
        let mut h = AnchorHash::with_capacity(10, 32);
        let mut rng = SplitMix64Rng::new(3);
        let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        h.remove_arbitrary(7);
        h.restore(7);
        let after: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn balanced_rough() {
        let h = AnchorHash::with_capacity(11, 64);
        let k = 110_000u32;
        let mut counts = vec![0u32; 64];
        let mut rng = SplitMix64Rng::new(4);
        for _ in 0..k {
            counts[h.bucket(rng.next_u64()) as usize] += 1;
        }
        let mean = k as f64 / 11.0;
        for b in working_set(&h) {
            let c = counts[b as usize] as f64;
            assert!((c - mean).abs() < 0.1 * mean, "b={b} c={c} mean={mean}");
        }
    }

    #[test]
    fn lifo_add_remove_roundtrip() {
        let mut h = AnchorHash::with_capacity(5, 16);
        let added = h.add_bucket();
        assert_eq!(h.len(), 6);
        let removed = h.remove_bucket();
        assert_eq!(removed, added);
        assert_eq!(h.len(), 5);
    }

    #[test]
    fn lifo_ready_detects_disguised_arbitrary_removals() {
        let mut h = AnchorHash::with_capacity(8, 8);
        assert!(h.lifo_ready());
        // Arbitrary removals whose most recent victim happens to equal
        // the shrunken n must still be detected: the working set here is
        // {0..5, 7}, not 0..6, and bucket 7 would outrange a shard list.
        h.remove_arbitrary(5);
        assert!(!h.lifo_ready());
        h.remove_arbitrary(6);
        assert_eq!(h.len(), 6);
        assert!(!h.lifo_ready());
        h.restore(6);
        h.restore(5);
        assert!(h.lifo_ready());
        // Plain LIFO churn keeps readiness.
        h.remove_bucket();
        assert!(h.lifo_ready());
    }

    #[test]
    fn degraded_scaling_and_restore_order_hints() {
        let mut h = AnchorHash::with_capacity(6, 16);
        assert!(h.grow_ready().is_ok());
        assert!(h.shrink_ready().is_ok());
        h.remove_arbitrary(2);
        h.remove_arbitrary(4);
        // Growth would restore 4, not grow: named in the reason.
        assert!(h.grow_ready().unwrap_err().contains('4'));
        assert!(h.shrink_ready().is_err());
        // Restore order: 4 (top of stack) first, then 2.
        assert!(h.restore_blocked(4).is_none());
        assert!(h.restore_blocked(2).unwrap().contains('4'));
        h.restore(4);
        assert!(h.restore_blocked(2).is_none());
        h.restore(2);
        assert!(h.grow_ready().is_ok());
    }
}
