//! **FlipHash** (Masson & Lee, 2024) — documented reconstruction.
//!
//! Published profile: constant-time, constant-memory consistent
//! range-hashing built on a keyed hash family evaluated at multiple seeds
//! per lookup (the paper's reference implementation re-keys XXH3 per
//! attempt).
//!
//! Reconstruction strategy (see the module docs in `algorithms`): the
//! provably-consistent core is
//! shared with the other constant-time algorithms (enclosing power-of-two
//! range, retry, boundary-size fallback); FlipHash's distinguishing trait
//! here is that every retry draw **re-keys a full 8-byte hash of the
//! digest** (xxhash64 with the attempt index as seed) rather than chaining
//! a cheap mixer — reproducing the paper's observed "slightly slower than
//! the integer-chaining algorithms" profile for the honest structural
//! reason (≈3× more ALU work per draw).

use crate::hashing::{next_pow2, xxhash64};

use super::binomial::relocate_within_level;
use super::ConsistentHasher;

/// Default re-key attempts before the boundary fallback.
pub const DEFAULT_ATTEMPTS: u32 = 16;

/// FlipHash lookup: digest × n → bucket (free function, hot path).
#[inline]
pub fn fliphash(digest: u64, n: u32, attempts: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    let e = next_pow2(n as u64);
    let m = e >> 1;
    let bytes = digest.to_le_bytes();
    let mut hi = digest;
    for i in 0..attempts {
        let b = hi & (e - 1);
        let c = relocate_within_level(b, hi);
        if c < m {
            // "Flip" down to the boundary-size placement: a pure function
            // of (digest, m), seamless across range doublings.
            let d = digest & (m - 1);
            return relocate_within_level(d, digest) as u32;
        }
        if c < n as u64 {
            return c as u32;
        }
        hi = xxhash64(&bytes, (i + 1) as u64); // re-keyed draw
    }
    let d = digest & (m - 1);
    relocate_within_level(d, digest) as u32
}

/// FlipHash wrapped in the [`ConsistentHasher`] interface.
#[derive(Debug, Clone, Copy)]
pub struct FlipHash {
    n: u32,
    attempts: u32,
}

impl FlipHash {
    /// Create with `n` buckets and the default attempt cap.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n, attempts: DEFAULT_ATTEMPTS }
    }
}

impl ConsistentHasher for FlipHash {
    fn name(&self) -> &'static str {
        "fliphash"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        fliphash(digest, self.n, self.attempts)
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let mut rng = SplitMix64Rng::new(21);
        for n in [1u32, 2, 3, 9, 16, 17, 1000] {
            for _ in 0..500 {
                assert!(fliphash(rng.next_u64(), n, DEFAULT_ATTEMPTS) < n);
            }
        }
    }

    #[test]
    fn monotone_single_step() {
        let mut rng = SplitMix64Rng::new(4);
        for _ in 0..5_000 {
            let h = rng.next_u64();
            let n = 1 + rng.next_below(300) as u32;
            let before = fliphash(h, n, DEFAULT_ATTEMPTS);
            let after = fliphash(h, n + 1, DEFAULT_ATTEMPTS);
            assert!(after == before || after == n, "h={h} n={n} {before}->{after}");
        }
    }

    #[test]
    fn era_boundary_consistency() {
        // n = 2^q -> 2^q + 1 doubles the enclosing range; keys must either
        // stay or move to the single new bucket.
        let mut rng = SplitMix64Rng::new(6);
        for q in [1u32, 2, 3, 4, 6, 8] {
            let n = 1u32 << q;
            for _ in 0..2_000 {
                let h = rng.next_u64();
                let before = fliphash(h, n, DEFAULT_ATTEMPTS);
                let after = fliphash(h, n + 1, DEFAULT_ATTEMPTS);
                assert!(after == before || after == n);
            }
        }
    }

    #[test]
    fn balanced_rough() {
        for n in [11u32, 24] {
            let k = 10_000 * n;
            let mut counts = vec![0u32; n as usize];
            let mut rng = SplitMix64Rng::new(1);
            for _ in 0..k {
                counts[fliphash(rng.next_u64(), n, DEFAULT_ATTEMPTS) as usize] += 1;
            }
            let mean = k as f64 / n as f64;
            for c in counts {
                assert!((c as f64 - mean).abs() < 0.06 * mean, "n={n} c={c} mean={mean}");
            }
        }
    }

    #[test]
    fn distinct_from_binomial_and_jumpback() {
        let mut rng = SplitMix64Rng::new(9);
        let n = 23;
        let mut diff_b = 0;
        let mut diff_j = 0;
        for _ in 0..1_000 {
            let d = rng.next_u64();
            if fliphash(d, n, DEFAULT_ATTEMPTS) != super::super::binomial::lookup(d, n, 6) {
                diff_b += 1;
            }
            if fliphash(d, n, DEFAULT_ATTEMPTS) != super::super::jumpback::jumpback(d, n) {
                diff_j += 1;
            }
        }
        assert!(diff_b > 100 && diff_j > 100, "{diff_b} {diff_j}");
    }
}
