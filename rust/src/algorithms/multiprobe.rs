//! **Multi-probe consistent hashing** (Appleton & O'Reilly, 2015): one
//! ring point per bucket (O(n) memory, no virtual-node blowup); a lookup
//! probes the ring `k` times with different key hashes and keeps the probe
//! whose clockwise distance to the next point is smallest, trading lookup
//! cost (k · O(log n)) for balance.

use crate::hashing::hash2;

use super::ConsistentHasher;

/// Default probe count (the published sweet spot for ~peak-to-mean 1.1).
pub const DEFAULT_PROBES: u32 = 21;

/// Multi-probe ring: sorted points, one per bucket.
#[derive(Debug, Clone)]
pub struct MultiProbe {
    /// Sorted (point, bucket) pairs.
    points: Vec<(u64, u32)>,
    n: u32,
    probes: u32,
}

impl MultiProbe {
    /// Create with `n` buckets and `probes` probes per lookup.
    pub fn new(n: u32, probes: u32) -> Self {
        assert!(n >= 1 && probes >= 1);
        let mut points: Vec<(u64, u32)> =
            (0..n).map(|b| (Self::point(b), b)).collect();
        points.sort_unstable();
        Self { points, n, probes }
    }

    fn point(bucket: u32) -> u64 {
        hash2(bucket as u64, 0x9_0BE5)
    }

    /// Clockwise distance from `x` to the next ring point, and its bucket.
    #[inline]
    fn successor(&self, x: u64) -> (u64, u32) {
        let i = self.points.partition_point(|&(p, _)| p < x);
        let (p, b) = if i == self.points.len() { self.points[0] } else { self.points[i] };
        (p.wrapping_sub(x), b)
    }
}

impl ConsistentHasher for MultiProbe {
    fn name(&self) -> &'static str {
        "multiprobe"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        let mut best_d = u64::MAX;
        let mut best_b = 0u32;
        for i in 0..self.probes {
            let x = hash2(digest, i as u64 ^ 0xF00D);
            let (d, b) = self.successor(x);
            if d < best_d {
                best_d = d;
                best_b = b;
            }
        }
        best_b
    }

    fn add_bucket(&mut self) -> u32 {
        let b = self.n;
        let p = Self::point(b);
        let i = self.points.partition_point(|&(q, _)| q < p);
        self.points.insert(i, (p, b));
        self.n += 1;
        b
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        let b = self.n;
        let p = Self::point(b);
        let i = self.points.partition_point(|&(q, _)| q < p);
        debug_assert_eq!(self.points[i], (p, b));
        self.points.remove(i);
        b
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let h = MultiProbe::new(13, DEFAULT_PROBES);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..2_000 {
            assert!(h.bucket(rng.next_u64()) < 13);
        }
    }

    #[test]
    fn add_remove_roundtrip_exact() {
        let mut h = MultiProbe::new(9, DEFAULT_PROBES);
        let mut rng = SplitMix64Rng::new(2);
        let digests: Vec<u64> = (0..3_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        h.add_bucket();
        h.remove_bucket();
        let after: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn monotone_single_step() {
        let mut rng = SplitMix64Rng::new(3);
        for _ in 0..800 {
            let d = rng.next_u64();
            let n = 1 + rng.next_below(50) as u32;
            let before = MultiProbe::new(n, DEFAULT_PROBES).bucket(d);
            let after = MultiProbe::new(n + 1, DEFAULT_PROBES).bucket(d);
            assert!(after == before || after == n);
        }
    }

    #[test]
    fn balance_better_than_single_probe() {
        let k = 50_000u32;
        let spread = |probes: u32| -> f64 {
            let h = MultiProbe::new(12, probes);
            let mut counts = vec![0u32; 12];
            let mut rng = SplitMix64Rng::new(4);
            for _ in 0..k {
                counts[h.bucket(rng.next_u64()) as usize] += 1;
            }
            let mean = k as f64 / 12.0;
            let var =
                counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / 12.0;
            var.sqrt() / mean
        };
        assert!(spread(DEFAULT_PROBES) < spread(1));
    }
}
