//! **BinomialHash** — the paper's contribution (Algorithms 1 and 2).
//!
//! Exact implementation of the constant-time, minimal-memory consistent
//! hash: map the digest against the *enclosing* perfect hanging tree
//! (capacity `E = next_pow2(n)`), relocate uniformly within the landing
//! level, and resolve invalid buckets (`[n, E)`) by rehashing up to ω
//! times before falling back to a congruent remap over the *minor* tree
//! (capacity `M = E/2`).
//!
//! State is two `u32`s (`n`, ω): minimal memory.  The loop is bounded by
//! ω and every primitive is O(1) integer/bitwise work: constant time.
//!
//! Bit-for-bit identical to `python/compile/kernels/scalar_ref.py` and to
//! the Pallas kernel artifact (pinned by `tests/golden/`).

use crate::hashing::{hash2, next_hash, next_pow2};

use super::ConsistentHasher;

/// Default maximum rehash iterations ω (§4.4: imbalance `< 1/2^ω` ≈ 1.6%).
pub const DEFAULT_OMEGA: u32 = 6;

/// The BinomialHash consistent-hashing function.
///
/// `Copy`-cheap and stateless between lookups; cloning or snapshotting a
/// placement epoch costs 8 bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinomialHash {
    n: u32,
    omega: u32,
    /// Cached `next_pow2(n)` (kept in sync by add/remove; §Perf).
    e: u64,
}

/// Algorithm 2 — `relocateWithinLevel(b, h)`.
///
/// Uniformly redistributes bucket `b` within its tree level: level 0
/// (bucket 0) and level 1 (bucket 1) are singletons and pass through;
/// otherwise with `d = highestOneBitIndex(b)` and mask `f = 2^d − 1` the
/// relocated bucket is `2^d + (hash(h, f) & f)`.
#[inline(always)]
pub fn relocate_within_level(b: u64, h: u64) -> u64 {
    // Branchless form (§Perf: −2…4 ns/lookup vs the early-return version):
    // `b | 2` keeps the leading-zero count well-defined for b < 2, and the
    // final select preserves the Alg. 2 pass-through for levels 0/1
    // (for b >= 2, b | 2 == b, so `d` is exact).
    let d = 63 - (b | 2).leading_zeros();
    let f = (1u64 << d) - 1;
    let i = hash2(h, f) & f;
    let relocated = (1u64 << d) + i;
    if b < 2 {
        b
    } else {
        relocated
    }
}

/// Algorithm 1 — `lookup(h0, n, ω)`: map digest `h0` to a bucket `[0, n)`.
///
/// Free function form used by the hot paths (router, benches) so the call
/// is trivially inlinable without `dyn` dispatch.
#[inline]
pub fn lookup(h0: u64, n: u32, omega: u32) -> u32 {
    lookup_with_tree(h0, n, next_pow2(n as u64), omega)
}

/// Algorithm 1 with the enclosing-tree capacity `E` precomputed.
///
/// The placement-engine form ([`BinomialHash`] caches `E` across lookups;
/// §Perf: −2 ns/lookup on the router hot path).  `e` MUST equal
/// `next_pow2(n)`.
#[inline]
pub fn lookup_with_tree(h0: u64, n: u32, e: u64, omega: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    debug_assert_eq!(e, next_pow2(n as u64));
    let m = e >> 1; // capacity of the minor tree
    let mut hi = h0;
    for _ in 0..omega {
        let b = hi & (e - 1); // line 4
        let c = relocate_within_level(b, hi); // line 5
        if c < m {
            // block A: rehash the ORIGINAL digest against the minor tree
            let d = h0 & (m - 1);
            return relocate_within_level(d, h0) as u32;
        }
        if c < n as u64 {
            return c as u32; // block B
        }
        hi = next_hash(hi); // line 13
    }
    // block C: congruent remap over the minor tree
    let d = h0 & (m - 1);
    relocate_within_level(d, h0) as u32
}

impl BinomialHash {
    /// Create with `n` buckets and the default ω.
    pub fn new(n: u32) -> Self {
        Self::with_omega(n, DEFAULT_OMEGA)
    }

    /// Create with an explicit ω (max rehash iterations).
    pub fn with_omega(n: u32, omega: u32) -> Self {
        assert!(n >= 1, "cluster must have at least one bucket");
        assert!(omega >= 1, "omega must be at least 1");
        Self { n, omega, e: next_pow2(n as u64) }
    }

    /// The configured ω.
    pub fn omega(&self) -> u32 {
        self.omega
    }

    /// Capacity `E` of the enclosing tree for the current `n`.
    pub fn enclosing_capacity(&self) -> u64 {
        self.e
    }

    /// Capacity `M` of the minor tree for the current `n`.
    pub fn minor_capacity(&self) -> u64 {
        self.enclosing_capacity() >> 1
    }
}

impl ConsistentHasher for BinomialHash {
    fn name(&self) -> &'static str {
        "binomial"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        lookup_with_tree(digest, self.n, self.e, self.omega)
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.e = next_pow2(self.n as u64);
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1, "cannot remove the last bucket");
        self.n -= 1;
        self.e = next_pow2(self.n as u64);
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range_exhaustive_small() {
        for n in 1..=70u32 {
            let h = BinomialHash::new(n);
            let mut rng = SplitMix64Rng::new(n as u64);
            for _ in 0..500 {
                let b = h.bucket(rng.next_u64());
                assert!(b < n, "n={n} b={b}");
            }
        }
    }

    #[test]
    fn n_one_maps_everything_to_zero() {
        let h = BinomialHash::new(1);
        let mut rng = SplitMix64Rng::new(9);
        for _ in 0..100 {
            assert_eq!(h.bucket(rng.next_u64()), 0);
        }
    }

    #[test]
    fn relocate_preserves_level() {
        let mut rng = SplitMix64Rng::new(5);
        for _ in 0..5_000 {
            let b = 2 + rng.next_below((1 << 32) - 2);
            let h = rng.next_u64();
            let c = relocate_within_level(b, h);
            assert_eq!(63 - c.leading_zeros(), 63 - b.leading_zeros());
        }
    }

    #[test]
    fn omega_one_still_valid() {
        let h = BinomialHash::with_omega(11, 1);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..2_000 {
            assert!(h.bucket(rng.next_u64()) < 11);
        }
    }

    #[test]
    fn tree_capacities() {
        let h = BinomialHash::new(11);
        assert_eq!(h.enclosing_capacity(), 16);
        assert_eq!(h.minor_capacity(), 8);
        let h = BinomialHash::new(16);
        assert_eq!(h.enclosing_capacity(), 16);
        assert_eq!(h.minor_capacity(), 8);
        let h = BinomialHash::new(17);
        assert_eq!(h.enclosing_capacity(), 32);
    }

    #[test]
    fn add_remove_lifo() {
        let mut h = BinomialHash::new(3);
        assert_eq!(h.add_bucket(), 3);
        assert_eq!(h.len(), 4);
        assert_eq!(h.remove_bucket(), 3);
        assert_eq!(h.len(), 3);
    }
}
