//! **BinomialHash** — the paper's contribution (Algorithms 1 and 2).
//!
//! Exact implementation of the constant-time, minimal-memory consistent
//! hash: map the digest against the *enclosing* perfect hanging tree
//! (capacity `E = next_pow2(n)`), relocate uniformly within the landing
//! level, and resolve invalid buckets (`[n, E)`) by rehashing up to ω
//! times before falling back to a congruent remap over the *minor* tree
//! (capacity `M = E/2`).
//!
//! State is two `u32`s (`n`, ω): minimal memory.  The loop is bounded by
//! ω and every primitive is O(1) integer/bitwise work: constant time.
//!
//! Bit-for-bit identical to `python/compile/kernels/scalar_ref.py` and to
//! the Pallas kernel artifact (pinned by `tests/golden/`).
//!
//! # Perf
//!
//! Scalar path: [`relocate_within_level`] is branchless (`b | 2` keeps
//! the leading-zero count defined for the level-0/1 pass-through; −2…4
//! ns/lookup vs the early-return form), and [`BinomialHash`] caches the
//! enclosing-tree capacity `E` across lookups (−2 ns/lookup on the
//! router hot path) — `benches/perf_variants.rs` keeps both honest.
//!
//! Batched path: [`lookup_batch`] is the [`ConsistentHasher::bucket_batch`]
//! kernel.  The scalar loop serializes on one ω-bounded rehash chain per
//! key; the batch kernel instead runs [`LANES`] keys per chunk with the ω
//! iteration hoisted *outside* the lane loop, per-lane all-ones/zero
//! `u64` done-masks replacing the scalar early-returns, and branchless
//! block-A/B/C resolution.  Two identities collapse the control flow:
//! block A and block C return the same *minor remap*
//! `relocateWithinLevel(h0 & (M−1), h0)` (hoisted and computed once per
//! lane up front), and `M = E/2 < n` always, so `c < M` implies `c < n`
//! — per iteration a lane needs only `fin = c < n` and
//! `val = select(c < M, minor, c)`.  The payoff is instruction- and
//! memory-level parallelism — eight independent integer dependency
//! chains the CPU pipelines regardless of whether the autovectorizer
//! also lowers the unrolled lane loop to SIMD (portable std-only Rust;
//! no intrinsics).  `perf_variants.rs` reports scalar vs batched
//! ns/key at batch 64 / 1k / 64k.

use crate::hashing::{hash2, next_hash, next_pow2};

use super::ConsistentHasher;

/// Default maximum rehash iterations ω (§4.4: imbalance `< 1/2^ω` ≈ 1.6%).
pub const DEFAULT_OMEGA: u32 = 6;

/// The BinomialHash consistent-hashing function.
///
/// `Copy`-cheap and stateless between lookups; cloning or snapshotting a
/// placement epoch costs 8 bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinomialHash {
    n: u32,
    omega: u32,
    /// Cached `next_pow2(n)` (kept in sync by add/remove; §Perf).
    e: u64,
}

/// Algorithm 2 — `relocateWithinLevel(b, h)`.
///
/// Uniformly redistributes bucket `b` within its tree level: level 0
/// (bucket 0) and level 1 (bucket 1) are singletons and pass through;
/// otherwise with `d = highestOneBitIndex(b)` and mask `f = 2^d − 1` the
/// relocated bucket is `2^d + (hash(h, f) & f)`.
#[inline(always)]
pub fn relocate_within_level(b: u64, h: u64) -> u64 {
    // Branchless form (§Perf: −2…4 ns/lookup vs the early-return version):
    // `b | 2` keeps the leading-zero count well-defined for b < 2, and the
    // final select preserves the Alg. 2 pass-through for levels 0/1
    // (for b >= 2, b | 2 == b, so `d` is exact).
    let d = 63 - (b | 2).leading_zeros();
    let f = (1u64 << d) - 1;
    let i = hash2(h, f) & f;
    let relocated = (1u64 << d) + i;
    if b < 2 {
        b
    } else {
        relocated
    }
}

/// Algorithm 1 — `lookup(h0, n, ω)`: map digest `h0` to a bucket `[0, n)`.
///
/// Free function form used by the hot paths (router, benches) so the call
/// is trivially inlinable without `dyn` dispatch.
#[inline]
pub fn lookup(h0: u64, n: u32, omega: u32) -> u32 {
    lookup_with_tree(h0, n, next_pow2(n as u64), omega)
}

/// Algorithm 1 with the enclosing-tree capacity `E` precomputed.
///
/// The placement-engine form ([`BinomialHash`] caches `E` across lookups;
/// §Perf: −2 ns/lookup on the router hot path).  `e` MUST equal
/// `next_pow2(n)`.
#[inline]
pub fn lookup_with_tree(h0: u64, n: u32, e: u64, omega: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    debug_assert_eq!(e, next_pow2(n as u64));
    let m = e >> 1; // capacity of the minor tree
    let mut hi = h0;
    for _ in 0..omega {
        let b = hi & (e - 1); // line 4
        let c = relocate_within_level(b, hi); // line 5
        if c < m {
            // block A: rehash the ORIGINAL digest against the minor tree
            let d = h0 & (m - 1);
            return relocate_within_level(d, h0) as u32;
        }
        if c < n as u64 {
            return c as u32; // block B
        }
        hi = next_hash(hi); // line 13
    }
    // block C: congruent remap over the minor tree
    let d = h0 & (m - 1);
    relocate_within_level(d, h0) as u32
}

/// Lane width of the batched kernel: chunks of 8 keys give the CPU eight
/// independent rehash chains to pipeline (and a power-of-two width the
/// autovectorizer can split across 128/256/512-bit registers).
pub const LANES: usize = 8;

/// Algorithm 1 over a batch: `out[i] = lookup_with_tree(digests[i], n, e,
/// omega)` for every `i`, computed [`LANES`] keys at a time.
///
/// See the module-level §Perf notes for the kernel shape (hoisted ω
/// iteration, per-lane done-masks, branchless block-A/B/C resolution).
/// `e` MUST equal `next_pow2(n)`.  The tail chunk (`len % LANES`) falls
/// back to the scalar lookup; results are bit-for-bit identical to it
/// either way (pinned by the golden vectors and the engine-wide
/// batch-vs-scalar property test).
///
/// # Panics
/// Panics if `digests.len() != out.len()`.
pub fn lookup_batch(digests: &[u64], n: u32, e: u64, omega: u32, out: &mut [u32]) {
    assert_eq!(digests.len(), out.len(), "bucket_batch slice length mismatch");
    if n <= 1 {
        out.fill(0);
        return;
    }
    debug_assert_eq!(e, next_pow2(n as u64));
    let m = e >> 1; // capacity of the minor tree; m < n always
    let nn = n as u64;
    let chunks = digests.chunks_exact(LANES);
    let tail = chunks.remainder();
    for (d8, o8) in chunks.zip(out.chunks_exact_mut(LANES)) {
        let mut hi = [0u64; LANES];
        let mut minor = [0u64; LANES]; // block A ≡ block C value, hoisted
        let mut res = [0u64; LANES];
        let mut done = [0u64; LANES]; // all-ones once the lane resolved
        for l in 0..LANES {
            let h0 = d8[l];
            hi[l] = h0;
            minor[l] = relocate_within_level(h0 & (m - 1), h0);
        }
        for _ in 0..omega {
            let mut all = !0u64;
            for l in 0..LANES {
                let b = hi[l] & (e - 1); // line 4
                let c = relocate_within_level(b, hi[l]); // line 5
                // Mask arithmetic replaces the scalar early-returns:
                // block A (c < m) resolves to the hoisted minor remap,
                // block B (m <= c < n) to c itself; a lane latches its
                // first resolution and idles (its chain keeps rehashing
                // harmlessly) until the whole chunk drains.
                let is_a = 0u64.wrapping_sub((c < m) as u64);
                let fin = 0u64.wrapping_sub((c < nn) as u64);
                let val = (minor[l] & is_a) | (c & !is_a);
                let newly = fin & !done[l];
                res[l] = (res[l] & !newly) | (val & newly);
                done[l] |= fin;
                hi[l] = next_hash(hi[l]); // line 13
                all &= done[l];
            }
            if all == !0u64 {
                break;
            }
        }
        for l in 0..LANES {
            // Unresolved lanes take block C — the same minor remap.
            o8[l] = ((res[l] & done[l]) | (minor[l] & !done[l])) as u32;
        }
    }
    let split = digests.len() - tail.len();
    for (digest, slot) in tail.iter().zip(&mut out[split..]) {
        *slot = lookup_with_tree(*digest, n, e, omega);
    }
}

impl BinomialHash {
    /// Create with `n` buckets and the default ω.
    pub fn new(n: u32) -> Self {
        Self::with_omega(n, DEFAULT_OMEGA)
    }

    /// Create with an explicit ω (max rehash iterations).
    pub fn with_omega(n: u32, omega: u32) -> Self {
        assert!(n >= 1, "cluster must have at least one bucket");
        assert!(omega >= 1, "omega must be at least 1");
        Self { n, omega, e: next_pow2(n as u64) }
    }

    /// The configured ω.
    pub fn omega(&self) -> u32 {
        self.omega
    }

    /// Capacity `E` of the enclosing tree for the current `n`.
    pub fn enclosing_capacity(&self) -> u64 {
        self.e
    }

    /// Capacity `M` of the minor tree for the current `n`.
    pub fn minor_capacity(&self) -> u64 {
        self.enclosing_capacity() >> 1
    }
}

impl ConsistentHasher for BinomialHash {
    fn name(&self) -> &'static str {
        "binomial"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        lookup_with_tree(digest, self.n, self.e, self.omega)
    }

    #[inline]
    fn bucket_batch(&self, digests: &[u64], out: &mut [u32]) {
        lookup_batch(digests, self.n, self.e, self.omega, out);
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.e = next_pow2(self.n as u64);
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1, "cannot remove the last bucket");
        self.n -= 1;
        self.e = next_pow2(self.n as u64);
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range_exhaustive_small() {
        for n in 1..=70u32 {
            let h = BinomialHash::new(n);
            let mut rng = SplitMix64Rng::new(n as u64);
            for _ in 0..500 {
                let b = h.bucket(rng.next_u64());
                assert!(b < n, "n={n} b={b}");
            }
        }
    }

    #[test]
    fn n_one_maps_everything_to_zero() {
        let h = BinomialHash::new(1);
        let mut rng = SplitMix64Rng::new(9);
        for _ in 0..100 {
            assert_eq!(h.bucket(rng.next_u64()), 0);
        }
    }

    #[test]
    fn relocate_preserves_level() {
        let mut rng = SplitMix64Rng::new(5);
        for _ in 0..5_000 {
            let b = 2 + rng.next_below((1 << 32) - 2);
            let h = rng.next_u64();
            let c = relocate_within_level(b, h);
            assert_eq!(63 - c.leading_zeros(), 63 - b.leading_zeros());
        }
    }

    #[test]
    fn omega_one_still_valid() {
        let h = BinomialHash::with_omega(11, 1);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..2_000 {
            assert!(h.bucket(rng.next_u64()) < 11);
        }
    }

    #[test]
    fn tree_capacities() {
        let h = BinomialHash::new(11);
        assert_eq!(h.enclosing_capacity(), 16);
        assert_eq!(h.minor_capacity(), 8);
        let h = BinomialHash::new(16);
        assert_eq!(h.enclosing_capacity(), 16);
        assert_eq!(h.minor_capacity(), 8);
        let h = BinomialHash::new(17);
        assert_eq!(h.enclosing_capacity(), 32);
    }

    #[test]
    fn batch_kernel_matches_scalar() {
        // Every (n, ω) class the control flow distinguishes: n = 1 (fill
        // zeros), n = 2 (smallest real tree), powers of two (E = n),
        // power-of-two ± 1 (E jumps), ω = 1 (block C dominates).
        let mut rng = SplitMix64Rng::new(0x10_0ba7);
        for &(n, omega) in
            &[(1, 6), (2, 6), (3, 6), (7, 6), (8, 6), (9, 6), (64, 6), (65, 6), (11, 1), (100, 3)]
        {
            let h = BinomialHash::with_omega(n, omega);
            // Lengths around the LANES boundary exercise full chunks,
            // the scalar tail, and the empty batch.
            for len in [0usize, 1, 7, 8, 9, 16, 67] {
                let digests: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
                let mut out = vec![u32::MAX; len];
                h.bucket_batch(&digests, &mut out);
                for (digest, got) in digests.iter().zip(&out) {
                    assert_eq!(*got, h.bucket(*digest), "n={n} omega={omega} digest={digest:#x}");
                }
            }
        }
    }

    #[test]
    fn add_remove_lifo() {
        let mut h = BinomialHash::new(3);
        assert_eq!(h.add_bucket(), 3);
        assert_eq!(h.len(), 4);
        assert_eq!(h.remove_bucket(), 3);
        assert_eq!(h.len(), 3);
    }
}
