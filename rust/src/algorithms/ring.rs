//! **Hash ring** (Karger et al., 1997) — classic consistent hashing with
//! virtual nodes.  Each bucket owns `vnodes` points on a 64-bit ring; a
//! key maps to the bucket owning the first point clockwise of its digest.
//! O(log(n·vnodes)) lookups via `BTreeMap`, O(n·vnodes) memory — the
//! state-heavy baseline the constant-time family eliminates.

use std::collections::BTreeMap;

use crate::hashing::hash2;

use super::ConsistentHasher;

/// Default virtual nodes per bucket (typical production setting; also the
/// setting used by the authors' survey \[3\]).
pub const DEFAULT_VNODES: u32 = 100;

/// Karger-style hash ring with virtual nodes.
#[derive(Debug, Clone)]
pub struct HashRing {
    ring: BTreeMap<u64, u32>,
    n: u32,
    vnodes: u32,
}

impl HashRing {
    /// Create with `n` buckets × `vnodes` points each.
    pub fn new(n: u32, vnodes: u32) -> Self {
        assert!(n >= 1 && vnodes >= 1);
        let mut this = Self { ring: BTreeMap::new(), n: 0, vnodes };
        for _ in 0..n {
            this.add_bucket();
        }
        this
    }

    fn point(bucket: u32, replica: u32) -> u64 {
        hash2(((bucket as u64) << 32) | replica as u64, 0x51D0_0D)
    }
}

impl ConsistentHasher for HashRing {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        debug_assert!(!self.ring.is_empty());
        // First point clockwise of the digest, wrapping at the top.
        match self.ring.range(digest..).next() {
            Some((_, &b)) => b,
            None => *self.ring.values().next().unwrap(),
        }
    }

    fn add_bucket(&mut self) -> u32 {
        let b = self.n;
        for r in 0..self.vnodes {
            self.ring.insert(Self::point(b, r), b);
        }
        self.n += 1;
        b
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        let b = self.n;
        for r in 0..self.vnodes {
            self.ring.remove(&Self::point(b, r));
        }
        b
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let h = HashRing::new(9, 50);
        let mut rng = SplitMix64Rng::new(1);
        for _ in 0..2_000 {
            assert!(h.bucket(rng.next_u64()) < 9);
        }
    }

    #[test]
    fn monotone_and_disruptive_minimal() {
        let mut h = HashRing::new(8, DEFAULT_VNODES);
        let mut rng = SplitMix64Rng::new(2);
        let digests: Vec<u64> = (0..4_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        let added = h.add_bucket();
        for (&d, &b) in digests.iter().zip(&before) {
            let after = h.bucket(d);
            assert!(after == b || after == added);
        }
        h.remove_bucket();
        let restored: Vec<u32> = digests.iter().map(|&d| h.bucket(d)).collect();
        assert_eq!(before, restored);
    }

    #[test]
    fn wraparound_covered() {
        // Digests above the highest ring point must wrap to the first point.
        let h = HashRing::new(3, 10);
        let top = *h.ring.keys().next_back().unwrap();
        if top < u64::MAX {
            let b = h.bucket(top + 1);
            assert_eq!(b, *h.ring.values().next().unwrap());
        }
    }

    #[test]
    fn balance_improves_with_vnodes() {
        let k = 60_000u32;
        let spread = |vnodes: u32| -> f64 {
            let h = HashRing::new(12, vnodes);
            let mut counts = vec![0u32; 12];
            let mut rng = SplitMix64Rng::new(3);
            for _ in 0..k {
                counts[h.bucket(rng.next_u64()) as usize] += 1;
            }
            let mean = k as f64 / 12.0;
            let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / 12.0;
            var.sqrt() / mean
        };
        assert!(spread(200) < spread(2));
    }
}
